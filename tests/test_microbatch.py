"""Gradient accumulation: n microbatches must produce the same update as the
full batch (fp32 accumulators; exact up to bf16 grad rounding)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.models.params import init_params
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step


def test_microbatch_grads_match_full_batch():
    cfg = configs.get_smoke("qwen3_0_6b")
    params = init_params(T.param_defs(cfg), seed=0, dtype=jnp.float32)
    opt = AdamW(lr=1e-3)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}

    step1 = jax.jit(make_train_step(cfg, None, opt))
    step2 = jax.jit(make_train_step(cfg.replace(microbatches=2), None, opt))

    p1, _, m1 = step1(params, opt.init(params), batch)
    p2, _, m2 = step2(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_microbatch_vlm_positions3_axis():
    cfg = configs.get_smoke("qwen2_vl_7b").replace(microbatches=2)
    params = init_params(T.param_defs(cfg), seed=0)
    opt = AdamW(lr=1e-3)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "vision_embeds": jnp.asarray(
            rng.normal(0, 0.02, (B, S // 8, cfg.d_model)), jnp.bfloat16),
        "positions3": jnp.asarray(np.broadcast_to(pos, (3, B, S))),
    }
    step = jax.jit(make_train_step(cfg, None, opt))
    _, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
