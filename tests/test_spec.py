"""Spec layer tests: RunSpec validation, canonical form, content addressing,
and spec-driven rerun equivalence (ISSUE 2 tentpole + satellites)."""
import json
import os
import random

import pytest

from repro.core.conflicts import OutputConflict, WildcardOutputError
from repro.core.records import RunRecord, rerun, run, run_spec, spec_of
from repro.core.repo import Repository
from repro.core.spec import RunSpec, SpecError


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


@pytest.fixture
def repo(tmp_path):
    return Repository.init(str(tmp_path / "repo"), annex_threshold=1 << 20)


# ------------------------------------------------------------- validation
def test_spec_requires_exactly_one_of_cmd_script():
    with pytest.raises(SpecError):
        RunSpec()
    with pytest.raises(SpecError):
        RunSpec(cmd="true", script="job.sh", outputs=["o"])


def test_script_spec_outputs_mandatory():
    with pytest.raises(SpecError):
        RunSpec(script="job.sh", outputs=[])
    RunSpec(cmd="true")  # cmd specs may have no outputs (datalad run)


def test_wildcard_outputs_rejected_for_both_kinds():
    with pytest.raises(WildcardOutputError):
        RunSpec(script="job.sh", outputs=["results/*.csv"])
    with pytest.raises(WildcardOutputError):
        RunSpec(cmd="true", outputs=["out/*.txt"])


def test_outputs_normalized_and_intra_spec_nesting_rejected():
    spec = RunSpec(cmd="true", outputs=["./out//a.txt", "b/../c.txt"])
    assert spec.outputs == ("out/a.txt", "c.txt")
    with pytest.raises(OutputConflict):
        RunSpec(script="j.sh", outputs=["out", "out/a.txt"])
    with pytest.raises(ValueError):
        RunSpec(cmd="true", outputs=["../escape.txt"])


def test_scalar_field_validation():
    with pytest.raises(SpecError):
        RunSpec(script="j.sh", outputs=["o"], array_n=0)
    with pytest.raises(SpecError):
        RunSpec(cmd="true", array_n=4)  # arrays need a script spec
    with pytest.raises(SpecError):
        RunSpec(script="j.sh", outputs=["o"], time_limit_s=0.0)
    with pytest.raises(SpecError):
        RunSpec(cmd="true", pwd="../elsewhere")
    with pytest.raises(SpecError):
        RunSpec(cmd="true", pwd="/tmp/outside")  # absolute pwd escapes too
    # a real in-repo directory whose name starts with dots is legitimate
    assert RunSpec(cmd="true", pwd="..cache/run1").pwd == "..cache/run1"
    with pytest.raises(SpecError):
        RunSpec(script="j.sh", outputs="ab")  # bare string, not a sequence
    with pytest.raises(SpecError):
        RunSpec(cmd="true", inputs="in.txt", outputs=["o"])


def test_spec_is_frozen_and_replace_revalidates():
    spec = RunSpec(script="j.sh", outputs=["o"])
    with pytest.raises(Exception):
        spec.script = "other.sh"
    derived = spec.replace(message="again", alt_dir="/tmp/pfs")
    assert derived.message == "again" and spec.message == ""
    with pytest.raises(SpecError):
        spec.replace(outputs=())  # still a script spec -> outputs mandatory


# ------------------------------------------- canonical form / content address
def test_roundtrip_identity_property():
    """RunSpec -> canonical JSON -> RunSpec is the identity, across many
    randomized specs (seeded property test)."""
    rng = random.Random(1234)
    for trial in range(50):
        n_out = rng.randint(1, 5)
        fields = dict(
            script_args=" ".join(f"--k{i}" for i in range(rng.randint(0, 3))),
            inputs=tuple(f"in/{rng.randint(0, 99)}.dat" for _ in range(rng.randint(0, 4))),
            outputs=tuple(f"out{trial}/o{i}.txt" for i in range(n_out)),
            pwd=rng.choice([".", "jobs/a", "deep/b/c"]),
            alt_dir=rng.choice([None, "/tmp/pfs"]),
            message=rng.choice(["", "msg", "Solve N=14"]),
            env=tuple(
                (f"VAR{i}", str(rng.randint(0, 9))) for i in range(rng.randint(0, 4))
            ),
        )
        if rng.random() < 0.5:
            spec = RunSpec(cmd=f"echo {trial}", **fields)
        else:
            spec = RunSpec(
                script=f"job{trial}.sh",
                array_n=rng.randint(1, 8),
                time_limit_s=rng.choice([None, 60.0]),
                **fields,
            )
        back = RunSpec.from_json(spec.to_json())
        assert back == spec
        assert back.spec_id == spec.spec_id
        assert RunSpec.from_canonical(spec.canonical_bytes()) == spec


def test_spec_id_stable_across_key_and_env_permutations():
    """spec_id must not depend on JSON key order, env-dict insertion order,
    or list/tuple spelling of path fields."""
    rng = random.Random(99)
    base = RunSpec(
        script="job.sh",
        inputs=["a.dat", "b.dat"],
        outputs=["out/x", "out2/y"],
        env={"B": "2", "A": "1", "C": "3"},
        message="stable",
    )
    for _ in range(20):
        d = base.to_json()
        items = list(d.items())
        rng.shuffle(items)
        shuffled = dict(items)
        env_items = list(d["env"].items())
        rng.shuffle(env_items)
        shuffled["env"] = dict(env_items)
        assert RunSpec.from_json(shuffled).spec_id == base.spec_id
    # env given as differently-ordered tuples of pairs
    assert (
        RunSpec(
            script="job.sh", inputs=("a.dat", "b.dat"), outputs=("out/x", "out2/y"),
            env=(("C", "3"), ("A", "1"), ("B", "2")), message="stable",
        ).spec_id
        == base.spec_id
    )


def test_spec_id_agrees_with_equality_for_numeric_spellings():
    a = RunSpec(script="s.sh", outputs=["o"], time_limit_s=60)
    b = RunSpec(script="s.sh", outputs=["o"], time_limit_s=60.0)
    assert a == b and a.spec_id == b.spec_id


def test_spec_id_differs_on_any_semantic_change():
    base = RunSpec(script="job.sh", outputs=["o"])
    assert base.spec_id != base.replace(script_args="--fast").spec_id
    assert base.spec_id != base.replace(outputs=("o2",)).spec_id
    assert base.spec_id != base.replace(env=(("K", "v"),)).spec_id
    assert base.spec_id != base.replace(array_n=2).spec_id


def test_future_spec_version_rejected():
    d = RunSpec(cmd="true").to_json()
    d["spec_version"] = 999
    with pytest.raises(SpecError):
        RunSpec.from_json(d)


# -------------------------------------------------- spec-driven run / rerun
def test_run_spec_embeds_spec_in_commit_and_record(repo):
    write(repo.root, "in.txt", "3\n")
    repo.save(message="in")
    spec = RunSpec(
        cmd="python3 -c \"print(int(open('in.txt').read())**3, file=open('cube.txt','w'))\"",
        inputs=["in.txt"],
        outputs=["cube.txt"],
        message="cube it",
    )
    oid = run_spec(repo, spec)
    commit = repo.objects.get_commit(oid)
    # first-class commit field: replay needs no message parsing at all
    assert RunSpec.from_json(commit["spec"]) == spec
    # and the RUNCMD block carries it too
    rec = RunRecord.from_message(commit["message"])
    assert RunSpec.from_json(rec.spec).spec_id == spec.spec_id
    assert spec_of(repo, oid).spec_id == spec.spec_id


def test_rerun_reconstructs_exact_spec(repo):
    """Acceptance: rerun reconstructs the originating RunSpec exactly (equal
    spec_id) without reassembling it from the commit message."""
    write(repo.root, "in.txt", "7\n")
    repo.save(message="in")
    spec = RunSpec(
        cmd="python3 -c \"print(int(open('in.txt').read())*2, file=open('out.txt','w'))\"",
        inputs=["in.txt"],
        outputs=["out.txt"],
        env={"Z_LAST": "1", "A_FIRST": "2"},
    )
    oid = run_spec(repo, spec)
    report = rerun(repo, oid)
    assert report["bitwise"] is True
    assert report["spec_id"] == spec.spec_id

    # changed input -> new commit whose embedded spec is byte-identical
    write(repo.root, "in.txt", "50\n")
    repo.save(paths=["in.txt"], message="new input")
    report = rerun(repo, oid)
    assert report["bitwise"] is False and report["new_commit"]
    new_commit = repo.objects.get_commit(report["new_commit"])
    assert (
        RunSpec.from_json(new_commit["spec"]).canonical_bytes()
        == spec.canonical_bytes()
    )


def test_rerun_spec_path_agrees_with_legacy_message_parse_path(repo):
    """Equivalence: a legacy (pre-spec) record — reconstructed by parsing the
    message fields — yields the same outputs verdict as the spec path."""
    cmd = "python3 -c \"print(int(open('n.txt').read()) + 1, file=open('m.txt','w'))\""
    write(repo.root, "n.txt", "1\n")
    repo.save(message="n")
    oid_spec = run(repo, cmd, inputs=["n.txt"], outputs=["m.txt"])  # spec-recorded

    # forge a legacy commit: same record JSON but with no spec anywhere
    legacy_record = RunRecord(
        cmd=cmd, dsid=repo.dsid, inputs=["n.txt"], outputs=["m.txt"], exit=0
    )
    write(repo.root, "m.txt", open(os.path.join(repo.root, "m.txt")).read())
    oid_legacy = repo.save(
        paths=["m.txt"], message=legacy_record.to_message("legacy"), allow_empty=True
    )
    assert repo.objects.get_commit(oid_legacy).get("spec") is None

    r_spec = rerun(repo, oid_spec, report_only=True)
    r_legacy = rerun(repo, oid_legacy, report_only=True)
    assert r_spec["outputs"] == r_legacy["outputs"]
    assert r_spec["bitwise"] == r_legacy["bitwise"] is True
    # and the legacy reconstruction describes the same work
    assert spec_of(repo, oid_legacy).spec_id == spec_of(repo, oid_spec).spec_id


def test_legacy_record_with_nested_outputs_still_replayable(repo):
    """Pre-spec records were never validated; nested/duplicate outputs in
    old history must fold into a replayable spec, not raise."""
    write(repo.root, "results/fig.txt", "fig\n")
    repo.save(message="base")
    legacy = RunRecord(
        cmd="python3 -c \"open('results/fig.txt','w').write('fig\\n')\"",
        dsid=repo.dsid,
        outputs=["results", "results/fig.txt", "results", "results/*.tmp"],
        exit=0,
    )
    oid = repo.save(
        paths=["results"], message=legacy.to_message("legacy nested"),
        allow_empty=True,
    )
    spec = spec_of(repo, oid)
    assert spec.outputs == ("results",)  # dedup + nested + wildcard folded
    assert rerun(repo, oid, report_only=True)["bitwise"] is True


def test_run_spec_rejects_script_specs(repo):
    with pytest.raises(SpecError):
        run_spec(repo, RunSpec(script="job.sh", outputs=["o"]))


def test_run_glob_expands_wildcard_inputs(repo):
    """Satellite: run() accepts wildcard inputs like schedule() does
    (datalad-run semantics) instead of raising FileNotFoundError."""
    write(repo.root, "data/a.csv", "1\n")
    write(repo.root, "data/b.csv", "2\n")
    repo.save(message="data")
    oid = run(
        repo,
        cmd="cat data/*.csv > sum.txt",
        inputs=["data/*.csv"],
        outputs=["sum.txt"],
    )
    assert open(os.path.join(repo.root, "sum.txt")).read() == "1\n2\n"
    # the record keeps the pattern (re-expanded at rerun time)
    assert spec_of(repo, oid).inputs == ("data/*.csv",)
    assert rerun(repo, oid)["bitwise"] is True
    # a missing literal input still refuses
    with pytest.raises(FileNotFoundError):
        run(repo, cmd="true", inputs=["nope.txt"], outputs=["x.txt"])


def test_run_spec_env_applied(repo):
    oid = run_spec(
        repo,
        RunSpec(cmd="echo $SPEC_VAR > envout.txt", outputs=["envout.txt"],
                env={"SPEC_VAR": "from-spec"}),
    )
    assert open(os.path.join(repo.root, "envout.txt")).read().strip() == "from-spec"
    assert rerun(repo, oid)["bitwise"] is True  # env replayed from the spec


def test_canonical_json_is_actually_canonical():
    spec = RunSpec(script="j.sh", outputs=["o"], env={"b": "2", "a": "1"})
    blob = spec.canonical_bytes()
    d = json.loads(blob)
    assert json.dumps(d, sort_keys=True, separators=(",", ":")).encode() == blob
    assert list(d["env"]) == ["a", "b"]
