"""Fault-injection + crash-recovery property tests (DESIGN.md §10).

The central property: for every named crash point, killing the client there
and then recovering a fresh incarnation over the same repository yields zero
divergence — every job finished exactly once, no annex object lost, no
duplicate published record. "Reboot" means a new FS/Repository/Session over
the same root while the *same* LocalSlurmCluster keeps running (the
controller and the compute nodes did not crash with the client).
"""
import json
import os
import time

import pytest

import repro
from repro.core import FaultPlan, FaultRule
from repro.core.faults import (
    CrashInjected,
    InjectedSlurmError,
    new_token,
    owner_is_dead,
)
from repro.core.fsio import FS, NULL_FS
from repro.core.records import RunRecord
from repro.core.recovery import FileLock, LockHeld, list_journals
from repro.core.repo import Repository
from repro.core.session import Session
from repro.core import slurm as S

# the named phase boundaries the crash matrix kills at, one by one
FINISH_POINTS = [
    "finish:journal-written",
    "finish:mid-ingest",
    "finish:after-ingest",
    "finish:before-publish",
    "finish:after-publish",
    "finish:after-close",
]
OCTOPUS_POINTS = ["finish:before-octopus", "finish:after-octopus"]
SUBMIT_POINTS = [
    "submit:jobs-added",
    "submit:after-sbatch",
    "submit:before-set-ids",
    "submit:after-set-ids",
]
REPACK_POINTS = [
    "repack:planned",
    "repack:data-renamed",
    "repack:pack-published",
    "repack:mid-unlink",
]
# §11 cache-hit publication path: these only fire on a WARM re-submission
# (a clean first run never publishes memoized records), so they get their
# own recording test below instead of joining the one-clean-run matrix
MEMOIZE_POINTS = [
    "memoize:journal-written",
    "memoize:before-publish",
    "memoize:after-publish",
    "memoize:after-close",
]


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


def setup_session(tmp_path, plan=None, n_jobs=3):
    """A repo (annex threshold 64 so job outputs annex) + n job specs."""
    root = str(tmp_path / "proj")
    os.makedirs(root, exist_ok=True)
    s = repro.open(root, create=True, faults=plan, annex_threshold=64)
    write(root, "data/seed.txt", "s" * 200)  # annexed seed content
    s.save(message="seed")
    specs = []
    for i in range(n_jobs):
        write(root, f"j{i}.sh", f"#!/bin/bash\nprintf 'x%.0s' {{1..300}} > out{i}.dat\n")
        specs.append(repro.RunSpec(script=f"j{i}.sh", outputs=[f"out{i}.dat"]))
    return root, s, specs


def reboot(root, cluster):
    """A fresh client incarnation over the same repository. The cluster
    (controller + nodes) survived the client crash, so it is reused — but
    the dead incarnation's fault plan does not follow the new client."""
    cluster.faults = None
    return Session(Repository(root, fs=FS(NULL_FS)), cluster=cluster)


def slurm_record_counts(repo):
    """{slurm_id: number of commits publishing its record} over all refs."""
    counts: dict[int, int] = {}
    seen: set[str] = set()
    for b in repo.branches():
        frontier = [repo.branch_head(b)]
        while frontier:
            oid = frontier.pop()
            if oid is None or oid in seen:
                continue
            seen.add(oid)
            c = repo.objects.get_commit(oid)
            rec = RunRecord.from_message(c.get("message", ""))
            if rec is not None and rec.slurm_job_id is not None:
                counts[rec.slurm_job_id] = counts.get(rec.slurm_job_id, 0) + 1
            frontier.extend(c.get("parents", []))
    return counts


def assert_consistent(s2, job_ids):
    rep = s2.verify()
    assert rep["divergence"] == 0, rep["issues"]
    rows = [s2.scheduler.db.get(j) for j in job_ids]
    assert all(r["status"] == "finished" for r in rows), rows
    counts = slurm_record_counts(s2.repo)
    for r in rows:
        assert counts.get(r["slurm_id"]) == 1, (r, counts)


# ------------------------------------------------------------ crash matrix
@pytest.mark.parametrize("point", FINISH_POINTS)
def test_finish_crash_matrix(tmp_path, point):
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s, specs = setup_session(tmp_path, plan)
    job_ids = s.submit_many(specs)
    s.wait()
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.finish()
    s2 = reboot(root, cluster)
    s2.recover()
    assert_consistent(s2, job_ids)
    # recovery is idempotent: a second pass finds nothing to do
    rep2 = s2.recover()
    assert rep2["journals_replayed"] == 0 and rep2["jobs_refinished"] == 0
    cluster.shutdown()


@pytest.mark.parametrize("point", OCTOPUS_POINTS)
def test_finish_octopus_crash_matrix(tmp_path, point):
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s, specs = setup_session(tmp_path, plan)
    job_ids = s.submit_many(specs)
    s.wait()
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.finish(octopus=True)
    s2 = reboot(root, cluster)
    s2.recover()
    assert_consistent(s2, job_ids)
    # the octopus merge happened exactly once (replayed iff it was lost)
    head = s2.repo.head_commit()
    parents = s2.repo.objects.get_commit(head).get("parents", [])
    assert len(parents) == len(job_ids) + 1
    cluster.shutdown()


@pytest.mark.parametrize("point", SUBMIT_POINTS)
def test_submit_crash_matrix(tmp_path, point):
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s, specs = setup_session(tmp_path, plan)
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.submit_many(specs)
    s2 = reboot(root, cluster)
    s2.recover()
    assert s2.verify()["divergence"] == 0
    # journaled submissions were recovered; unjournaled rows were closed —
    # either way every open row is now finishable and nothing leaks
    open_rows = [r for r in s2.scheduler.db.all_jobs() if r["status"] == "scheduled"]
    assert all(r["slurm_id"] is not None for r in open_rows)
    if open_rows:
        s2.wait([r["job_id"] for r in open_rows])
        s2.finish()
    rep = s2.verify()
    assert rep["divergence"] == 0, rep["issues"]
    assert not any(
        r["status"] == "scheduled" for r in s2.scheduler.db.all_jobs()
    )
    cluster.shutdown()


@pytest.mark.parametrize("point", REPACK_POINTS)
def test_repack_crash_matrix(tmp_path, point):
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root = str(tmp_path / "proj")
    os.makedirs(root)
    s = repro.open(root, create=True, faults=plan, annex_threshold=1 << 20)
    for i in range(4):
        write(root, f"f{i}.txt", f"content {i}")
        s.save(paths=[f"f{i}.txt"], message=f"c{i}")
    with pytest.raises(CrashInjected):
        s.gc()
    s2 = Session(Repository(root, fs=FS(NULL_FS)))  # no cluster was involved
    s2.recover()
    assert s2.verify()["divergence"] == 0
    # a crashed repack can never wedge the store: the lock is breakable
    # (either recover() broke it above, or acquire breaks it here) and a
    # fresh repack completes, after which every commit is still readable
    s2.gc()
    assert s2.verify()["divergence"] == 0
    assert s2.repo.resolve("main")


@pytest.mark.parametrize("point", MEMOIZE_POINTS)
def test_memoize_crash_matrix(tmp_path, point):
    """Kill the client inside the §11 cache-hit publication path: a cold
    sweep warms the cache, an identical re-submission crashes at ``point``,
    and recovery must land at zero divergence with every warm row closed
    as memoized exactly once."""
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s, specs = setup_session(tmp_path, plan)
    cold_ids = s.submit_many(specs)  # memoize points never fire cold
    s.wait()
    s.finish()
    head_cold = s.repo.head_commit()
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.submit_many(specs)  # 100% hits -> dies inside _publish_memoized
    s2 = reboot(root, cluster)
    s2.recover()
    rep = s2.verify()
    assert rep["divergence"] == 0, rep["issues"]
    warm_rows = [
        r for r in s2.scheduler.db.all_jobs() if r["job_id"] not in cold_ids
    ]
    assert len(warm_rows) == len(specs)
    assert all(
        r["status"] == "memoized" and r["slurm_id"] is None for r in warm_rows
    ), warm_rows
    # exactly one reachable memoized record per warm job, all ahead of the
    # cold head
    n_memo, oid = 0, s2.repo.head_commit()
    while oid and oid != head_cold:
        c = s2.repo.objects.get_commit(oid)
        rec = RunRecord.from_message(c.get("message", ""))
        assert rec is not None and rec.memoized, oid
        n_memo += 1
        parents = c.get("parents", [])
        oid = parents[0] if parents else None
    assert n_memo == len(specs)
    # recovery is idempotent
    rep2 = s2.recover()
    assert rep2["journals_replayed"] == 0
    assert rep2["memoized_republished"] == 0
    cluster.shutdown()


def test_memoize_crash_points_recorded(tmp_path):
    """The warm-path twin of the clean-run coverage test: a cold sweep
    plus one fully-memoized re-submission passes every MEMOIZE_POINTS
    boundary."""
    plan = FaultPlan(seed=0, record_points=True)
    root, s, specs = setup_session(tmp_path, plan)
    s.submit_many(specs)
    s.wait()
    s.finish()
    s.submit_many(specs)
    s.close()
    log = set(plan.crash_point_log)
    for point in MEMOIZE_POINTS:
        assert point in log, f"{point} never passed on a warm re-submission"


def test_crash_points_recorded_cover_matrix(tmp_path):
    """A clean recording run passes every boundary the matrices kill at —
    guards against the static lists and the code drifting apart."""
    plan = FaultPlan(seed=0, record_points=True)
    root, s, specs = setup_session(tmp_path, plan)
    s.submit_many(specs)
    s.wait()
    s.finish(octopus=True)
    s.gc()
    s.close()
    log = set(plan.crash_point_log)
    for point in FINISH_POINTS + OCTOPUS_POINTS + SUBMIT_POINTS + REPACK_POINTS:
        assert point in log, f"{point} never passed in a clean run"


# --------------------------------------- §12 chunked checkpoint crash matrix
# chunk:* fire inside a chunked annex ingest (chunks publish before the
# manifest); ckpt:* bracket the CheckpointManager commit. These need a
# chunk-enabled repo and a checkpoint save, so they get their own env.
CKPT_POINTS = [
    "chunk:mid-publish",
    "chunk:before-manifest",
    "ckpt:leaves-written",
    "ckpt:after-commit",
]


def ckpt_env(tmp_path, plan=None):
    from repro.core.chunks import ChunkParams

    root = str(tmp_path / "proj")
    os.makedirs(root, exist_ok=True)
    s = repro.open(
        root, create=True, faults=plan, annex_threshold=64,
        chunk_threshold=1 << 12,
        chunk_params=ChunkParams(min_size=1 << 9, avg_bits=10,
                                 max_size=1 << 13),
    )
    return root, s


def ckpt_state(seed=0):
    import numpy as np

    rng = np.random.default_rng(seed)
    # one leaf above the chunk threshold, one 0-d below it
    params = {"w": rng.standard_normal((64, 128), dtype=np.float32)}
    opt_state = {"m": rng.standard_normal((64, 128), dtype=np.float32),
                 "step": np.int32(0)}
    return params, opt_state


def ckpt_manager(repo):
    from repro.train.checkpoint import CheckpointManager

    return CheckpointManager(repo)


@pytest.mark.parametrize("point", CKPT_POINTS)
def test_ckpt_crash_matrix(tmp_path, point):
    """Kill a checkpoint save at every §12 boundary: the commit is
    all-or-nothing, recovery lands at zero divergence, a crashed chunked
    ingest strands only unreferenced chunks (gc sweeps them), and the
    interrupted save replays cleanly."""
    import numpy as np

    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s = ckpt_env(tmp_path, plan)
    params, opt_state = ckpt_state()
    with pytest.raises(CrashInjected):
        ckpt_manager(s.repo).save(1, params, opt_state, data_step=1)
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    s2.recover()
    assert s2.verify()["divergence"] == 0
    ckpt2 = ckpt_manager(s2.repo)
    committed = ckpt2.checkpoints()
    if point == "ckpt:after-commit":
        # the commit landed before the crash: the checkpoint is fully usable
        assert [step for _, step in committed] == [1]
    else:
        # no partial checkpoint commit is ever visible
        assert committed == []
        swept = s2.gc()["chunks_swept"]
        if point.startswith("chunk:"):
            # the dead ingest published chunks but never the manifest
            assert swept > 0, point
        ckpt2.save(1, params, opt_state, data_step=1)
    state, manifest = ckpt2.restore()
    assert manifest["step"] == 1
    assert np.array_equal(np.asarray(state["params"]["w"]), params["w"])
    assert np.array_equal(np.asarray(state["opt_state"]["m"]), opt_state["m"])
    assert s2.verify()["divergence"] == 0
    # gc after recovery+resave leaves no orphans behind
    assert s2.gc()["chunks_swept"] == 0
    s2.close()


def test_ckpt_crash_points_recorded(tmp_path):
    """A clean chunked checkpoint save passes every CKPT_POINTS boundary —
    the matrix above cannot silently rot."""
    plan = FaultPlan(seed=0, record_points=True)
    root, s = ckpt_env(tmp_path, plan)
    params, opt_state = ckpt_state()
    ckpt_manager(s.repo).save(1, params, opt_state)
    log = set(plan.crash_point_log)
    for point in CKPT_POINTS:
        assert point in log, f"{point} never passed in a clean checkpoint save"
    s.close()


# ------------------------------------------------------- transient faults
def run_workload(tmp_path, sub, plan=None):
    root, s, specs = setup_session(tmp_path / sub, plan, n_jobs=2)
    job_ids = s.submit_many(specs)
    s.wait()
    res = s.finish()
    assert all(r.state == S.COMPLETED for r in res)
    elapsed = s.repo.fs.clock.total
    assert s.verify()["divergence"] == 0
    s.close()
    return elapsed


def test_transient_faults_are_retried_with_bounded_charge(tmp_path):
    clean = run_workload(tmp_path, "clean")
    plan = FaultPlan(
        seed=3,
        rules=[
            # sacct fails twice then succeeds (controller under load)
            FaultRule(op="sacct", every=1, times=2, transient=True),
            # every 50th read throws a transient EIO
            FaultRule(op="read", every=50, times=4, transient=True),
        ],
    )
    faulty = run_workload(tmp_path, "faulty", plan)
    # retried to success, charging only bounded backoff on the sim clock
    assert faulty >= clean
    assert faulty - clean < 2.0, (clean, faulty)


def test_transient_exhaustion_surfaces_the_error(tmp_path):
    plan = FaultPlan(
        seed=3, max_slurm_retries=2,
        rules=[FaultRule(op="sbatch", transient=True)],  # never stops failing
    )
    root, s, specs = setup_session(tmp_path, plan, n_jobs=1)
    with pytest.raises(InjectedSlurmError):
        s.submit_many(specs)
    # the soft-failure path cleaned up: row closed, journal retired
    rows = s.scheduler.db.all_jobs()
    assert all(r["status"] == "submit-failed" for r in rows)
    assert list_journals(s.repo.fs, s.repo.repro_dir) == []
    s.close()


def test_seeded_probabilistic_rules_are_deterministic():
    def fires(seed):
        plan = FaultPlan(seed=seed, rules=[FaultRule(op="read", p=0.3)])
        out = []
        for i in range(64):
            try:
                plan.on_fs("read", f"/f{i}")
                out.append(0)
            except IOError:
                out.append(1)
        return out

    assert fires(11) == fires(11)
    assert fires(11) != fires(12)  # astronomically unlikely to collide


# ------------------------------------------------ annex tmp-leak sweeping
def test_crash_mid_ingest_leaks_tmp_and_open_sweeps_it(tmp_path):
    plan = FaultPlan(
        seed=1,
        rules=[FaultRule(op="rename", path="annex/objects", error="crash", nth=1)],
    )
    root = str(tmp_path / "proj")
    os.makedirs(root)
    s = repro.open(root, create=True, faults=plan, annex_threshold=64)
    write(root, "big.dat", "z" * 500)
    with pytest.raises(CrashInjected):
        s.save(paths=["big.dat"], message="ingest")
    annex_root = os.path.join(root, ".repro", "annex", "objects")
    leaked = [n for n in os.listdir(annex_root) if n.startswith("tmp-")]
    assert leaked, "the dead process must not have cleaned up its tmp"
    # reboot: opening the store sweeps dead-owner tmps (pid+token proof,
    # no age wait needed)
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    assert not [
        n for n in os.listdir(annex_root) if n.startswith("tmp-")
    ]
    assert s2.verify()["divergence"] == 0
    # the interrupted save replays cleanly
    s2.save(paths=["big.dat"], message="ingest again")
    assert s2.verify()["divergence"] == 0


def test_live_owner_tmps_survive_sweep(tmp_path):
    root = str(tmp_path / "proj")
    os.makedirs(root)
    s = repro.open(root, create=True)
    annex_root = os.path.join(root, ".repro", "annex", "objects")
    os.makedirs(annex_root, exist_ok=True)
    live = os.path.join(
        annex_root, f"tmp-{os.getpid()}-{s.repo.fs.token}-abc123"
    )
    write(root, os.path.relpath(live, root), "inflight")
    # even a forced sweep never removes a tmp whose owner is alive
    assert s.repo.annex.sweep_stale_tmps(max_age_s=None) == 0
    assert os.path.exists(live)


# ----------------------------------------------------------- stale locks
def test_stale_repack_lock_is_broken(tmp_path):
    root = str(tmp_path / "proj")
    os.makedirs(root)
    s = repro.open(root, create=True)
    for i in range(3):
        write(root, f"f{i}.txt", f"c{i}")
        s.save(paths=[f"f{i}.txt"], message=f"c{i}")
    lock_path = os.path.join(root, ".repro", "locks", "repack.lock")
    os.makedirs(os.path.dirname(lock_path), exist_ok=True)
    # a lock stamped by a dead incarnation of this very process: the pid is
    # alive, but the token was never registered -> provably dead owner
    with open(lock_path, "w") as f:
        json.dump({
            "pid": os.getpid(), "token": "dead0incarnat",
            "host": "here", "heartbeat": time.time(),
        }, f)
    stats = s.repo.objects.repack()  # acquire auto-breaks the stale lock
    assert stats["objects_packed"] >= 1
    assert not os.path.exists(lock_path)


def test_live_lock_blocks_and_stale_token_logic(tmp_path):
    fs = FS(NULL_FS)
    path = str(tmp_path / "x.lock")
    lock = FileLock(fs, path)
    lock.acquire()
    with pytest.raises(LockHeld):
        FileLock(fs, path).acquire(wait_s=0.1, poll_s=0.01)
    lock.release()
    FileLock(fs, path).acquire(wait_s=0.1).release()
    # owner_is_dead: live foreign pids are never declared dead; a dead
    # token of our own pid is
    assert not owner_is_dead(os.getpid(), new_token())
    assert owner_is_dead(os.getpid(), "neverregister")
    assert owner_is_dead(2 ** 22 + 12345, None) in (True, False)  # pid probe


# ------------------------------------------------- slurm-side satellites
def test_scancel_is_idempotent(tmp_path):
    root, s, specs = setup_session(tmp_path, n_jobs=1)
    (jid,) = s.submit_many(specs)
    s.wait()
    slurm_id = s.scheduler.db.get(jid)["slurm_id"]
    # cancelling a completed job is a no-op that reports COMPLETED — twice
    assert s.cluster.scancel(slurm_id) == S.COMPLETED
    assert s.cluster.scancel(slurm_id) == S.COMPLETED
    assert s.cluster.sacct(slurm_id) == S.COMPLETED
    # unknown ids are a no-op, not an error
    assert s.cluster.scancel(999_999_999) is None
    res = s.finish()
    assert [r.state for r in res] == [S.COMPLETED]
    s.close()


def test_reschedule_straggler_completed_race(tmp_path):
    root, s, specs = setup_session(tmp_path, n_jobs=1)
    (jid,) = s.submit_many(specs)
    s.wait()  # the "straggler" completed before the cancel lands
    assert s.scheduler.reschedule_straggler(jid) is None
    row = s.scheduler.db.get(jid)
    assert row["status"] == "scheduled"  # left open for a normal finish
    res = s.finish()
    assert [(r.job_id, r.state) for r in res] == [(jid, S.COMPLETED)]
    assert slurm_record_counts(s.repo)[row["slurm_id"]] == 1
    s.close()


def test_submit_many_mid_batch_sbatch_failure(tmp_path):
    plan = FaultPlan(seed=2, rules=[FaultRule(op="sbatch", nth=2)])
    root, s, specs = setup_session(tmp_path, plan, n_jobs=3)
    with pytest.raises(InjectedSlurmError):
        s.submit_many(specs)
    rows = sorted(s.scheduler.db.all_jobs(), key=lambda r: r["job_id"])
    assert rows[0]["status"] == "scheduled" and rows[0]["slurm_id"] is not None
    assert [r["status"] for r in rows[1:]] == ["submit-failed"] * 2
    assert s.scheduler.db.orphan_protection() == []
    assert list_journals(s.repo.fs, s.repo.repro_dir) == []
    # the survivor finishes normally
    s.wait([rows[0]["job_id"]])
    res = s.finish()
    assert [(r.job_id, r.state) for r in res] == [(rows[0]["job_id"], S.COMPLETED)]
    assert s.verify()["divergence"] == 0
    s.close()


def test_finish_close_failed_jobs_after_submit_crash(tmp_path):
    """The documented recovery path for rows whose slurm id was never
    persisted: finish reports UNKNOWN, close_failed_jobs closes them and
    releases their output protection so resubmission works."""
    plan = FaultPlan(seed=2, crash_at={"submit:jobs-added": 1})
    root, s, specs = setup_session(tmp_path, plan, n_jobs=2)
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.submit_many(specs)
    s2 = reboot(root, cluster)
    res = s2.finish()  # reports, closes nothing
    assert {r.state for r in res} == {"UNKNOWN"}
    assert all(
        r["status"] == "scheduled" for r in s2.scheduler.db.all_jobs()
    )
    res = s2.finish(close_failed_jobs=True)
    assert {r.state for r in res} == {"UNKNOWN"}
    assert all(
        r["status"] == "closed-unsubmitted" for r in s2.scheduler.db.all_jobs()
    )
    # protection released: the same outputs can be scheduled again
    job_ids = s2.submit_many(specs)
    s2.wait(job_ids)
    s2.finish()
    assert_consistent(s2, job_ids)
    cluster.shutdown()


def test_injected_node_fail_keeps_job_protected(tmp_path):
    plan = FaultPlan(seed=4, rules=[FaultRule(op="task", error=S.NODE_FAIL, nth=1)])
    root, s, specs = setup_session(tmp_path, plan, n_jobs=2)
    job_ids = s.submit_many(specs)
    s.wait(job_ids)
    res = {r.job_id: r for r in s.finish()}
    states = sorted(r.state for r in res.values())
    assert states == [S.COMPLETED, S.NODE_FAIL]
    failed = next(j for j, r in res.items() if r.state == S.NODE_FAIL)
    assert res[failed].commit is None
    assert s.scheduler.db.get(failed)["status"] == "scheduled"  # protected
    res2 = {r.job_id: r for r in s.finish(close_failed_jobs=True)}
    assert s.scheduler.db.get(failed)["status"] == f"closed-{S.NODE_FAIL.lower()}"
    assert s.verify()["divergence"] == 0
    s.close()


# ------------------------------------------------------- verify() repairs
def test_verify_detects_and_repairs_orphans(tmp_path):
    root, s, specs = setup_session(tmp_path, n_jobs=1)
    db = s.scheduler.db
    job_ids = s.submit_many(specs)
    s.wait(job_ids)
    s.finish()
    # manufacture divergence: an open row with no slurm id
    orphan = db.add_jobs([repro.RunSpec(script="j0.sh", outputs=["other.out"])])[0]
    rep = s.verify()
    kinds = {i["kind"] for i in rep["issues"]}
    assert "orphan-job" in kinds and rep["divergence"] >= 1
    rep = s.verify(repair=True)
    assert rep["divergence"] == 0
    assert db.get(orphan)["status"] == "closed-unsubmitted"
    assert s.verify()["divergence"] == 0
    s.close()


# --------------------------------------------- remote transfer crash matrix
# §13 transfer boundaries: push points fire in a clean chunked push, pull
# points in a clean pull after the local copies are force-dropped.
REMOTE_PUSH_POINTS = [
    "remote:push-journal-written",
    "remote:push-mid-object",
    "remote:push-before-manifest",
    "remote:push-after-key",
    "remote:push-done",
]
REMOTE_PULL_POINTS = [
    "remote:pull-journal-written",
    "remote:pull-mid-object",
    "remote:pull-after-key",
    "remote:pull-done",
]


def remote_env(tmp_path, plan=None):
    """A chunk-enabled repo with one remote, one chunked file and one small
    whole object saved at HEAD."""
    from repro.core.chunks import ChunkParams

    root = str(tmp_path / "proj")
    os.makedirs(root, exist_ok=True)
    s = repro.open(
        root, create=True, faults=plan, annex_threshold=64,
        chunk_threshold=1 << 12,
        chunk_params=ChunkParams(min_size=1 << 9, avg_bits=10,
                                 max_size=1 << 13),
    )
    rng = __import__("random").Random(42)
    with open(os.path.join(root, "big.dat"), "wb") as f:
        f.write(bytes(rng.randrange(256) for _ in range(1 << 15)))
    write(root, "small.dat", "s" * 200)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA", net="lan")
    return root, s


def head_keys_with_chunks(repo):
    """Every HEAD annex key plus every chunk its local manifest names."""
    from repro.core.remote import head_annex_keys

    keys = set(head_annex_keys(repo))
    for k in list(keys):
        keys.update(repo.annex.manifest_of(k) or [])
    return keys


def assert_remote_converged(s2):
    """Zero divergence, remote holds every HEAD key + chunk, no pending
    journal, and a second recover() finds nothing to do."""
    assert s2.verify()["divergence"] == 0
    store = s2.repo.remote_by_name("siteA")
    wanted = head_keys_with_chunks(s2.repo)
    assert store.has_many(wanted, fresh=True) == wanted
    assert list_journals(s2.repo.fs, s2.repo.repro_dir) == []
    rep2 = s2.recover()
    assert rep2["journals_replayed"] == 0
    assert rep2["pushes_resumed"] == 0 and rep2["pulls_resumed"] == 0


@pytest.mark.parametrize("point", REMOTE_PUSH_POINTS)
def test_remote_push_crash_matrix(tmp_path, point):
    """Kill the client at every push boundary: recovery resumes the journal
    and converges to zero divergence — the remote ends with exactly the
    HEAD content, no duplicate and no lost chunk."""
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s = remote_env(tmp_path, plan)
    with pytest.raises(CrashInjected):
        s.push()
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    rep = s2.recover()
    if point != "remote:push-done":
        assert rep["pushes_resumed"] == 1
    assert_remote_converged(s2)


@pytest.mark.parametrize("point", REMOTE_PULL_POINTS)
def test_remote_pull_crash_matrix(tmp_path, point):
    """Kill the client at every pull boundary (cold-restore scenario: local
    copies dropped, content only on the remote): recovery completes the
    pull and the local annex converges to HEAD truth."""
    root, s = remote_env(tmp_path)  # clean push first
    s.push()
    s.drop("big.dat", force=True)
    s.drop("small.dat", force=True)
    s.gc()  # sweep the dropped key's now-orphan chunks: a real cold pull
    s.close()
    plan = FaultPlan(seed=7, crash_at={point: 1})
    s1 = Session(Repository(root, fs=FS(NULL_FS, faults=plan)))
    with pytest.raises(CrashInjected):
        s1.pull()
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    rep = s2.recover()
    if point != "remote:pull-done":
        assert rep["pulls_resumed"] == 1
    assert_remote_converged(s2)
    # every HEAD key is local again and reads back verified
    for k in set(head_keys_with_chunks(s2.repo)):
        assert s2.repo.annex.has(k, fresh=True)
    s2.repo.annex_get("small.dat")
    with open(os.path.join(root, "small.dat")) as f:
        assert f.read() == "s" * 200


def test_remote_crash_points_recorded(tmp_path):
    """A clean push + drop + pull passes every remote:* boundary — the two
    matrices above cannot silently rot."""
    plan = FaultPlan(seed=0, record_points=True)
    root, s = remote_env(tmp_path, plan)
    s.push()
    s.drop("big.dat", force=True)
    s.gc()  # sweep orphan chunks so the pull transfers, not just re-binds
    s.pull()
    log = set(plan.crash_point_log)
    for point in REMOTE_PUSH_POINTS + REMOTE_PULL_POINTS:
        assert point in log, f"{point} never passed in a clean push+pull"
    s.close()


def test_resumed_push_resends_only_missing_chunks(tmp_path):
    """The exactly-once byte property: a push killed mid-object re-sends,
    on resume, strictly less than a cold push — the chunks that landed
    before the crash never move again."""
    # cold baseline: same content, fresh remote
    root_c, s_c = remote_env(tmp_path / "cold")
    cold_bytes = s_c.push()[0]["bytes_sent"]
    s_c.close()

    plan = FaultPlan(seed=7, crash_at={"remote:push-mid-object": 1})
    root, s = remote_env(tmp_path / "crash", plan)
    with pytest.raises(CrashInjected):
        s.push()
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    store = s2.repo.remote_by_name("siteA")
    b0 = store.bytes_sent
    rep = s2.recover()
    assert rep["pushes_resumed"] == 1
    resumed_bytes = store.bytes_sent - b0
    assert 0 < resumed_bytes < cold_bytes
    assert_remote_converged(s2)


# --------------------------------------------- §14 pipeline DAG crash matrix
# dag:* bracket the level-by-level pipeline submission: a journal is written
# before anything reaches the DB, each level crosses submit -> deps-recorded
# -> journaled, and before-done retires the journal. Recovery resubmits
# exactly the levels the crash prevented.
DAG_POINTS = [
    ("dag:journal-written", 2),   # nothing landed: both levels resubmit
    ("dag:level-submitted", 1),   # level 0 landed, level 1 resubmits
    ("dag:deps-recorded", 1),
    ("dag:level-journaled", 1),
    ("dag:before-done", 0),       # everything landed: pure journal retire
]


def dag_pipeline(root):
    from repro.core import Pipeline

    write(root, "a.sh", "#!/bin/bash\nprintf 'a%.0s' {1..200} > a.out\n")
    write(root, "b.sh", "#!/bin/bash\ncat a.out a.out > b.out\n")
    return Pipeline({
        "a": repro.RunSpec(script="a.sh", outputs=["a.out"]),
        "b": repro.RunSpec(
            script="b.sh", inputs=["a.out"], outputs=["b.out"]
        ),
    })


@pytest.mark.parametrize("point,resubmit", DAG_POINTS)
def test_dag_crash_matrix(tmp_path, point, resubmit):
    """Kill the client at every dag:* boundary of a 2-level pipeline
    submission: recovery resumes the campaign from the journal, resubmits
    only the missing levels, and the finished campaign is byte-identical
    to an uncrashed one (zero divergence, every stage finished once)."""
    plan = FaultPlan(seed=7, crash_at={point: 1})
    root, s, _ = setup_session(tmp_path, plan, n_jobs=0)
    pipeline = dag_pipeline(root)
    cluster = s.cluster
    with pytest.raises(CrashInjected):
        s.scheduler.submit_pipeline(pipeline)
    s2 = reboot(root, cluster)
    rep = s2.recover()
    assert rep["dag_pipelines_resumed"] == 1
    assert rep["dag_levels_resubmitted"] == resubmit
    rows = {
        r["stage"]: r for r in s2.scheduler.db.all_jobs() if r["stage"]
    }
    assert set(rows) == {"a", "b"}
    open_ids = [
        r["job_id"] for r in rows.values() if r["status"] == "scheduled"
    ]
    s2.wait(open_ids)
    s2.finish()
    assert_consistent(s2, [r["job_id"] for r in rows.values()])
    # the afterok edge survived (or was re-recorded) across the crash
    parents = s2.scheduler.db.parents_of(rows["b"]["job_id"])
    assert [p["job_id"] for p in parents] == [rows["a"]["job_id"]]
    # recovery is idempotent: the journal is retired
    rep2 = s2.recover()
    assert rep2["journals_replayed"] == 0
    assert rep2["dag_pipelines_resumed"] == 0
    cluster.shutdown()


def test_dag_crash_points_recorded(tmp_path):
    """A clean pipeline campaign passes every DAG_POINTS boundary — guards
    against the matrix list and the submission path drifting apart."""
    plan = FaultPlan(seed=0, record_points=True)
    root, s, _ = setup_session(tmp_path, plan, n_jobs=0)
    s.run_pipeline(dag_pipeline(root))
    s.close()
    log = set(plan.crash_point_log)
    for point, _ in DAG_POINTS:
        assert point in log, f"{point} never passed in a clean campaign"
