"""Pack layer (DESIGN.md §8): loose/packed equivalence, crash safety through
repack, auto-repack in finish, batched sacct polling, and the blob/annex
cost-model satellites."""
import os
import random

import pytest

import repro
from repro.core.annex import AnnexStore
from repro.core.fsio import FS, GPFS, NULL_FS, SimClock
from repro.core.objects import ObjectStore
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.slurm import COMPLETED, LocalSlurmCluster
from repro.core.spec import RunSpec


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(p, mode) as f:
        f.write(data)


def all_oids(repo):
    """Every reachable object oid: commits, trees, blobs."""
    oids = set()
    for branch in repo.branches():
        for commit_oid, commit in repo.log(repo.branch_head(branch)):
            oids.add(commit_oid)

            def walk(tree_oid):
                oids.add(tree_oid)
                for entry in repo.objects.get_tree(tree_oid).values():
                    if entry["t"] == "tree":
                        walk(entry["oid"])
                    elif entry["t"] == "blob":
                        oids.add(entry["oid"])

            if commit["tree"]:
                walk(commit["tree"])
    return oids


def loose_files(store):
    out = []
    for d in sorted(os.listdir(store.root)):
        p = os.path.join(store.root, d)
        if d != "pack" and os.path.isdir(p):
            out += [os.path.join(p, f) for f in sorted(os.listdir(p))]
    return out


@pytest.fixture
def repo(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=4096)
    write(repo.root, "a.txt", "alpha")
    write(repo.root, "dir/b.txt", "beta")
    write(repo.root, "dir/sub/c.txt", "gamma")
    write(repo.root, "big.bin", b"\x07" * 8192)  # annexed
    repo.save(message="first")
    write(repo.root, "dir/b.txt", "beta 2")
    repo.save(message="second")
    return repo


# ------------------------------------------------------- equivalence property
def test_repack_preserves_every_object_byte_identically(repo):
    oids = all_oids(repo)
    before = {oid: repo.objects.get(oid) for oid in oids}
    stats = repo.objects.repack()
    assert stats["objects_packed"] == len(oids)
    assert loose_files(repo.objects) == []
    # same store instance
    for oid in oids:
        assert repo.objects.has(oid)
        assert repo.objects.get(oid) == before[oid]
    # fresh instance (new process): only the pack index serves reads
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    for oid in oids:
        assert repo2.objects.has(oid)
        assert repo2.objects.get(oid) == before[oid]


def test_repack_equivalence_property_randomized(tmp_path):
    """Property test over random edit/save/repack interleavings: get/has/
    resolve answers are identical before and after any repack()."""
    rng = random.Random(1234)
    repo = Repository.init(str(tmp_path / "repo"))
    commits = []
    for round_no in range(6):
        for _ in range(rng.randint(1, 4)):
            rel = f"d{rng.randint(0, 2)}/f{rng.randint(0, 5)}.txt"
            write(repo.root, rel, f"payload {rng.random()}")
        commits.append(repo.save(message=f"round {round_no}"))
        snapshot = {oid: repo.objects.get(oid) for oid in all_oids(repo)}
        if rng.random() < 0.5:
            repo.objects.repack()
        assert {oid: repo.objects.get(oid) for oid in all_oids(repo)} == snapshot
        for c in commits:
            assert repo.resolve(c[:10]) == c
    # final full compaction, checked from a fresh instance
    final = {oid: repo.objects.get(oid) for oid in all_oids(repo)}
    repo.objects.repack()
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert {oid: repo2.objects.get(oid) for oid in all_oids(repo2)} == final
    for c in commits:
        assert repo2.resolve(c[:10]) == c


def test_resolve_prefix_consults_pack_index(repo):
    head = repo.head_commit()
    assert repo.resolve(head[:8]) == head
    repo.objects.repack()
    # the shard file is gone; only the in-memory pack index can answer
    assert not os.path.exists(repo.objects._path(head))
    assert repo.resolve(head[:8]) == head
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert repo2.resolve(head[:8]) == head
    with pytest.raises(ValueError):
        repo2.resolve("ff")  # too short for a prefix search
    with pytest.raises(ValueError):
        repo2.resolve("0000")  # no match


def test_checkout_from_pack(repo, tmp_path):
    head = repo.head_commit()
    repo.objects.repack()
    for rel in ("a.txt", "dir/b.txt", "dir/sub/c.txt"):
        os.unlink(os.path.join(repo.root, rel))
    repo.checkout(head)
    with open(os.path.join(repo.root, "dir/b.txt")) as f:
        assert f.read() == "beta 2"


def test_put_after_repack_writes_no_loose_duplicate(repo):
    repo.objects.repack()
    repo2 = Repository(repo.root, fs=FS(NULL_FS))  # cold known-oid set
    oid = repo2.objects.put_blob(b"alpha")  # content already packed
    assert repo2.objects.get_blob(oid) == b"alpha"
    assert not os.path.exists(repo2.objects._path(oid))
    assert loose_files(repo2.objects) == []


def test_consolidation_bounds_the_pack_directory(tmp_path):
    """Pack count (and so the pack dir's entry count) stays bounded across
    arbitrarily many repacks — the flat-forever claim's second half."""
    repo = Repository.init(str(tmp_path / "repo"))
    commits = []
    for i in range(8):
        write(repo.root, f"f{i}.txt", f"round {i}")
        commits.append(repo.save(message=f"round {i}"))
        repo.objects.repack(max_packs=3)
    pack_dir = os.path.join(repo.objects.root, "pack")
    packs_on_disk = [f for f in os.listdir(pack_dir) if f.endswith(".pack")]
    assert len(packs_on_disk) <= 3
    assert loose_files(repo.objects) == []
    # nothing lost through the folds: fresh instance reads all of history
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    for i, c in enumerate(commits):
        assert repo2.resolve(c[:10]) == c
        assert repo2.objects.get_blob(
            repo2.tree_of(c)[f"f{i}.txt"]["oid"]
        ) == f"round {i}".encode()


# ------------------------------------------------------------- crash safety
def test_crash_between_pack_publish_and_unlink_loses_nothing(repo):
    oids = all_oids(repo)
    before = {oid: repo.objects.get(oid) for oid in oids}
    # the post-crash state: pack + index published, loose copies never removed
    repo.objects.repack(delete_loose=False)
    assert len(loose_files(repo.objects)) == len(oids)  # duplicates, not loss
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert {oid: repo2.objects.get(oid) for oid in oids} == before
    # the next repack sweeps the duplicates without writing a second copy
    stats = repo2.objects.repack()
    assert stats["objects_packed"] == 0
    assert stats["loose_unlinked"] == len(oids)
    assert loose_files(repo2.objects) == []
    repo3 = Repository(repo.root, fs=FS(NULL_FS))
    assert {oid: repo3.objects.get(oid) for oid in oids} == before


def test_crash_mid_unlink_storm_loses_nothing(repo, monkeypatch):
    oids = all_oids(repo)
    before = {oid: repo.objects.get(oid) for oid in oids}
    real_unlink = FS.unlink
    calls = {"n": 0}

    def dying_unlink(self, path):
        calls["n"] += 1
        if calls["n"] > 3:
            raise RuntimeError("simulated crash mid-repack")
        real_unlink(self, path)

    monkeypatch.setattr(FS, "unlink", dying_unlink)
    with pytest.raises(RuntimeError):
        repo.objects.repack()
    monkeypatch.setattr(FS, "unlink", real_unlink)
    # some loose files gone, some left — but the pack was published first,
    # so a fresh process sees every object
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert {oid: repo2.objects.get(oid) for oid in oids} == before
    repo2.objects.repack()
    assert loose_files(repo2.objects) == []


def test_crash_before_index_publish_leaves_loose_untouched(repo, monkeypatch):
    oids = all_oids(repo)
    before = {oid: repo.objects.get(oid) for oid in oids}
    n_loose = len(loose_files(repo.objects))

    def dying_rename(self, src, dst):
        raise RuntimeError("simulated crash before index publish")

    monkeypatch.setattr(FS, "rename", dying_rename)
    with pytest.raises(RuntimeError):
        repo.objects.repack()
    monkeypatch.undo()
    # no index published -> nothing was unlinked; the stray .pack is garbage
    assert len(loose_files(repo.objects)) == n_loose
    pack_dir = os.path.join(repo.objects.root, "pack")
    assert not any(f.endswith(".idx") for f in os.listdir(pack_dir))
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert {oid: repo2.objects.get(oid) for oid in oids} == before
    repo2.objects.repack()  # retry succeeds
    assert loose_files(repo2.objects) == []
    assert {oid: repo2.objects.get(oid) for oid in oids} == before


def test_get_retries_through_pack_index_after_external_repack(repo):
    """A reader whose pack index predates another process's repack must not
    see FileNotFoundError for an object that moved into a pack."""
    head = repo.head_commit()
    reader = ObjectStore(repo.objects.root, FS(NULL_FS))
    assert reader.has(head)  # loads the (still empty) pack index
    repo.objects.repack()  # "another process" compacts + unlinks
    assert reader.get(head) == repo.objects.get(head)  # stale index -> retry
    with pytest.raises(FileNotFoundError):
        reader.get("f" * 64)  # truly absent objects still raise


def test_get_retries_after_external_consolidation(repo):
    """A reader's stale index may point at a pack another process folded
    away — the retry must land in the consolidated pack, not crash."""
    repo.objects.repack()  # pack A
    head = repo.head_commit()
    reader = ObjectStore(repo.objects.root, FS(NULL_FS))
    expected = reader.get(head)  # index now pins pack A
    write(repo.root, "later.txt", "post-pack change")
    repo.save(message="later")
    repo.objects.repack(max_packs=1)  # folds A into a new pack, drops A
    assert reader.get(head) == expected  # stale pack path -> reload -> hit


def test_reload_prunes_packs_dropped_by_external_consolidation(repo):
    """A force reload mirrors disk exactly — packs another process folded
    away vanish from the index, so the next local repack can't stat or
    fold ghosts."""
    repo.objects.repack()  # pack A
    head = repo.head_commit()
    reader = ObjectStore(repo.objects.root, FS(NULL_FS))
    reader.get(head)  # index now knows pack A
    write(repo.root, "extra.txt", "more history")
    repo.save(message="extra")
    repo.objects.repack(max_packs=1)  # folds A into a new pack, drops A
    reader.packs.load(reader.fs, force=True)
    assert set(reader.packs.pack_ids(reader.fs)) == set(
        repo.objects.packs.pack_ids(repo.fs)
    )
    reader.repack()  # must not crash on ghost pack sizes
    assert reader.get(head) == repo.objects.get(head)


def test_repack_sweeps_aged_crash_garbage_only(repo):
    import time as _time

    pack_dir = os.path.join(repo.objects.root, "pack")
    os.makedirs(pack_dir, exist_ok=True)
    write(repo.objects.root, "pack/incoming-999-dead.tmp", b"half a pack")
    write(repo.objects.root, "pack/pack-deadbeef.pack", b"unindexed data")
    old = _time.time() - 172800  # 2 days: well past the in-flight age gate
    for n in ("incoming-999-dead.tmp", "pack-deadbeef.pack"):
        os.utime(os.path.join(pack_dir, n), (old, old))
    # a FRESH unindexed data file may be another process's in-flight pack
    # in its rename-before-index-publish window: it must survive the sweep
    write(repo.objects.root, "pack/pack-0fresh0.pack", b"in-flight data")
    stats = repo.objects.repack()
    assert stats["garbage_swept"] == 2
    on_disk = os.listdir(pack_dir)
    assert "incoming-999-dead.tmp" not in on_disk
    assert "pack-deadbeef.pack" not in on_disk
    assert "pack-0fresh0.pack" in on_disk
    assert repo.objects.get_commit(repo.head_commit())  # store still intact


# --------------------------------------------------- auto-repack + pressure
def test_finish_triggers_threshold_auto_repack(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"))
    cluster = LocalSlurmCluster(max_workers=2, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster, cli_startup_s=0.0,
                           auto_repack_threshold=0)
    write(repo.root, "job/run.sh", "echo out > r.txt\n")
    repo.save(message="script")
    sched.submit(RunSpec(script="run.sh", outputs=["job/r.txt"], pwd="job"))
    cluster.wait(timeout=60)
    res = sched.finish()
    cluster.shutdown()
    assert res and res[0].state == COMPLETED and res[0].commit
    # the finish batch exceeded the (zero) threshold -> everything packed
    assert loose_files(repo.objects) == []
    assert repo.objects.packs.n_packed(repo.fs) > 0
    repo2 = Repository(repo.root, fs=FS(NULL_FS))
    assert repo2.tree_of(res[0].commit)["job/r.txt"]["t"] == "blob"


def test_auto_repack_disabled_by_default(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"))
    cluster = LocalSlurmCluster(max_workers=2, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster, cli_startup_s=0.0)
    write(repo.root, "job/run.sh", "echo out > r.txt\n")
    repo.save(message="script")
    sched.submit(RunSpec(script="run.sh", outputs=["job/r.txt"], pwd="job"))
    cluster.wait(timeout=60)
    sched.finish()
    cluster.shutdown()
    assert loose_files(repo.objects) != []  # nothing was compacted


def test_session_gc_and_default_threshold(tmp_path):
    with repro.open(str(tmp_path / "repo"), create=True, profile=GPFS) as s:
        # GPFS has a degradation threshold -> sessions arm auto-repack
        assert s.auto_repack_threshold == GPFS.degrade_threshold
        write(s.repo.root, "x.txt", "hello")
        s.save(message="x")
        stats = s.gc()
        assert stats["objects_packed"] > 0
        assert loose_files(s.repo.objects) == []
    with repro.open(str(tmp_path / "repo2"), create=True) as s:
        assert s.auto_repack_threshold is None  # NULL_FS never degrades


def test_phantom_entry_purge_charges_the_storm(tmp_path):
    fs = FS(GPFS, SimClock())
    shard = str(tmp_path / "objects" / "aa")
    fs.preload_dir_entries(shard, 500)
    t0, ops0 = fs.clock.snapshot(), fs.clock.meta_ops
    purged = fs.purge_phantom_entries(shard)
    assert purged == 500
    assert fs.clock.meta_ops - ops0 == 500
    # 500 unlinks at base cost + the degradation sum for entries 193..500
    expected = 500 * GPFS.meta_op_s + GPFS.dir_degrade * sum(
        k - GPFS.degrade_threshold for k in range(GPFS.degrade_threshold + 1, 501)
    )
    assert fs.clock.snapshot() - t0 == pytest.approx(expected)
    assert fs.dir_entry_count(shard) == 0
    assert fs.purge_phantom_entries(shard) == 0  # idempotent


def test_repack_drops_modeled_shard_pressure(tmp_path):
    clock = SimClock()
    repo = Repository.init(str(tmp_path / "repo"), profile=GPFS, clock=clock)
    write(repo.root, "f.txt", "content")
    repo.save(message="f")
    shard = os.path.join(repo.objects.root, "00")
    repo.fs.preload_dir_entries(shard, 1000)
    assert repo.objects.loose_pressure() >= 1000
    repo.objects.repack()
    assert repo.objects.loose_pressure() <= GPFS.degrade_threshold


# ------------------------------------------------------- satellite: sacct
def test_sacct_many_charges_one_poll(tmp_path):
    clock = SimClock()
    cluster = LocalSlurmCluster(max_workers=2, clock=clock, sacct_cost_s=0.02)
    write(str(tmp_path), "run.sh", "true\n")
    ids = [cluster.sbatch("run.sh", workdir=str(tmp_path)) for _ in range(5)]
    cluster.wait(timeout=60)
    t0 = clock.snapshot()
    states = cluster.sacct_many(ids)
    assert clock.snapshot() - t0 == pytest.approx(0.02)  # ONE charge for 5 jobs
    assert states == {j: COMPLETED for j in ids}
    assert cluster.sacct_many([]) == {}
    assert clock.snapshot() - t0 == pytest.approx(0.02)  # empty poll is free
    t1 = clock.snapshot()
    for j in ids:
        assert cluster.sacct(j) == states[j]
    assert clock.snapshot() - t1 == pytest.approx(5 * 0.02)  # per-job: 5 charges
    cluster.shutdown()


def test_scheduler_polls_are_batched(tmp_path, monkeypatch):
    repo = Repository.init(str(tmp_path / "repo"))
    cluster = LocalSlurmCluster(max_workers=2, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster, cli_startup_s=0.0)
    specs = []
    for j in range(3):
        write(repo.root, f"job{j}/run.sh", "echo out > r.txt\n")
        specs.append(RunSpec(script="run.sh", outputs=[f"job{j}/r.txt"],
                             pwd=f"job{j}"))
    repo.save(message="scripts")
    sched.submit_many(specs)
    cluster.wait(timeout=60)
    per_job_calls = {"n": 0}
    monkeypatch.setattr(
        cluster, "sacct",
        lambda jid: per_job_calls.__setitem__("n", per_job_calls["n"] + 1),
    )
    assert len(sched.list_open_jobs()) == 3
    sched.find_stragglers()
    res = sched.finish()
    cluster.shutdown()
    assert len(res) == 3 and all(r.commit for r in res)
    assert per_job_calls["n"] == 0  # every poll went through sacct_many


# ------------------------------------------------- satellite: blob cache
def test_put_blob_primes_read_cache(tmp_path):
    clock = SimClock()
    store = ObjectStore(str(tmp_path / "objects"), FS(GPFS, clock))
    oid = store.put_blob(b"fresh blob payload")
    ops = clock.meta_ops
    assert store.get_blob(oid) == b"fresh blob payload"
    assert clock.meta_ops == ops  # served from the cache primed by put_blob
    # a cold read populates the cache too
    store2 = ObjectStore(str(tmp_path / "objects"), FS(GPFS, clock))
    store2.get_blob(oid)
    ops = clock.meta_ops
    store2.get_blob(oid)
    assert clock.meta_ops == ops


def test_blob_cache_disabled_and_bounded(tmp_path):
    clock = SimClock()
    store = ObjectStore(str(tmp_path / "objects"), FS(GPFS, clock))
    oid = store.put_blob(b"payload")
    store.disable_caches()
    ops = clock.meta_ops
    assert store.get_blob(oid) == b"payload"
    assert clock.meta_ops > ops  # escape hatch: every read hits the FS

    small = ObjectStore(str(tmp_path / "objects2"), FS(NULL_FS),
                        blob_cache_bytes=64)
    oids = [small.put_blob(bytes([i]) * 32) for i in range(4)]
    assert small._blob_cache_used <= 64
    assert len(small._blob_cache) <= 2
    for oid in oids:  # eviction never breaks reads
        assert small.get_blob(oid) == small.get_blob(oid)


# ------------------------------------------------- satellite: annex keys
def test_annex_keys_goes_through_the_cost_model(tmp_path):
    clock = SimClock()
    fs = FS(GPFS, clock)
    store = AnnexStore(str(tmp_path / "annex"), fs)
    from repro.core.hashing import annex_key_for_bytes

    keys = set()
    for i in range(3):
        data = bytes([i]) * 100
        key = annex_key_for_bytes(data)
        store.put_bytes(key, data)
        keys.add(key)
    ops = clock.meta_ops
    assert set(store.keys()) == keys
    assert clock.meta_ops > ops  # enumeration is charged, not free
