"""Sharding-rule unit tests (no devices needed beyond CPU default)."""
import os
import subprocess
import sys

from jax.sharding import PartitionSpec as P


def _rules(**kw):
    """Build rules against a fake mesh-shaped object (no devices)."""
    from repro.distributed.sharding import ShardingRules

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    return ShardingRules(mesh=FakeMesh(), dp=("data",), tp="model", **kw)


def test_baseline_specs():
    r = _rules()
    assert r.w_in == P(None, "model")
    assert r.w_out == P("model", None)
    assert r.residual == P("data", "model", None)
    assert r.kv_cache(True) == P("data", None, None, "model")
    assert r.kv_cache(False) == P(None, None, None, "model")


def test_seq_kv_cache():
    r = _rules(kv_shard="seq")
    assert r.kv_cache(True) == P("data", "model", None, None)


def test_fsdp_specs():
    r = _rules(fsdp=True)
    assert r.w_in == P("data", "model")
    assert r.w_out == P("model", "data")
    assert r.embed == P("data", "model")


def test_expert_axis_modes():
    r = _rules()
    assert r.w_expert_in(128) == P("data", None, "model")  # ZeRO over data
    assert r.w_expert_in(8) == P(None, "data", "model")  # 8 doesn't divide 16
    r_ep = _rules(expert_axis="model")
    assert r_ep.w_expert_in(128) == P("model", "data" , None)
    assert r_ep.w_expert_out(128) == P("model", None, "data")
    # 8 experts can't take the 16-wide model axis either -> fallback
    assert r_ep.w_expert_in(8) == P(None, "data", "model")


def test_no_seq_shard_residual():
    r = _rules(seq_shard_residual=False)
    assert r.residual == P("data", None, None)
