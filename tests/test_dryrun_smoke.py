"""Dry-run smoke: lower+compile a representative cell subset in a subprocess
(so the 512-device XLA_FLAGS never leaks into this test process — smoke tests
must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import run_cell
cell = run_cell(sys.argv[1], sys.argv[2], multi_pod=(sys.argv[3] == "multi"))
print("CELL=" + json.dumps({k: cell[k] for k in ("status", "chips")}))
"""


@pytest.mark.parametrize(
    "arch,shape,mesh",
    [
        ("qwen3_0_6b", "train_4k", "single"),
        ("qwen3_0_6b", "decode_32k", "single"),
        ("rwkv6_1_6b", "long_500k", "single"),
        ("qwen3_0_6b", "train_4k", "multi"),
    ],
)
def test_dryrun_cell_compiles(arch, shape, mesh, tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, shape, mesh],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("CELL=")][0]
    cell = json.loads(line[5:])
    assert cell["status"] == "ok"
    assert cell["chips"] == (512 if mesh == "multi" else 256)


def test_default_process_sees_one_device():
    """XLA_FLAGS must NOT be set globally — smoke tests see 1 device."""
    import jax

    assert jax.device_count() == 1
