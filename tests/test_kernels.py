"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed with interpret=True (kernel bodies run on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import ssm as model_ssm

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(0, 1, shape), dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,dh,causal,window",
    [
        (2, 128, 128, 4, 4, 64, True, None),
        (1, 256, 256, 8, 2, 64, True, None),  # GQA 4:1
        (2, 128, 128, 4, 1, 128, True, None),  # MQA
        (1, 256, 256, 4, 4, 64, True, 64),  # sliding window
        (1, 128, 128, 2, 2, 96, False, None),  # encoder (non-causal), Dh=96
        (2, 64, 64, 4, 2, 32, True, 16),
    ],
)
def test_flash_attention_vs_ref(b, sq, sk, h, kv, dh, causal, window, dtype):
    rng = np.random.default_rng(hash((b, sq, h, kv, dh)) % 2**31)
    q = rand(rng, (b, sq, h, dh), dtype)
    k = rand(rng, (b, sk, kv, dh), dtype)
    v = rand(rng, (b, sk, kv, dh), dtype)
    got = ops.flash_attention(q, k, v, causal, window, True)
    want = ref.attention_ref(q, k, v, causal, window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_attention_block_sweep():
    """Block shape must not change the math."""
    from repro.kernels.flash_attention import flash_attention_bhsd

    rng = np.random.default_rng(0)
    q = rand(rng, (1, 2, 256, 64), jnp.float32)
    k = rand(rng, (1, 2, 256, 64), jnp.float32)
    v = rand(rng, (1, 2, 256, 64), jnp.float32)
    outs = []
    for bq, bk in [(64, 64), (128, 256), (256, 64), (256, 256)]:
        outs.append(
            np.asarray(
                flash_attention_bhsd(
                    q, k, v, causal=True, window=None,
                    block_q=bq, block_k=bk, interpret=True,
                )
            )
        )
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_flash_attention_grad_matches_ref():
    rng = np.random.default_rng(1)
    q = rand(rng, (1, 64, 2, 32), jnp.float32)
    k = rand(rng, (1, 64, 2, 32), jnp.float32)
    v = rand(rng, (1, 64, 2, 32), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(jnp.square(ops.flash_attention(q, k, v, True, None, True)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.square(ref.attention_ref(q, k, v, True, None)))

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,dh,chunk", [(2, 64, 2, 32, 16), (1, 128, 4, 64, 16),
                                            (1, 32, 1, 128, 16)])
def test_rwkv6_kernel_vs_ref(b, s, h, dh, chunk, dtype):
    rng = np.random.default_rng(42)
    r = rand(rng, (b, s, h, dh), dtype)
    k = rand(rng, (b, s, h, dh), dtype)
    v = rand(rng, (b, s, h, dh), dtype)
    logw = -jnp.abs(rand(rng, (b, s, h, dh), jnp.float32)) - 0.05
    u = rand(rng, (h, dh), jnp.float32)
    s0 = jnp.asarray(rng.normal(0, 0.3, (b, h, dh, dh)), jnp.float32)
    got, gstate = ops.rwkv6(r, k, v, logw.astype(dtype), u, s0, True)
    want, wstate = ref.rwkv6_ref(r, k, v, logw.astype(dtype), u, s0)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(gstate), np.asarray(wstate),
                               rtol=3e-3 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-3 if dtype == jnp.bfloat16 else 1e-4)


def test_rwkv6_model_chunked_vs_naive():
    """The model's jnp chunked path == the naive oracle (independent of the
    Pallas kernel)."""
    rng = np.random.default_rng(7)
    b, s, h, dh = 2, 48, 2, 16
    r, k, v = (jnp.asarray(rng.normal(0, 1, (b, s, h, dh)), jnp.float32) for _ in range(3))
    logw = -jnp.abs(jnp.asarray(rng.normal(0, 1, (b, s, h, dh)), jnp.float32)) - 0.02
    u = jnp.asarray(rng.normal(0, 1, (h, dh)), jnp.float32)
    o1, s1 = model_ssm.rwkv6_chunked(r, k, v, logw, u)
    o2, s2 = model_ssm.rwkv6_naive(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- mamba
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,di,st,chunk", [(2, 64, 64, 8, 64), (1, 128, 256, 16, 64)])
def test_mamba_kernel_vs_ref(b, s, di, st, chunk, dtype):
    rng = np.random.default_rng(3)
    u = rand(rng, (b, s, di), dtype)
    dt = jnp.abs(rand(rng, (b, s, di), dtype)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(0, 1, (di, st)), jnp.float32))
    B_ = rand(rng, (b, s, st), dtype)
    C_ = rand(rng, (b, s, st), dtype)
    h0 = jnp.asarray(rng.normal(0, 0.3, (b, di, st)), jnp.float32)
    got_y, got_h = ops.mamba_scan(u, dt, A, B_, C_, h0, True)
    want_y, want_h = ref.mamba_ref(u, dt, A, B_, C_, h0)
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-3, atol=1e-3)


def test_mamba_model_chunked_vs_naive():
    rng = np.random.default_rng(5)
    b, s, di, st = 1, 512, 32, 4
    u = jnp.asarray(rng.normal(0, 1, (b, s, di)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.normal(0, 0.1, (b, s, di)), jnp.float32))
    A = -jnp.abs(jnp.asarray(rng.normal(0, 1, (di, st)), jnp.float32))
    B_ = jnp.asarray(rng.normal(0, 1, (b, s, st)), jnp.float32)
    C_ = jnp.asarray(rng.normal(0, 1, (b, s, st)), jnp.float32)
    y1, h1 = model_ssm.mamba_scan_chunked(u, dt, A, B_, C_, chunk=256)
    y2, h2 = model_ssm.mamba_scan_naive(u, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


# --------------------------------------------------- property-based sweeps
if HAVE_HYP:

    @given(
        b=st.integers(1, 2),
        nq=st.integers(1, 3),
        heads=st.sampled_from([(2, 1), (2, 2), (4, 2)]),
        dh=st.sampled_from([32, 64]),
        causal=st.booleans(),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_flash_attention_random_shapes(b, nq, heads, dh, causal):
        h, kv = heads
        s = 64 * nq
        rng = np.random.default_rng(b * 1000 + s + h + dh)
        q = rand(rng, (b, s, h, dh), jnp.float32)
        k = rand(rng, (b, s, kv, dh), jnp.float32)
        v = rand(rng, (b, s, kv, dh), jnp.float32)
        got = ops.flash_attention(q, k, v, causal, None, True)
        want = ref.attention_ref(q, k, v, causal, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @given(
        s=st.sampled_from([16, 32, 64]),
        dh=st.sampled_from([16, 32]),
        strong_decay=st.booleans(),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_rwkv6_decay_regimes(s, dh, strong_decay):
        """Weak and strong decays must both stay finite and match the oracle
        (the fp32-range clamp argument in models/ssm.py)."""
        rng = np.random.default_rng(s + dh)
        b, h = 1, 2
        scale = 3.5 if strong_decay else 0.05
        r, k, v = (jnp.asarray(rng.normal(0, 1, (b, s, h, dh)), jnp.float32) for _ in range(3))
        logw = -jnp.abs(jnp.asarray(rng.normal(0, scale, (b, s, h, dh)), jnp.float32)) - 1e-3
        logw = jnp.maximum(logw, -model_ssm.MAX_DECAY)
        u = jnp.asarray(rng.normal(0, 1, (h, dh)), jnp.float32)
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        got, _ = ops.rwkv6(r, k, v, logw, u, s0, True)
        want, _ = ref.rwkv6_ref(r, k, v, logw, u, s0)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
