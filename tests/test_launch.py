"""Unit tests for the launch layer: HLO collective parsing, roofline math,
input-spec construction (no 512-device init — pure host-side logic)."""
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.hlo_stats import collective_stats, op_histogram
from repro.launch.roofline import analyze, model_flops
from repro.launch import specs


SAMPLE_HLO = """
HloModule jit_step
%x.1 = bf16[128,1024]{1,0} parameter(0)
%y.2 = f32[256,512]{1,0} parameter(1)
%ag.3 = bf16[2048,1024]{1,0} all-gather(%x.1), replica_groups={{0,1}}
%ar.4 = f32[256,512]{1,0} all-reduce(%y.2), to_apply=%add
%rs.5 = f32[16,512]{1,0} reduce-scatter(%y.2), dimensions={0}
%cp.6 = bf16[128,1024]{1,0} collective-permute(%x.1), source_target_pairs={{0,1}}
%ags.7 = (bf16[128,1024], bf16[2048,1024]) all-gather-start(%x.1)
%agd.8 = bf16[2048,1024]{1,0} all-gather-done(%ags.7)
"""


def test_collective_stats_operand_bytes():
    st = collective_stats(SAMPLE_HLO)
    x_bytes = 128 * 1024 * 2
    y_bytes = 256 * 512 * 4
    assert st["by_type"]["all-gather"] == 2 * x_bytes  # ag.3 + ags.7 (done skipped)
    assert st["by_type"]["all-reduce"] == y_bytes
    assert st["by_type"]["reduce-scatter"] == y_bytes
    assert st["by_type"]["collective-permute"] == x_bytes
    assert st["count"] == 5
    assert st["total_bytes"] == sum(st["by_type"].values())


def test_op_histogram():
    h = op_histogram(SAMPLE_HLO)
    assert h.get("all-gather") == 1


def test_roofline_analyze_terms_and_dominance():
    cell = {
        "arch": "x", "shape": "train_4k", "mesh": "pod16x16", "kind": "train",
        "chips": 256, "seq_len": 4096, "global_batch": 256,
        "flops_per_device": 197e12,  # exactly 1 second of compute
        "bytes_per_device": 819e9 * 2,  # 2 seconds of HBM
        "collective_bytes_per_device": 50e9 * 0.5,  # 0.5 s of ICI
        "params_active": 1e9, "params_total": 1e9,
        "memory": {"argument_bytes": 2**30, "temp_bytes": 2**30,
                   "output_bytes": 0, "alias_bytes": 0},
    }
    r = analyze(cell)
    assert r["dominant"] == "memory"
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 2.0) < 1e-9
    assert abs(r["collective_s"] - 0.5) < 1e-9
    assert r["fits_v5e_16g"]
    # 6 N D / (flops/dev * chips)
    want = 6 * 1e9 * 256 * 4096 / (197e12 * 256)
    assert abs(r["useful_compute_ratio"] - want) < 1e-9


def test_model_flops_kinds():
    base = {"params_active": 2e9, "global_batch": 32, "seq_len": 1000}
    assert model_flops({**base, "kind": "train"}) == 6 * 2e9 * 32 * 1000
    assert model_flops({**base, "kind": "prefill"}) == 2 * 2e9 * 32 * 1000
    assert model_flops({**base, "kind": "decode"}) == 2 * 2e9 * 32


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "seamless_m4t_large_v2",
                                  "qwen2_vl_7b", "jamba_1_5_large_398b"])
def test_batch_specs_cover_modalities(arch):
    cfg = configs.get(arch)
    shape = configs.SHAPES["train_4k"]
    out = specs.batch_specs(cfg, shape, mesh=None, rules=None)
    assert out["tokens"].shape == (256, 4096)
    assert out["tokens"].dtype == jnp.int32
    if cfg.enc_dec:
        assert out["encoder_embeds"].shape == (256, 1024, cfg.d_model)
    if cfg.vision_len_ratio:
        assert out["vision_embeds"].shape == (256, 512, cfg.d_model)
        assert out["positions3"].shape == (3, 256, 4096)


def test_decode_specs_cache_structure():
    cfg = configs.get("jamba_1_5_large_398b")
    shape = configs.SHAPES["decode_32k"]
    caches, token, pos = specs.decode_specs(cfg, shape, mesh=None, rules=None)
    assert token.shape == (128, 1)
    assert pos.shape == ()
    # hybrid: attention position p3 has kv cache, mamba positions have h/conv
    assert set(caches["p3"]) == {"k", "v"}
    assert caches["p3"]["k"].shape == (9, 128, 32768, 8, 128)
    assert set(caches["p0"]) == {"h", "conv"}
    assert caches["p0"]["h"].dtype == jnp.float32


def test_cell_runnable_rules():
    assert configs.cell_runnable(configs.get("internlm2_20b"),
                                 configs.SHAPES["long_500k"])[0] is False
    for a in ("mixtral_8x22b", "rwkv6_1_6b", "jamba_1_5_large_398b"):
        assert configs.cell_runnable(configs.get(a),
                                     configs.SHAPES["long_500k"])[0] is True
    assert configs.cell_runnable(configs.get("internlm2_20b"),
                                 configs.SHAPES["train_4k"])[0] is True
