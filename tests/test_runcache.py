"""Run-cache subsystem tests (DESIGN.md §11).

The contract under test: a re-submitted spec whose execution key (spec_id +
content-addressed input tree + environment fingerprint) matches a recorded
run short-circuits into a memoized provenance commit that is *bit-identical*
to executing it — same output tree entries, same worktree bytes, same
reconstructed spec_id — while never touching Slurm. Plus the index
plumbing: schema migration, fsck/repair, gc eviction, refresh bypass.
"""
import os
import random
import sqlite3

import pytest

import repro
from repro.core.jobdb import JobDB
from repro.core.records import RunRecord
from repro.core.runcache import RunCache, env_fingerprint
from repro.core.spec import RunSpec


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(p, mode) as f:
        f.write(data)


def open_session(tmp_path, name="proj", **kw):
    root = str(tmp_path / name)
    os.makedirs(root, exist_ok=True)
    return root, repro.open(root, create=True, annex_threshold=64, **kw)


def _job(root, payload: str):
    """An input file + a deterministic transform script over it."""
    write(root, "in.dat", payload)
    write(root, "job.sh", "#!/bin/bash\ncat in.dat in.dat > out.dat\n")
    return RunSpec(script="job.sh", inputs=["in.dat"], outputs=["out.dat"])


def _run_one(s, spec):
    (jid,) = s.submit_many([spec])
    s.wait([jid])
    (res,) = s.finish(job_id=jid)
    return jid, res


# ----------------------------------------------------- hit replay property
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cache_hit_replay_is_bit_identical(tmp_path, seed):
    """Seeded property: for random annexed payloads, the memoized replay
    reproduces the cold execution exactly — output tree entry, worktree
    bytes, and reconstructed spec_id — with no Slurm submission."""
    rng = random.Random(seed)
    payload = "".join(rng.choice("abcdefgh\n") for _ in range(rng.randint(200, 600)))
    root, s = open_session(tmp_path)
    spec = _job(root, payload)
    cold_id, cold = _run_one(s, spec)
    assert cold.commit
    out_path = os.path.join(root, "out.dat")
    with open(out_path, "rb") as f:
        bytes_cold = f.read()
    entry_cold = s.repo.entry_at(cold.commit, "out.dat")
    assert entry_cold["t"] == "annex"  # payload > threshold: annexed

    os.unlink(out_path)  # force the hit to re-materialize from the store
    warm_spec = _job(root, payload)  # fresh object: content addressing only
    assert warm_spec.spec_id == spec.spec_id
    (warm_id,) = s.submit_many([warm_spec])

    row = s.scheduler.db.get(warm_id)
    assert row["status"] == "memoized" and row["slurm_id"] is None
    head = s.repo.head_commit()
    assert head != cold.commit
    assert s.repo.entry_at(head, "out.dat") == entry_cold
    with open(out_path, "rb") as f:
        assert f.read() == bytes_cold
    commit = s.repo.objects.get_commit(head)
    rec = RunRecord.from_message(commit["message"])
    assert rec.memoized and rec.memoized_of == cold.commit
    assert rec.slurm_job_id is None
    assert s.spec_of(head).spec_id == spec.spec_id
    assert s.verify()["divergence"] == 0
    s.close()


def test_input_change_misses(tmp_path):
    root, s = open_session(tmp_path)
    spec = _job(root, "p" * 300)
    _run_one(s, spec)
    # same spec_id, different input content -> different execution key
    write(root, "in.dat", "q" * 300)
    (jid,) = s.submit_many([_job(root, "q" * 300)])
    row = s.scheduler.db.get(jid)
    assert row["status"] == "scheduled" and row["slurm_id"] is not None
    s.wait([jid])
    (res,) = s.finish(job_id=jid)
    assert res.commit
    with open(os.path.join(root, "out.dat")) as f:
        assert f.read() == "q" * 600
    assert s.scheduler.db.cache_count() == 2
    s.close()


def test_refresh_bypasses_the_cache(tmp_path):
    root, s = open_session(tmp_path)
    spec = _job(root, "r" * 300)
    _run_one(s, spec)
    (jid,) = s.submit_many([_job(root, "r" * 300)], refresh=True)
    row = s.scheduler.db.get(jid)
    assert row["status"] == "scheduled" and row["slurm_id"] is not None
    s.wait([jid])
    (res,) = s.finish(job_id=jid)
    assert res.commit
    s.close()


def test_run_cache_off_never_memoizes(tmp_path):
    root, s = open_session(tmp_path, run_cache=False)
    spec = _job(root, "n" * 300)
    _run_one(s, spec)
    assert s.scheduler.db.cache_count() == 0
    (jid,) = s.submit_many([_job(root, "n" * 300)])
    assert s.scheduler.db.get(jid)["slurm_id"] is not None
    s.wait([jid])
    s.finish(job_id=jid)
    assert s.scheduler.db.cache_count() == 0
    s.close()


def test_env_fingerprint_keys_the_cache(tmp_path):
    root, s = open_session(tmp_path, cache_env={"module": "gcc/12.2"})
    spec = _job(root, "e" * 300)
    _run_one(s, spec)
    s.close()
    # same repo, different declared environment -> miss
    s2 = repro.open(root, cache_env={"module": "gcc/13.1"})
    (jid,) = s2.submit_many([_job(root, "e" * 300)])
    assert s2.scheduler.db.get(jid)["slurm_id"] is not None
    s2.wait([jid])
    s2.finish(job_id=jid)
    # and back to the original environment -> hit
    s2.close()
    s3 = repro.open(root, cache_env={"module": "gcc/12.2"})
    (jid3,) = s3.submit_many([_job(root, "e" * 300)])
    assert s3.scheduler.db.get(jid3)["status"] == "memoized"
    s3.close()


def test_execution_key_properties():
    spec = RunSpec(script="j.sh", outputs=["o"], inputs=["a", "b"])
    e1 = [("a", {"t": "blob", "oid": "x"}), ("b", {"t": "blob", "oid": "y"})]
    assert spec.execution_key(e1) == spec.execution_key(list(reversed(e1)))
    e2 = [("a", {"t": "blob", "oid": "x"}), ("b", {"t": "blob", "oid": "z"})]
    assert spec.execution_key(e1) != spec.execution_key(e2)
    assert spec.execution_key(e1, "envA") != spec.execution_key(e1, "envB")
    # a different message is a different spec_id, hence a different key —
    # reschedule/straggler resubmissions deliberately MISS
    other = RunSpec(script="j.sh", outputs=["o"], inputs=["a", "b"],
                    message="retry")
    assert other.execution_key(e1) != spec.execution_key(e1)
    assert env_fingerprint(None) == "" == env_fingerprint({})
    assert env_fingerprint({"a": 1}) == env_fingerprint({"a": "1"})


# --------------------------------------------------------- schema migration
def test_migration_upgrades_a_v1_db_exactly_once(tmp_path):
    from repro.core.jobdb import _SCHEMA_V1

    repro_dir = str(tmp_path / ".repro")
    os.makedirs(repro_dir)
    db_path = os.path.join(repro_dir, "jobdb.sqlite")
    # hand-build a pre-versioning (PR 1 era) database: base schema, no
    # PRAGMA user_version, no spec column, no runcache table
    conn = sqlite3.connect(db_path)
    conn.executescript(_SCHEMA_V1)
    conn.execute(
        "INSERT INTO jobs (slurm_id, script, submitted_at)"
        " VALUES (7, 'x.sh', 0)"
    )
    conn.commit()
    conn.close()

    db = JobDB(repro_dir)
    conn = sqlite3.connect(db_path)  # noqa: the db file is shared
    assert conn.execute("PRAGMA user_version").fetchone()[0] == 5
    cols = {r[1] for r in conn.execute("PRAGMA table_info(jobs)")}
    assert {"spec", "exec_key"} <= cols
    tables = {
        r[0] for r in conn.execute(
            "SELECT name FROM sqlite_master WHERE type='table'"
        )
    }
    assert "runcache" in tables
    # the pre-migration row survived
    assert conn.execute("SELECT slurm_id FROM jobs").fetchone()[0] == 7
    conn.close()
    assert db.cache_count() == 0

    # idempotent: reopening applies nothing further
    db2 = JobDB(repro_dir)
    conn = sqlite3.connect(db_path)
    assert conn.execute("PRAGMA user_version").fetchone()[0] == 5
    conn.close()


def test_fresh_db_lands_at_current_version(tmp_path):
    repro_dir = str(tmp_path / ".repro")
    os.makedirs(repro_dir)
    JobDB(repro_dir)
    conn = sqlite3.connect(os.path.join(repro_dir, "jobdb.sqlite"))
    assert conn.execute("PRAGMA user_version").fetchone()[0] == 5
    conn.close()


# ------------------------------------------------------- fsck + gc eviction
def _fake_row(key="k" * 64, commit="c" * 64):
    return {
        "exec_key": key, "spec_id": "s" * 64, "commit_oid": commit,
        "output_tree": {"out.dat": {"t": "blob", "oid": "b" * 64}},
        "annex_keys": [],
    }


def test_verify_flags_and_repairs_broken_cache_rows(tmp_path):
    root, s = open_session(tmp_path)
    spec = _job(root, "v" * 300)
    _run_one(s, spec)
    db = s.scheduler.db
    db.cache_put([_fake_row()])  # recorded commit does not exist
    assert RunCache(s.repo, db).check()
    rep = s.verify()
    assert "broken-cache" in {i["kind"] for i in rep["issues"]}
    assert rep["divergence"] >= 1
    rep = s.verify(repair=True)
    assert rep["divergence"] == 0
    assert db.cache_count() == 1  # the genuine row survived
    assert s.verify()["divergence"] == 0
    # the genuine row still hits
    (jid,) = s.submit_many([_job(root, "v" * 300)])
    assert db.get(jid)["status"] == "memoized"
    s.close()


def test_gc_evicts_unmaterializable_rows(tmp_path):
    root, s = open_session(tmp_path)
    spec = _job(root, "g" * 300)
    _run_one(s, spec)
    db = s.scheduler.db
    db.cache_put([_fake_row()])
    stats = s.gc()
    assert stats["cache_evicted"] == 1
    assert db.cache_count() == 1
    assert s.verify()["divergence"] == 0
    s.close()


def test_gc_prune_cache_off_leaves_rows(tmp_path):
    root, s = open_session(tmp_path)
    spec = _job(root, "h" * 300)
    _run_one(s, spec)
    s.scheduler.db.cache_put([_fake_row()])
    stats = s.gc(prune_cache=False)
    assert "cache_evicted" not in stats
    assert s.scheduler.db.cache_count() == 2
    s.close()
