"""Pipeline DAG engine (DESIGN §14): edge inference, topological batching
with afterok dependencies, failure cascades, cache-aware partial replay,
and straggler rewiring under dependents."""
import os
import time
import warnings

import pytest

import repro
from repro.core import Pipeline, PipelineError
from repro.core.dag import PipelineWarning, _overlaps
from repro.core.jobdb import JobDB
from repro.core.slurm import (
    CANCELLED,
    COMPLETED,
    FAILED,
    PENDING,
    LocalSlurmCluster,
)
from repro.core.spec import RunSpec


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


def script(root, rel, body):
    write(root, rel, "#!/bin/bash\n" + body + "\n")


def make_session(tmp_path, **kw):
    root = str(tmp_path / "proj")
    os.makedirs(root, exist_ok=True)
    s = repro.open(root, create=True, **kw)
    return root, s


def three_stage(root):
    """preprocess -> train -> evaluate, scripts declared as inputs so
    editing a script invalidates its stage's cache entry."""
    script(root, "pre.sh", "mkdir -p data; printf 'clean%.0s' {1..60} > data/clean.txt")
    script(root, "train.sh", "mkdir -p model; cat data/clean.txt > model/weights.bin")
    script(root, "eval.sh", "mkdir -p report; wc -c < model/weights.bin > report/score.txt")
    return {
        "preprocess": RunSpec(
            script="pre.sh", inputs=["pre.sh"], outputs=["data/clean.txt"]
        ),
        "train": RunSpec(
            script="train.sh",
            inputs=["train.sh", "data/clean.txt"],
            outputs=["model/weights.bin"],
        ),
        "evaluate": RunSpec(
            script="eval.sh",
            inputs=["eval.sh", "model/weights.bin"],
            outputs=["report/score.txt"],
        ),
    }


# ------------------------------------------------------------ DAG structure
def test_edge_inference_and_levels():
    a = RunSpec(script="a.sh", outputs=["data/raw.txt"])
    b = RunSpec(script="b.sh", inputs=["data/raw.txt"], outputs=["data/b.txt"])
    c = RunSpec(script="c.sh", inputs=["data/raw.txt"], outputs=["data/c.txt"])
    d = RunSpec(
        script="d.sh", inputs=["data/b.txt", "data/c.txt"], outputs=["out.txt"]
    )
    p = Pipeline({"a": a, "b": b, "c": c, "d": d})
    assert p.roots() == ["a"]
    assert p.levels() == [["a"], ["b", "c"], ["d"]]
    assert ("a", "b") in p.edges() and ("c", "d") in p.edges()
    assert set(p.downstream_cone("a")) == {"a", "b", "c", "d"}
    assert set(p.downstream_cone("b")) == {"b", "d"}
    assert "data/raw.txt" in p.upstream_outputs("d")
    assert len(p) == 4


def test_wildcard_input_matches_upstream_output():
    a = RunSpec(script="a.sh", outputs=["logs/run1.json"])
    b = RunSpec(script="b.sh", inputs=["logs/*.json"], outputs=["sum.txt"])
    p = Pipeline({"a": a, "b": b})
    assert p.edges() == [("a", "b")]
    # directory-producing parent: wildcard under the produced directory
    c = RunSpec(script="c.sh", outputs=["results"])
    d = RunSpec(script="d.sh", inputs=["results/**/*.csv"], outputs=["agg.txt"])
    assert Pipeline({"c": c, "d": d}).edges() == [("c", "d")]


def test_literal_input_under_output_directory():
    a = RunSpec(script="a.sh", outputs=["data"])
    b = RunSpec(script="b.sh", inputs=["data/part/x.txt"], outputs=["y.txt"])
    assert Pipeline({"a": a, "b": b}).edges() == [("a", "b")]


def test_overlap_helper():
    assert _overlaps("data/x.txt", "data/x.txt")
    assert _overlaps("data/x.txt", "data")  # literal under output dir
    assert _overlaps("data", "data/x.txt")  # output nested under input dir
    assert _overlaps("data/*.txt", "data/x.txt")  # wildcard match
    assert _overlaps("data/**/a.csv", "data")  # static dir under output
    assert not _overlaps("data/*.txt", "other/x.txt")
    assert not _overlaps("database", "data")  # no false prefix overlap


def test_cycle_is_rejected():
    a = RunSpec(script="a.sh", inputs=["b.txt"], outputs=["a.txt"])
    b = RunSpec(script="b.sh", inputs=["a.txt"], outputs=["b.txt"])
    with pytest.raises(PipelineError, match="cycle"):
        Pipeline({"a": a, "b": b})


def test_ambiguous_producer_is_rejected():
    a = RunSpec(script="a.sh", outputs=["out.txt"])
    b = RunSpec(script="b.sh", outputs=["out.txt"])
    with pytest.raises(PipelineError):
        Pipeline({"a": a, "b": b})


def test_self_consumption_is_rejected():
    a = RunSpec(script="a.sh", inputs=["x.txt"], outputs=["x.txt"])
    with pytest.raises(PipelineError, match="own output"):
        Pipeline({"a": a})


def test_stage_validation():
    with pytest.raises(PipelineError, match="no stages"):
        Pipeline({})
    with pytest.raises(PipelineError, match="script specs"):
        Pipeline({"a": RunSpec(cmd="true")})
    with pytest.raises(PipelineError, match="duplicate"):
        Pipeline([
            ("a", RunSpec(script="a.sh", outputs=["x"])),
            ("a", RunSpec(script="b.sh", outputs=["y"])),
        ])


def test_resource_overrides():
    a = RunSpec(script="a.sh", outputs=["x.txt"])
    b = RunSpec(script="b.sh", inputs=["x.txt"], outputs=["y.txt"])
    p = Pipeline(
        {"a": a, "b": b},
        resources={"b": {"time_limit_s": 120, "array_n": 4}},
    )
    assert p.stages["b"].time_limit_s == 120.0
    assert p.stages["b"].array_n == 4
    assert p.stages["a"].time_limit_s is None
    with pytest.raises(PipelineError, match="unknown stage"):
        Pipeline({"a": a}, resources={"zz": {"array_n": 2}})
    with pytest.raises(PipelineError, match="non-resource"):
        Pipeline({"a": a}, resources={"a": {"script": "evil.sh"}})


def test_pipeline_id_stable_and_shape_sensitive():
    a = RunSpec(script="a.sh", outputs=["x.txt"])
    b = RunSpec(script="b.sh", inputs=["x.txt"], outputs=["y.txt"])
    assert Pipeline({"a": a, "b": b}).pipeline_id == Pipeline(
        {"a": a, "b": b}
    ).pipeline_id
    assert Pipeline({"a": a, "b": b}).pipeline_id != Pipeline(
        {"a": a}
    ).pipeline_id


def test_missing_inputs_respects_provided(tmp_path):
    spec = RunSpec(
        script="t.sh", inputs=["data/clean.txt", "cfg.json"], outputs=["m.bin"]
    )
    root = str(tmp_path)
    write(root, "cfg.json", "{}")
    assert spec.missing_inputs(root) == ["data/clean.txt"]
    assert spec.missing_inputs(root, provided={"data/clean.txt"}) == []
    # nested-under-provided-directory counts as provided too
    spec2 = RunSpec(script="t.sh", inputs=["data/part/x.txt"], outputs=["m"])
    assert spec2.missing_inputs(root, provided={"data"}) == []
    assert spec2.expand_inputs(root, provided={"data"}) == []
    with pytest.raises(FileNotFoundError):
        spec2.expand_inputs(root)


# --------------------------------------------------- afterok on the cluster
def test_afterok_holds_then_releases(tmp_path):
    cluster = LocalSlurmCluster(max_workers=2)
    wd = str(tmp_path)
    script(wd, "a.sh", "sleep 0.2; date +%s.%N > a.done")
    script(wd, "b.sh", "date +%s.%N > b.done")
    pa = cluster.sbatch("a.sh", workdir=wd)
    pb = cluster.sbatch("b.sh", workdir=wd, dependency=[pa])
    assert cluster.sacct(pb) == PENDING  # held, not running
    cluster.wait([pa, pb], timeout=30)
    assert cluster.sacct(pa) == COMPLETED and cluster.sacct(pb) == COMPLETED
    ta = float(open(os.path.join(wd, "a.done")).read())
    tb = float(open(os.path.join(wd, "b.done")).read())
    assert tb >= ta  # dependent started only after the parent finished
    cluster.shutdown()


def test_afterok_failed_parent_cascades(tmp_path):
    cluster = LocalSlurmCluster(max_workers=2)
    wd = str(tmp_path)
    script(wd, "boom.sh", "exit 3")
    script(wd, "child.sh", "touch child.ran")
    p = cluster.sbatch("boom.sh", workdir=wd)
    c = cluster.sbatch("child.sh", workdir=wd, dependency=[p])
    g = cluster.sbatch("child.sh", workdir=wd, dependency=[c])
    cluster.wait([p, c, g], timeout=30)
    assert cluster.sacct(p) == FAILED
    assert cluster.sacct(c) == CANCELLED
    assert cluster.sacct(g) == CANCELLED  # cascades through grandchildren
    assert not os.path.exists(os.path.join(wd, "child.ran"))
    cluster.shutdown()


# ------------------------------------------------------------- end to end
def test_three_level_campaign_three_batches(tmp_path):
    root, s = make_session(tmp_path)
    stages = three_stage(root)
    p = Pipeline(stages)
    assert p.levels() == [["preprocess"], ["train"], ["evaluate"]]
    batches = []
    real = s.scheduler.submit_many

    def counting(specs, **kw):
        batches.append(list(kw.get("stages") or []))
        return real(specs, **kw)

    s.scheduler.submit_many = counting
    out = s.run_pipeline(p)
    rows = [s.scheduler.db.get(j) for j in out["jobs"].values()]
    assert all(r["status"] == "finished" for r in rows)
    # one topologically-batched submit_many per level, no more
    assert batches == [["preprocess"], ["train"], ["evaluate"]]
    assert open(os.path.join(root, "report/score.txt")).read().strip() == "300"
    # pipeline rows are tagged and edges recorded
    assert {r["stage"] for r in rows} == set(stages)
    pid = rows[0]["pipeline"]
    assert pid and all(r["pipeline"] == pid for r in rows)
    deps = s.scheduler.db.parents_of(out["jobs"]["evaluate"])
    assert [d["stage"] for d in deps] == ["train"]
    s.cluster.shutdown()


def test_warm_replay_fully_memoized(tmp_path):
    root, s = make_session(tmp_path)
    p = Pipeline(three_stage(root))
    s.run_pipeline(p)
    before = len(s.cluster._jobs)
    out = s.run_pipeline(p)
    rows = [s.scheduler.db.get(j) for j in out["jobs"].values()]
    assert all(r["status"] == "memoized" for r in rows)
    assert len(s.cluster._jobs) == before  # nothing reached Slurm
    s.cluster.shutdown()


def test_partial_replay_reruns_only_failed_cone(tmp_path):
    root, s = make_session(tmp_path)
    stages = three_stage(root)
    p = Pipeline(stages)
    s.run_pipeline(p)
    # invalidate the middle stage: train.sh content is keyed because the
    # script is declared as an input
    script(root, "train.sh",
           "mkdir -p model; cat data/clean.txt data/clean.txt > model/weights.bin")
    before = len(s.cluster._jobs)
    out = s.run_pipeline(Pipeline(stages))
    rows = {n: s.scheduler.db.get(j) for n, j in out["jobs"].items()}
    assert rows["preprocess"]["status"] == "memoized"
    assert rows["train"]["status"] == "finished"
    assert rows["evaluate"]["status"] == "finished"
    assert len(s.cluster._jobs) == before + 2  # only the train cone ran
    assert open(os.path.join(root, "report/score.txt")).read().strip() == "600"
    s.cluster.shutdown()


def test_failed_parent_closes_dependents_and_replay_recovers(tmp_path):
    root, s = make_session(tmp_path)
    stages = three_stage(root)
    script(root, "train.sh", "exit 7")  # mid-campaign failure
    out = s.run_pipeline(Pipeline(stages), close_failed_jobs=True)
    rows = {n: s.scheduler.db.get(j) for n, j in out["jobs"].items()}
    assert rows["preprocess"]["status"] == "finished"
    assert rows["train"]["status"] == "closed-failed"
    assert rows["evaluate"]["status"] == "cancelled-dependency"
    assert s.cluster.sacct(rows["evaluate"]["slurm_id"]) == CANCELLED
    # closing the cascade released every protected output
    assert s.scheduler.db.n_protected() == 0
    # fix the stage and replay: only the failed cone re-executes
    script(root, "train.sh", "mkdir -p model; cat data/clean.txt > model/weights.bin")
    before = len(s.cluster._jobs)
    out2 = s.run_pipeline(Pipeline(stages))
    rows2 = {n: s.scheduler.db.get(j) for n, j in out2["jobs"].items()}
    assert rows2["preprocess"]["status"] == "memoized"
    assert rows2["train"]["status"] == "finished"
    assert rows2["evaluate"]["status"] == "finished"
    assert len(s.cluster._jobs) == before + 2
    assert s.verify()["divergence"] == 0
    s.cluster.shutdown()


def test_diamond_pipeline_runs_in_level_order(tmp_path):
    root, s = make_session(tmp_path)
    script(root, "a.sh", "printf 'r%.0s' {1..80} > raw.txt")
    script(root, "b.sh", "tr r b < raw.txt > b.txt")
    script(root, "c.sh", "tr r c < raw.txt > c.txt")
    script(root, "d.sh", "cat b.txt c.txt > d.txt")
    p = Pipeline({
        "a": RunSpec(script="a.sh", outputs=["raw.txt"]),
        "b": RunSpec(script="b.sh", inputs=["raw.txt"], outputs=["b.txt"]),
        "c": RunSpec(script="c.sh", inputs=["raw.txt"], outputs=["c.txt"]),
        "d": RunSpec(
            script="d.sh", inputs=["b.txt", "c.txt"], outputs=["d.txt"]
        ),
    })
    out = s.run_pipeline(p)
    assert all(
        s.scheduler.db.get(j)["status"] == "finished"
        for j in out["jobs"].values()
    )
    assert len(open(os.path.join(root, "d.txt")).read()) == 160
    d_parents = {
        r["stage"] for r in s.scheduler.db.parents_of(out["jobs"]["d"])
    }
    assert d_parents == {"b", "c"}
    s.cluster.shutdown()


# ----------------------------------------------- straggler with dependents
def test_reschedule_straggler_rewires_dependents(tmp_path):
    root, s = make_session(tmp_path)
    # the parent blocks until a sentinel file appears, so both the original
    # and its replacement are controllable from the test
    script(root, "slow.sh", "while [ ! -f go ]; do sleep 0.05; done; "
           "printf 'p%.0s' {1..70} > parent.txt")
    script(root, "child.sh", "cat parent.txt parent.txt > child.txt")
    p = Pipeline({
        "slow": RunSpec(script="slow.sh", outputs=["parent.txt"]),
        "child": RunSpec(
            script="child.sh", inputs=["parent.txt"], outputs=["child.txt"]
        ),
    })
    jobs = s.scheduler.submit_pipeline(p)
    child_row = s.scheduler.db.get(jobs["child"])
    old_slurm = s.scheduler.db.get(jobs["slow"])["slurm_id"]
    new_id = s.scheduler.reschedule_straggler(jobs["slow"])
    assert new_id is not None
    new_row = s.scheduler.db.get(new_id)
    # original closed, replacement open, child rewired onto the replacement
    assert s.scheduler.db.get(jobs["slow"])["status"] == "cancelled-straggler"
    assert s.cluster.sacct(old_slurm) == CANCELLED
    assert s.cluster.sacct(child_row["slurm_id"]) == PENDING  # NOT cascaded
    parents = s.scheduler.db.parents_of(jobs["child"])
    assert [r["job_id"] for r in parents] == [new_id]
    # release the sentinel: replacement completes, child runs after it
    write(root, "go", "")
    s.wait([new_id, jobs["child"]], timeout=30)
    assert s.cluster.sacct(new_row["slurm_id"]) == COMPLETED
    assert s.cluster.sacct(child_row["slurm_id"]) == COMPLETED
    results = s.scheduler.finish()
    assert os.path.getsize(os.path.join(root, "child.txt")) == 140
    statuses = {
        r["job_id"]: r["status"] for r in s.scheduler.db.all_jobs()
    }
    assert statuses[new_id] == "finished"
    assert statuses[jobs["child"]] == "finished"
    assert s.verify()["divergence"] == 0
    s.cluster.shutdown()


# --------------------------------------------------- edge-case hardening
def test_root_level_wildcard_warns():
    prep = RunSpec(script="p.sh", outputs=["prep"])
    # unanchored: `*.npy` cannot be tied to the directory output `prep`, so
    # no edge is inferred — the hazard must be surfaced, not silent
    loose = RunSpec(script="c.sh", inputs=["*.npy"], outputs=["agg.txt"])
    with pytest.warns(PipelineWarning, match="root-level wildcard"):
        p = Pipeline({"prep": prep, "consume": loose})
    assert p.edges() == []
    # anchored under the producing directory: edge inferred, no warning
    anchored = RunSpec(
        script="c.sh", inputs=["prep/*.npy"], outputs=["agg.txt"]
    )
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert Pipeline({"prep": prep, "consume": anchored}).edges() == [
            ("prep", "consume")
        ]
        # a root-level wildcard that literally matches an output is fine too
        a = RunSpec(script="a.sh", outputs=["x.npy"])
        b = RunSpec(script="b.sh", inputs=["*.npy"], outputs=["y.txt"])
        assert Pipeline({"a": a, "b": b}).edges() == [("a", "b")]
        # no producers at all (single root stage): nothing to warn about
        Pipeline({"prep": RunSpec(
            script="p.sh", inputs=["*.raw"], outputs=["prep"]
        )})


def test_sbatch_unknown_dependency_leaves_no_phantom(tmp_path):
    cluster = LocalSlurmCluster(max_workers=2)
    wd = str(tmp_path)
    script(wd, "a.sh", "true")
    pa = cluster.sbatch("a.sh", workdir=wd)
    before = set(cluster._jobs)
    with pytest.raises(KeyError, match="unknown dependency"):
        cluster.sbatch("a.sh", workdir=wd, dependency=[pa, 999_999])
    # nothing was registered: no phantom never-terminal PENDING job and no
    # stale parent->child entries for the valid parents in the list
    assert set(cluster._jobs) == before
    assert not any(cluster._dependents.values())
    cluster.wait([pa], timeout=30)
    assert cluster.sacct(pa) == COMPLETED
    cluster.shutdown()


def test_scontrol_unknown_add_keeps_edges_intact(tmp_path):
    cluster = LocalSlurmCluster(max_workers=2)
    wd = str(tmp_path)
    script(wd, "slow.sh", "while [ ! -f go ]; do sleep 0.05; done")
    script(wd, "b.sh", "true")
    pa = cluster.sbatch("slow.sh", workdir=wd)
    pb = cluster.sbatch("b.sh", workdir=wd, dependency=[pa])
    with pytest.raises(KeyError, match="unknown dependency"):
        cluster.scontrol_update_dependency(pb, add=[999_999])
    # the failed rewire left the afterok edge in place: pb still waits for
    # pa and is released when it completes (the old half-mutation dropped
    # pb from the waiting set, stranding it PENDING forever)
    assert cluster.sacct(pb) == PENDING
    write(wd, "go", "")
    cluster.wait([pa, pb], timeout=30)
    assert cluster.sacct(pb) == COMPLETED
    cluster.shutdown()


def test_subprocess_rewire_holds_first_and_preserves_parents(monkeypatch):
    from repro.core import slurm as slurm_mod

    cluster = slurm_mod.SubprocessSlurmCluster()
    calls = []

    class R:
        def __init__(self, stdout=""):
            self.returncode = 0
            self.stdout = stdout

    def fake_run(cmd, **kw):
        calls.append(list(cmd))
        if cmd[:3] == ["scontrol", "show", "job"]:
            return R(
                "JobId=7 JobName=x JobState=PENDING Reason=Dependency\n"
                "   Dependency=afterok:101(unfulfilled):102,singleton\n"
            )
        return R()

    monkeypatch.setattr(slurm_mod.subprocess, "run", fake_run)
    assert cluster.scontrol_update_dependency(7, remove=[101], hold=True)
    # hold lands BEFORE the expression is rewritten, so the job is never
    # momentarily dependency-free and eligible to start
    assert calls[0] == ["scontrol", "hold", "7"]
    update = next(c for c in calls if c[:2] == ["scontrol", "update"])
    # remove-only keeps the OTHER afterok parent and non-afterok clauses:
    # real scontrol replaces the whole expression, so the backend must
    # read-modify-write it
    assert update[-1] == "Dependency=singleton,afterok:102"

    calls.clear()
    assert cluster.scontrol_update_dependency(7, add=[555])
    update = next(c for c in calls if c[:2] == ["scontrol", "update"])
    assert update[-1] == "Dependency=singleton,afterok:101:102:555"

    # a non-PENDING job cannot be rewired; the hold taken first is released
    def fake_run_running(cmd, **kw):
        calls.append(list(cmd))
        if cmd[:3] == ["scontrol", "show", "job"]:
            return R("JobId=7 JobState=RUNNING Dependency=(null)\n")
        return R()

    calls.clear()
    monkeypatch.setattr(slurm_mod.subprocess, "run", fake_run_running)
    assert not cluster.scontrol_update_dependency(7, remove=[101], hold=True)
    assert ["scontrol", "release", "7"] in calls


def test_replace_dep_parent_children_filter(tmp_path):
    repro_dir = str(tmp_path / ".repro")
    os.makedirs(repro_dir)
    db = JobDB(repro_dir)
    old = db.add_job(RunSpec(script="p.sh", outputs=["p.txt"]))
    new = db.add_job(RunSpec(script="p2.sh", outputs=["p2.txt"]))
    c1 = db.add_job(RunSpec(script="c1.sh", outputs=["c1.txt"]))
    c2 = db.add_job(RunSpec(script="c2.sh", outputs=["c2.txt"]))
    db.add_deps([(c1, old), (c2, old)])
    # only c1 was detached on the cluster: move just its edge — c2 still
    # chains off the old job there, and jobdb must keep saying so
    db.replace_dep_parent(old, new, children=[c1])
    assert [r["job_id"] for r in db.parents_of(c1)] == [new]
    assert [r["job_id"] for r in db.parents_of(c2)] == [old]
    db.replace_dep_parent(old, new, children=[])  # no-op
    assert [r["job_id"] for r in db.parents_of(c2)] == [old]
    db.replace_dep_parent(old, new)  # unfiltered form moves the rest
    assert [r["job_id"] for r in db.parents_of(c2)] == [new]
