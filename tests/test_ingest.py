"""Data plane (DESIGN.md §9): concurrent-transfer model, single-pass annex
ingest, dedup short-circuit, fused alt-dir absorption, and the bytes-heavy
benchmark smoke."""
import hashlib
import os
import threading

import pytest

from repro.core.annex import AnnexStore
from repro.core.fsio import FS, GPFS_STRIPED, FSProfile, SimClock
from repro.core.hashing import annex_key_for_file, sha256_file
from repro.core.repo import Repository

# bandwidth-only profile: aggregate 8 B/s, per-stream cap 2 B/s — numbers
# small enough that charges are exact binary floats
STRIPED = FSProfile(
    name="striped-test", meta_op_s=0.0, read_bw=8.0, write_bw=8.0,
    read_stream_bw=2.0, write_stream_bw=2.0,
)
FLAT = FSProfile(name="flat-test", meta_op_s=0.0, read_bw=8.0, write_bw=8.0)


def write(root, rel, data: bytes):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "wb") as f:
        f.write(data)
    return p


# ------------------------------------------------------- §9 stream model
def test_serial_stream_charged_at_per_stream_cap():
    fs = FS(STRIPED, SimClock())
    with fs.transfer_stream(False) as charge:
        charge(8)  # k=1: eff = min(1*2, 8) = 2 B/s
    assert fs.clock.total == pytest.approx(4.0)


def test_overlapping_streams_split_aggregate_bandwidth():
    fs = FS(STRIPED, SimClock())
    with fs.transfer_stream(False) as c1, fs.transfer_stream(False) as c2:
        c1(8)  # k=2: eff = min(2*2, 8) = 4 B/s
        c2(8)
    # makespan semantics: 16 bytes at 4 B/s delivered = 4 s total, i.e. two
    # overlapping 8-byte streams finish together in the time one would take
    assert fs.clock.total == pytest.approx(4.0)


def test_streams_saturate_at_aggregate():
    fs = FS(STRIPED, SimClock())
    streams = [fs.transfer_stream(False) for _ in range(8)]
    charges = [s.__enter__() for s in streams]
    for c in charges:
        c(8)  # k=8: eff = min(16, 8) = 8 B/s — saturated, contention past 4
    for s in streams:
        s.__exit__(None, None, None)
    assert fs.clock.total == pytest.approx(8.0)  # 64 bytes / 8 B/s


def test_directions_pool_independently():
    fs = FS(STRIPED, SimClock())
    with fs.transfer_stream(False) as r, fs.transfer_stream(True) as w:
        r(8)  # the write stream does not contend with the read pool
        w(8)
    assert fs.clock.total == pytest.approx(8.0)  # 4 s + 4 s


def test_undeclared_profile_keeps_flat_model(tmp_path):
    """A profile without stream caps charges serial callers exactly
    bytes/bandwidth — today's model, byte for byte."""
    fs = FS(FLAT, SimClock())
    p = write(str(tmp_path), "f.bin", b"x" * 48)
    data = fs.read_bytes(p)
    assert data == b"x" * 48
    assert fs.clock.total == pytest.approx(48 / 8.0)
    assert fs.clock.bytes_read == 48
    # and even with the cap, a lone caller through high-level ops pays the
    # cap rate — concurrency can only discount, never penalize
    fs2 = FS(STRIPED, SimClock())
    fs2.read_bytes(p)
    assert fs2.clock.total == pytest.approx(48 / 2.0)


def test_gpfs_striped_profile_saturates_at_8_streams():
    assert GPFS_STRIPED.read_stream_bw * 8 == pytest.approx(GPFS_STRIPED.read_bw)
    assert GPFS_STRIPED.write_stream_bw * 8 == pytest.approx(GPFS_STRIPED.write_bw)


# ------------------------------------------------- fs-routed sha256_file
def test_sha256_file_charges_cost_model_when_fs_given(tmp_path):
    data = os.urandom(1 << 16)
    p = write(str(tmp_path), "blob.bin", data)
    fs = FS(FLAT, SimClock())
    hx, size = sha256_file(p, fs=fs)
    assert (hx, size) == (hashlib.sha256(data).hexdigest(), len(data))
    assert fs.clock.bytes_read == len(data)  # hashing reads are charged
    assert fs.clock.total == pytest.approx(len(data) / 8.0)
    # raw-path variant (no FS context) still works and matches
    assert sha256_file(p) == (hx, size)


# ------------------------------------------------- single-pass ingest
def test_ingest_file_single_read_single_write(tmp_path):
    data = os.urandom(3 << 20) + b"tail"
    src = write(str(tmp_path), "src.bin", data)
    fs = FS(FLAT, SimClock())
    store = AnnexStore(str(tmp_path / "annex"), fs)
    key = store.ingest_file(src)
    # ONE charged read pass + ONE charged write pass — not hash-then-copy
    assert fs.clock.bytes_read == len(data)
    assert fs.clock.bytes_written == len(data)
    assert key == annex_key_for_file(src)
    assert store.read(key) == data
    # no tmp leftovers, exactly one object
    found = []
    for dirpath, _, files in os.walk(store.root):
        found.extend(files)
    assert found == [key]


def test_ingest_file_dedup_short_circuit(tmp_path):
    data = b"d" * (1 << 20)
    src1 = write(str(tmp_path), "a.bin", data)
    src2 = write(str(tmp_path), "b.bin", data)
    fs = FS(FLAT, SimClock())
    store = AnnexStore(str(tmp_path / "annex"), fs)
    key = store.ingest_file(src1)
    key2 = store.ingest_file(src2)  # duplicate content from another path
    assert key2 == key
    found = []
    for dirpath, _, files in os.walk(store.root):
        found.extend(files)
    assert found == [key]  # one object, no tmp leftovers


def test_put_bytes_known_key_set_skips_probe(tmp_path):
    data = b"payload" * 100
    fs = FS(FLAT, SimClock())
    store = AnnexStore(str(tmp_path / "annex"), fs)
    from repro.core.hashing import annex_key_for_bytes

    key = annex_key_for_bytes(data)
    store.put_bytes(key, data)
    before = fs.clock.meta_ops
    store.put_bytes(key, data)  # known key: answered in memory
    assert fs.clock.meta_ops == before


def test_concurrent_put_same_key_idempotent(tmp_path):
    """The TOCTOU fix: two writers racing the same key both succeed; exactly
    one valid object remains (tmp + atomic rename, packs.py pattern)."""
    data = os.urandom(1 << 18)
    from repro.core.hashing import annex_key_for_bytes

    key = annex_key_for_bytes(data)
    fs = FS(FLAT, SimClock())
    # separate store instances: separate known-key sets, shared directory
    stores = [AnnexStore(str(tmp_path / "annex"), fs) for _ in range(4)]
    barrier = threading.Barrier(len(stores))
    errors = []

    def put(s):
        try:
            barrier.wait()
            s.put_bytes(key, data)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=put, args=(s,)) for s in stores]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    found = []
    for dirpath, _, files in os.walk(str(tmp_path / "annex")):
        found.extend(files)
    assert found == [key]
    assert stores[0].read(key) == data  # verifies content against the key


def test_has_many_probes_per_key_not_listdir(tmp_path):
    fs = FS(FLAT, SimClock())
    store = AnnexStore(str(tmp_path / "annex"), fs)
    from repro.core.hashing import annex_key_for_bytes

    present, absent = [], []
    for i in range(3):
        data = b"k%d" % i
        key = annex_key_for_bytes(data)
        store.put_bytes(key, data)
        present.append(key)
    for i in range(2):
        absent.append(annex_key_for_bytes(b"missing%d" % i))
    fresh_store = AnnexStore(str(tmp_path / "annex"), fs)  # empty known set
    before = fs.clock.meta_ops
    got = fresh_store.has_many(present + absent)
    assert got == set(present)
    # one exists probe per key — NOT a listdir sweep over every shard
    assert fs.clock.meta_ops - before == 5
    before = fs.clock.meta_ops
    got = fresh_store.has_many(present + absent)
    assert got == set(present)
    # second pass: present keys answered from the known-key set
    assert fs.clock.meta_ops - before == 2


def test_whereis_many_batched(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=8)
    write(repo.root, "big.bin", b"a" * 64)
    repo.save(message="add")
    key = repo.annex_key_at("big.bin")
    other = "SHA256-s1--" + "0" * 64
    wm = repo.whereis_many([key, other])
    assert wm[key] == ["local"]
    assert wm[other] == []


# ------------------------------------------------- fused external ingest
def test_ingest_external_file_annex_rename_fast_path(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"), profile=FLAT,
                           annex_threshold=512)
    data = os.urandom(4096)
    src = write(str(tmp_path), "stage/jobs/0/out.bin", data)
    clock = repo.fs.clock
    r0, w0 = clock.bytes_read, clock.bytes_written
    entry = repo.ingest_external_file(src, "jobs/0/out.bin")
    assert clock.bytes_read - r0 == len(data)  # bytes moved ONCE
    assert clock.bytes_written - w0 == len(data)
    assert entry["t"] == "annex"
    assert repo.annex.read(entry["key"]) == data
    # worktree copy materialized by RENAME, not a second byte copy
    wt = os.path.join(repo.root, "jobs/0/out.bin")
    assert open(wt, "rb").read() == data
    assert not os.path.exists(src)


def test_ingest_external_file_small_becomes_blob(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=1 << 20)
    src = write(str(tmp_path), "stage/note.txt", b"tiny note")
    entry = repo.ingest_external_file(src, "note.txt")
    assert entry["t"] == "blob"
    assert repo.objects.get_blob(entry["oid"]) == b"tiny note"
    assert open(os.path.join(repo.root, "note.txt"), "rb").read() == b"tiny note"
    assert not os.path.exists(src)


# ------------------------------------------------- staging equivalence
def test_streamed_staging_equals_seed_staging(tmp_path):
    """Single-pass staging and the seed read-whole protocol emit identical
    tree entries for the same content (blob, annexed, pointer)."""
    a = Repository.init(str(tmp_path / "a"), annex_threshold=1024)
    b = Repository.init(str(tmp_path / "b"), annex_threshold=1024)
    big = os.urandom(8192)
    for repo in (a, b):
        write(repo.root, "small.txt", b"small content")
        write(repo.root, "big.bin", big)
    ea = a.stage_paths(["small.txt", "big.bin"])  # single-pass default
    eb = b.stage_paths(["small.txt", "big.bin"], single_pass=False)
    assert ea == eb
    assert ea["big.bin"]["t"] == "annex"


# ------------------------------------------------- bench smoke (tier-1)
def test_bench_ingest_smoke():
    """Fast tier-1 variant of the bytes-heavy benchmark: the fused data
    plane must ~halve charged reads vs the seed path, and the pipelined
    finish can never charge more than the serial one (the §9 model only
    discounts overlap)."""
    from benchmarks import bench_ingest

    rows = bench_ingest.run(n_jobs=2, files_per_job=2, mib_per_file=2)
    by_case = {r["case"]: r for r in rows}
    seed = by_case["ingest_seed"]
    fused = by_case["ingest_fused"]
    piped = by_case["ingest_pipelined"]
    assert fused["bytes_read"] <= 0.7 * seed["bytes_read"]
    assert fused["bytes_written"] <= 0.7 * seed["bytes_written"]
    assert fused["sim_s_total"] < seed["sim_s_total"]
    assert piped["sim_s_total"] <= fused["sim_s_total"] * 1.001
    # same volume moved (slurm metadata files vary by a few bytes per run)
    assert abs(piped["bytes_read"] - fused["bytes_read"]) < 4096
