"""Integration tests: Slurm shim + job DB + scheduler protocol (paper §5)."""
import json
import os
import stat
import time

import pytest

from repro.core.conflicts import OutputConflict, WildcardOutputError
from repro.core.jobdb import JobDB
from repro.core.records import TITLE_SLURM, RunRecord, spec_of
from repro.core.repo import Repository
from repro.core.scheduler import ScheduleError, SlurmScheduler
from repro.core.slurm import COMPLETED, FAILED, LocalSlurmCluster
from repro.core.spec import RunSpec


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


def make_job_script(root, rel, body):
    write(root, rel, "#!/bin/bash\n" + body + "\n")


@pytest.fixture
def env(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=1 << 20)
    cluster = LocalSlurmCluster(max_workers=4, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster)
    yield repo, cluster, sched
    cluster.shutdown()


# --------------------------------------------------------------- slurm shim
def test_local_cluster_runs_job_and_writes_slurm_files(tmp_path):
    cluster = LocalSlurmCluster(max_workers=2)
    wd = str(tmp_path)
    write(wd, "job.sh", "#!/bin/bash\necho hello $SLURM_JOB_ID\n")
    jid = cluster.sbatch("job.sh", workdir=wd)
    cluster.wait([jid], timeout=30)
    assert cluster.sacct(jid) == COMPLETED
    log = open(os.path.join(wd, f"log.slurm-{jid}.out")).read()
    assert f"hello {jid}" in log
    meta = json.load(open(os.path.join(wd, f"slurm-job-{jid}.env.json")))
    assert meta["SLURM_JOB_ID"] == jid
    assert meta["State"] == COMPLETED
    cluster.shutdown()


def test_local_cluster_array_job_states(tmp_path):
    cluster = LocalSlurmCluster(max_workers=4)
    wd = str(tmp_path)
    write(wd, "arr.sh", "#!/bin/bash\n[ \"$SLURM_ARRAY_TASK_ID\" = 2 ] && exit 1\nexit 0\n")
    jid = cluster.sbatch("arr.sh", workdir=wd, array_n=4)
    cluster.wait([jid], timeout=30)
    states = cluster.sacct_tasks(jid)
    assert states.count(COMPLETED) == 3 and states.count(FAILED) == 1
    assert cluster.sacct(jid) == FAILED  # array COMPLETED only if all tasks are
    cluster.shutdown()


def test_local_cluster_timeout(tmp_path):
    cluster = LocalSlurmCluster(max_workers=1)
    wd = str(tmp_path)
    write(wd, "slow.sh", "#!/bin/bash\nsleep 30\n")
    jid = cluster.sbatch("slow.sh", workdir=wd, time_limit_s=0.3)
    cluster.wait([jid], timeout=30)
    assert cluster.sacct(jid) == "TIMEOUT"
    cluster.shutdown()


# --------------------------------------------------------------- schedule
def test_schedule_requires_outputs(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "job.sh", "true")
    with pytest.raises(ScheduleError):
        sched.schedule("job.sh", outputs=[])


def test_schedule_rejects_wildcard_outputs(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "job.sh", "true")
    with pytest.raises(WildcardOutputError):
        sched.schedule("job.sh", outputs=["results/*.csv"])


def test_schedule_conflict_refused(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "job.sh", "sleep 0.5; echo done > out/result.txt")
    os.makedirs(os.path.join(repo.root, "out"))
    sched.schedule("job.sh", outputs=["out"])
    with pytest.raises(OutputConflict):
        sched.schedule("job.sh", outputs=["out/result.txt"])  # inside claimed dir
    with pytest.raises(OutputConflict):
        sched.schedule("job.sh", outputs=["out"])  # same dir


def test_full_schedule_finish_cycle_with_record(env):
    repo, cluster, sched = env
    write(repo.root, "input.txt", "21")
    repo.save(message="input")
    make_job_script(
        repo.root, "job.sh",
        'mkdir -p out && echo $(( $(cat input.txt) * 2 )) > out/answer.txt',
    )
    job_id = sched.schedule(
        "job.sh", outputs=["out"], inputs=["input.txt"], message="double it"
    )
    job = sched.db.get(job_id)
    cluster.wait([job["slurm_id"]], timeout=30)
    results = sched.finish()
    assert len(results) == 1 and results[0].state == COMPLETED
    assert open(os.path.join(repo.root, "out/answer.txt")).read().strip() == "42"

    # reproducibility record in the commit message, like paper Fig. 4
    commit = repo.objects.get_commit(results[0].commit)
    assert TITLE_SLURM in commit["message"]
    rec = RunRecord.from_message(commit["message"])
    assert rec.slurm_job_id == job["slurm_id"]
    assert rec.cmd == "sbatch job.sh"
    assert "out" in rec.outputs
    assert any(f.startswith("log.slurm-") for f in rec.slurm_outputs)
    # slurm log + env json are committed
    tree = repo.tree_of(results[0].commit)
    assert any(p.startswith("log.slurm-") for p in tree)
    assert any(p.startswith("slurm-job-") and p.endswith(".env.json") for p in tree)

    # protection released: same outputs schedulable again
    sched.schedule("job.sh", outputs=["out"], inputs=["input.txt"])


def test_many_concurrent_jobs_one_clone(env):
    """§5.1 goal: many Slurm jobs running at the same time on ONE clone."""
    repo, cluster, sched = env
    n = 12
    for j in range(n):
        make_job_script(
            repo.root, f"jobs/{j}/slurm.sh",
            f'echo "result {j}" > result.txt',
        )
    repo.save(message="job scripts")
    ids = [
        sched.schedule(
            "slurm.sh", outputs=[f"jobs/{j}/result.txt"], pwd=f"jobs/{j}"
        )
        for j in range(n)
    ]
    cluster.wait(timeout=60)
    results = sched.finish()
    assert len(results) == n
    assert all(r.state == COMPLETED for r in results)
    for j in range(n):
        assert open(os.path.join(repo.root, f"jobs/{j}/result.txt")).read() == f"result {j}\n"
    assert sched.db.open_jobs() == []
    assert len(ids) == n


def test_finish_ignores_running_jobs(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "slow.sh", "sleep 2; echo done > slow_out.txt")
    job_id = sched.schedule("slow.sh", outputs=["slow_out.txt"])
    time.sleep(0.3)
    assert sched.finish() == []  # running -> ignored for now (§5.2)
    open_jobs = sched.list_open_jobs()
    assert len(open_jobs) == 1
    job = sched.db.get(job_id)
    cluster.wait([job["slurm_id"]], timeout=30)
    assert len(sched.finish()) == 1


def test_failed_job_handling(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "bad.sh", "echo partial > bad_out.txt; exit 7")
    job_id = sched.schedule("bad.sh", outputs=["bad_out.txt"])
    job = sched.db.get(job_id)
    cluster.wait([job["slurm_id"]], timeout=30)

    # without flags: stays in DB, outputs stay protected
    res = sched.finish()
    assert res[0].state == FAILED and res[0].commit is None
    with pytest.raises(OutputConflict):
        sched.schedule("bad.sh", outputs=["bad_out.txt"])

    # --close-failed-jobs: removed, outputs released, nothing committed
    sched.finish(close_failed_jobs=True)
    assert sched.db.open_jobs() == []
    job_id2 = sched.schedule("bad.sh", outputs=["bad_out.txt"])
    job2 = sched.db.get(job_id2)
    cluster.wait([job2["slurm_id"]], timeout=30)

    # --commit-failed-jobs: handled like a success, with exit=1 recorded
    res = sched.finish(commit_failed_jobs=True)
    assert res[0].commit is not None
    rec = RunRecord.from_message(repo.objects.get_commit(res[0].commit)["message"])
    assert rec.exit == 1


def test_array_job_single_record(env):
    """§5.6: an array job is one job with many outputs and ONE record."""
    repo, cluster, sched = env
    make_job_script(
        repo.root, "arr.sh",
        'mkdir -p tasks/$SLURM_ARRAY_TASK_ID && echo $SLURM_ARRAY_TASK_ID > tasks/$SLURM_ARRAY_TASK_ID/r.txt',
    )
    job_id = sched.schedule(
        "arr.sh", outputs=[f"tasks/{t}" for t in range(4)], array_n=4
    )
    job = sched.db.get(job_id)
    cluster.wait([job["slurm_id"]], timeout=30)
    results = sched.finish()
    assert len(results) == 1  # one record for the entire array
    rec = RunRecord.from_message(repo.objects.get_commit(results[0].commit)["message"])
    assert rec.extras["array_n"] == 4
    for t in range(4):
        assert open(os.path.join(repo.root, f"tasks/{t}/r.txt")).read().strip() == str(t)


def test_per_job_branches_and_octopus(env):
    """§5.8: --octopus commits each job to its own branch + N-parent merge."""
    repo, cluster, sched = env
    write(repo.root, "base.txt", "base")
    repo.save(message="base")
    for j in range(3):
        make_job_script(repo.root, f"j{j}.sh", f"echo {j} > out_{j}.txt")
    for j in range(3):
        sched.schedule(f"j{j}.sh", outputs=[f"out_{j}.txt"])
    cluster.wait(timeout=60)
    results = sched.finish(octopus=True)
    assert all(r.branch and r.branch.startswith("job/") for r in results)
    head = repo.head_commit()
    merge = repo.objects.get_commit(head)
    assert len(merge["parents"]) == 4  # base + 3 job branches
    tree = repo.tree_of(head)
    assert {"out_0.txt", "out_1.txt", "out_2.txt"} <= set(tree)


def test_alt_dir_staging(env, tmp_path):
    """§5.7: repo on 'local FS', job runs under alt_dir ('parallel FS')."""
    repo, cluster, sched = env
    alt = str(tmp_path / "pfs")
    write(repo.root, "jobs/7/input.txt", "I")
    make_job_script(repo.root, "jobs/7/slurm.sh", "tr I J < input.txt > output.txt")
    repo.save(message="job setup")
    job_id = sched.schedule(
        "slurm.sh",
        outputs=["jobs/7/output.txt"],
        inputs=["jobs/7/input.txt"],
        pwd="jobs/7",
        alt_dir=alt,
    )
    job = sched.db.get(job_id)
    # the job really ran under alt_dir
    assert os.path.exists(os.path.join(alt, "jobs/7/input.txt"))
    cluster.wait([job["slurm_id"]], timeout=30)
    assert os.path.exists(os.path.join(alt, "jobs/7/output.txt"))
    results = sched.finish()
    assert results[0].state == COMPLETED
    # outputs copied back into the repository and committed
    assert open(os.path.join(repo.root, "jobs/7/output.txt")).read() == "J"
    assert "jobs/7/output.txt" in repo.tree_of(results[0].commit)


def test_reschedule_from_record(env):
    """§5.2 slurm-reschedule: key argument is a commit hash from slurm-finish."""
    repo, cluster, sched = env
    write(repo.root, "in.txt", "5")
    repo.save(message="in")
    make_job_script(repo.root, "calc.sh", 'echo $(( $(cat in.txt) + 1 )) > res.txt')
    sched.schedule("calc.sh", outputs=["res.txt"], inputs=["in.txt"])
    cluster.wait(timeout=30)
    (res,) = sched.finish()

    # change the input; rerun via reschedule of that commit
    write(repo.root, "in.txt", "100")
    repo.save(paths=["in.txt"], message="new input")
    new_ids = sched.reschedule(commitish=res.commit)
    assert len(new_ids) == 1
    cluster.wait(timeout=30)
    (res2,) = sched.finish()
    assert open(os.path.join(repo.root, "res.txt")).read().strip() == "101"
    rec2 = RunRecord.from_message(repo.objects.get_commit(res2.commit)["message"])
    assert rec2.cmd == "sbatch calc.sh"

    # with no commit hash: reschedules the most recent slurm job
    newest = sched.reschedule()
    assert len(newest) == 1
    cluster.wait(timeout=30)
    sched.finish()


def test_straggler_detection_and_reschedule(env):
    repo, cluster, sched = env
    for j in range(3):
        make_job_script(repo.root, f"fast{j}.sh", f"sleep 0.1; echo ok > f{j}.txt")
    make_job_script(repo.root, "strag.sh", "sleep 60; echo ok > s.txt")
    for j in range(3):
        sched.schedule(f"fast{j}.sh", outputs=[f"f{j}.txt"])
    s_id = sched.schedule("strag.sh", outputs=["s.txt"])
    deadline = time.time() + 30
    while time.time() < deadline:
        fast_done = [
            j for j, st in sched.list_open_jobs()
            if st == COMPLETED and j["job_id"] != s_id
        ]
        if len(fast_done) == 3:
            break
        time.sleep(0.2)
    # the straggler's elapsed time grows while the fast jobs' median is
    # fixed, so poll until detection fires (immune to CPU-load noise in
    # the fast jobs' runtimes)
    deadline = time.time() + 20
    stragglers = []
    while time.time() < deadline and not stragglers:
        time.sleep(0.3)
        stragglers = sched.find_stragglers(factor=3.0, min_samples=3)
    assert [s["job_id"] for s in stragglers] == [s_id]
    new_id = sched.reschedule_straggler(s_id)
    assert new_id != s_id
    assert sched.db.get(s_id)["status"] == "cancelled-straggler"
    # cleanup: cancel the re-submitted straggler too
    cluster.scancel(sched.db.get(new_id)["slurm_id"])


def test_jobdb_hidden_from_versioning(env):
    repo, cluster, sched = env
    write(repo.root, "a.txt", "a")
    c = repo.save(message="a")
    assert not any("jobdb" in p or ".repro" in p for p in repo.tree_of(c))


# ------------------------------------------------------------ spec layer
def test_submit_takes_spec_and_persists_it(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "job.sh", "echo s > spec_out.txt")
    spec = RunSpec(script="job.sh", outputs=["spec_out.txt"], message="via spec")
    job_id = sched.submit(spec)
    job = sched.db.get(job_id)
    # the exact spec is stored in the job DB row
    assert RunSpec.from_json(job["spec"]) == spec
    cluster.wait([job["slurm_id"]], timeout=30)
    (res,) = sched.finish()
    # ... and embedded in the finish commit, retrievable without the message
    assert spec_of(repo, res.commit).spec_id == spec.spec_id


def test_submit_many_single_charge_transaction_and_conflict_pass(env, monkeypatch):
    """Acceptance: submit_many(N specs) = one CLI-startup charge, one jobdb
    write transaction for protection, one shared conflict pass (each output
    checked exactly once)."""
    repo, cluster, sched = env
    sched.cli_startup_s = 0.35
    n = 64
    specs = []
    for j in range(n):
        make_job_script(repo.root, f"jobs/{j}/slurm.sh", "echo ok > r.txt")
        specs.append(
            RunSpec(script="slurm.sh", outputs=[f"jobs/{j}/r.txt"], pwd=f"jobs/{j}")
        )

    checks = []
    real_check = JobDB._check_one
    monkeypatch.setattr(
        JobDB, "_check_one",
        staticmethod(lambda conn, name: (checks.append(name), real_check(conn, name))[1]),
    )
    begins = []
    sched.db._conn().set_trace_callback(
        lambda stmt: begins.append(stmt) if stmt.strip().upper().startswith("BEGIN") else None
    )
    clock = repo.fs.clock
    t0, meta0 = clock.snapshot(), clock.meta_ops

    ids = sched.submit_many(specs)

    sched.db._conn().set_trace_callback(None)
    assert len(ids) == n and len(set(ids)) == n
    # one shared conflict pass: every output checked exactly once
    assert sorted(checks) == sorted(f"jobs/{j}/r.txt" for j in range(n))
    # one insert+protect transaction, one slurm-id transaction — not 2N
    assert len(begins) == 2
    # the sbatch cost is per job, the CLI startup charge is per *batch*
    assert cluster.sbatch_cost_s == 0.0
    assert clock.snapshot() - t0 == pytest.approx(0.35, abs=1e-6)
    cluster.wait(timeout=60)
    assert len(sched.finish()) == n
    assert clock.meta_ops > meta0  # sanity: work happened on the sim FS


def test_submit_many_batch_conflicts_roll_back_everything(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "a.sh", "true")
    specs = [
        RunSpec(script="a.sh", outputs=["outdir/x.txt"]),
        RunSpec(script="a.sh", outputs=["other.txt"]),
        RunSpec(script="a.sh", outputs=["outdir"]),  # conflicts with spec 0
    ]
    with pytest.raises(OutputConflict):
        sched.submit_many(specs)
    # nothing was inserted or protected: the whole batch rolled back
    assert sched.db.open_jobs() == []
    assert sched.db.n_protected() == 0
    sched.submit(RunSpec(script="a.sh", outputs=["outdir/x.txt"]))


def test_schedule_failure_closes_job_and_relocks_outputs(env):
    """Satellite bugfix: a failed sbatch must not leave a protected job row
    behind or the outputs unlocked."""
    repo, cluster, sched = env
    write(repo.root, "prev_out.txt", "old result")
    repo.save(message="prev")
    repo.lock("prev_out.txt")
    # script does not exist -> LocalSlurmCluster.sbatch raises
    with pytest.raises(FileNotFoundError):
        sched.schedule("missing.sh", outputs=["prev_out.txt"])
    # job row closed, protection released
    assert sched.db.open_jobs() == []
    assert sched.db.n_protected() == 0
    # the pre-existing output was re-locked (schedule had unlocked it)
    mode = os.stat(os.path.join(repo.root, "prev_out.txt")).st_mode
    assert not mode & stat.S_IWUSR
    # and the same outputs are schedulable again
    make_job_script(repo.root, "ok.sh", "echo new > prev_out.txt")
    job_id = sched.schedule("ok.sh", outputs=["prev_out.txt"])
    cluster.wait([sched.db.get(job_id)["slurm_id"]], timeout=30)
    assert sched.finish()[0].state == COMPLETED


def test_submit_many_midbatch_failure_keeps_submitted_jobs(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "good.sh", "echo g > g.txt")
    specs = [
        RunSpec(script="good.sh", outputs=["g.txt"]),
        RunSpec(script="gone.sh", outputs=["h.txt"]),  # sbatch will raise
        RunSpec(script="good.sh", outputs=["i.txt"]),
    ]
    with pytest.raises(FileNotFoundError):
        sched.submit_many(specs)
    open_jobs = sched.db.open_jobs()
    # the successfully submitted job survives with its slurm id persisted...
    assert len(open_jobs) == 1
    assert open_jobs[0]["outputs"] == ["g.txt"]
    assert open_jobs[0]["slurm_id"] is not None
    # ...while the failed and never-submitted jobs released their outputs
    assert sched.db.n_protected() == 1
    sched.submit(RunSpec(script="good.sh", outputs=["h.txt"]))
    sched.submit(RunSpec(script="good.sh", outputs=["i.txt"]))
    cluster.wait(timeout=60)
    assert len(sched.finish()) == 3


def test_schedule_accepts_wildcard_inputs_like_run(env):
    """Satellite: wildcard inputs glob-expand (and annex-fetch) at schedule
    time, agreeing with records.run."""
    repo, cluster, sched = env
    write(repo.root, "data/p1.csv", "1\n")
    write(repo.root, "data/p2.csv", "2\n")
    repo.save(message="data")
    make_job_script(repo.root, "cat.sh", "cat data/*.csv > merged.txt")
    job_id = sched.schedule("cat.sh", outputs=["merged.txt"], inputs=["data/*.csv"])
    cluster.wait([sched.db.get(job_id)["slurm_id"]], timeout=30)
    (res,) = sched.finish()
    assert res.state == COMPLETED
    assert open(os.path.join(repo.root, "merged.txt")).read() == "1\n2\n"
    # the stored spec keeps the pattern for faithful replay
    assert spec_of(repo, res.commit).inputs == ("data/*.csv",)


def test_reschedule_replays_exact_spec(env):
    """Acceptance: reschedule deserializes the stored spec verbatim — the
    resubmitted job's spec differs only in its message."""
    repo, cluster, sched = env
    write(repo.root, "in.txt", "5")
    repo.save(message="in")
    make_job_script(repo.root, "calc.sh", 'echo $(( $(cat in.txt) + 1 )) > res.txt')
    spec = RunSpec(
        script="calc.sh", outputs=["res.txt"], inputs=["in.txt"],
        env={"OMP_NUM_THREADS": "4"}, message="original",
    )
    sched.submit(spec)
    cluster.wait(timeout=30)
    (res,) = sched.finish()
    new_ids = sched.reschedule(commitish=res.commit)
    job = sched.db.get(new_ids[0])
    replayed = RunSpec.from_json(job["spec"])
    assert replayed.replace(message=spec.message) == spec
    assert replayed.replace(message=spec.message).spec_id == spec.spec_id
    cluster.wait(timeout=30)
    (res2,) = sched.finish()
    assert res2.state == COMPLETED


def test_straggler_reschedule_reuses_stored_spec(env):
    repo, cluster, sched = env
    make_job_script(repo.root, "slow.sh", "sleep 30; echo s > s.txt")
    job_id = sched.schedule("slow.sh", outputs=["s.txt"], env={"MARK": "1"})
    orig = RunSpec.from_json(sched.db.get(job_id)["spec"])
    new_id = sched.reschedule_straggler(job_id)
    fresh = RunSpec.from_json(sched.db.get(new_id)["spec"])
    assert fresh.replace(message=orig.message) == orig
    cluster.scancel(sched.db.get(new_id)["slurm_id"])


# ------------------------------------------------ concurrent data plane (§9)
def test_concurrent_finish_disjoint_batches_one_repo(tmp_path):
    """The paper's core concurrency claim, exercised at the data plane: two
    scheduler threads sharing ONE repository finish disjoint job batches
    concurrently. No annex object may be lost, duplicate content must
    collapse to one object (no duplicate loose writes), and ref publication
    must serialize into one linear chain containing every job's commit."""
    import threading

    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=512)
    write(repo.root, "README", "seed\n")
    base = repo.save(message="base")
    cluster = LocalSlurmCluster(max_workers=8, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster, ingest_workers=4)
    n = 8
    specs = []
    for j in range(n):
        # jobs 3 and 7 land in different batches but produce IDENTICAL
        # content — the dedup short-circuit must collapse them to one key
        tag = "shared" if j in (3, 7) else f"job{j}"
        make_job_script(
            repo.root, f"jobs/{j}/slurm.sh",
            f'for i in $(seq 1 400); do echo "payload {tag} $i"; done > out.bin',
        )
        specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}/out.bin"],
                             pwd=f"jobs/{j}"))
    ids = sched.submit_many(specs)
    cluster.wait(timeout=60)

    barrier = threading.Barrier(2)
    errors = []

    def finish_batch(job_ids):
        try:
            barrier.wait()
            for jid in job_ids:
                (res,) = sched.finish(job_id=jid)
                assert res.commit, res
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t1 = threading.Thread(target=finish_batch, args=(ids[:4],))
    t2 = threading.Thread(target=finish_batch, args=(ids[4:],))
    t1.start(); t2.start(); t1.join(); t2.join()
    cluster.shutdown()
    assert not errors
    assert sched.db.open_jobs() == []

    # no lost annex objects: every job's output is an annexed entry in the
    # final tree and its content is present and verifiable
    tree = repo.tree_of(repo.head_commit())
    keys = set()
    for j in range(n):
        entry = tree[f"jobs/{j}/out.bin"]
        assert entry["t"] == "annex"
        assert repo.annex.read(entry["key"])  # verifies against the key
        keys.add(entry["key"])
    # duplicate content collapsed: 8 jobs, 7 distinct keys
    assert tree["jobs/3/out.bin"]["key"] == tree["jobs/7/out.bin"]["key"]
    assert len(keys) == n - 1
    # no duplicate/stray loose writes in the annex: exactly the final
    # objects, no tmp leftovers
    on_disk = []
    for dirpath, _, files in os.walk(repo.annex.root):
        on_disk.extend(files)
    assert sorted(on_disk) == sorted(keys)

    # serialized ref publication: a single linear first-parent chain from
    # HEAD back to base containing all 8 job commits
    chain = []
    oid = repo.head_commit()
    while oid != base:
        c = repo.objects.get_commit(oid)
        assert len(c["parents"]) == 1
        chain.append(oid)
        oid = c["parents"][0]
    assert len(chain) == n


def test_concurrent_unfiltered_finish_commits_each_job_once(tmp_path):
    """Two racing finish() calls with NO job filter both see the same open
    jobs; the commit/close decision is made exactly once per job under the
    ref lock — never two reproducibility records for one job. Half the jobs
    stage through --alt-dir, so the race also covers two finishers
    absorbing the same staged files (the loser falls back to the worktree
    copy the winner renamed into place)."""
    import threading

    repo = Repository.init(str(tmp_path / "repo"), annex_threshold=512)
    write(repo.root, "README", "seed\n")
    base = repo.save(message="base")
    cluster = LocalSlurmCluster(max_workers=6, sbatch_cost_s=0.0, sacct_cost_s=0.0)
    sched = SlurmScheduler(repo, cluster, ingest_workers=2)
    alt = str(tmp_path / "stage")
    n = 6
    specs = []
    for j in range(n):
        make_job_script(repo.root, f"jobs/{j}/slurm.sh",
                        f"echo result-{j} > out.txt")
        specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}/out.txt"],
                             pwd=f"jobs/{j}", alt_dir=alt if j % 2 else None))
    sched.submit_many(specs)
    cluster.wait(timeout=60)

    barrier = threading.Barrier(2)
    all_results, errors = [], []

    def finish_all():
        try:
            barrier.wait()
            all_results.extend(sched.finish())
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=finish_all) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    cluster.shutdown()
    assert not errors
    assert sched.db.open_jobs() == []
    committed = [r for r in all_results if r.commit]
    assert len(committed) == n  # each job committed exactly once, anywhere
    assert len({r.job_id for r in committed}) == n
    # and the published chain holds exactly n commits over the base
    chain = 0
    oid = repo.head_commit()
    while oid != base:
        c = repo.objects.get_commit(oid)
        assert len(c["parents"]) == 1
        chain += 1
        oid = c["parents"][0]
    assert chain == n


def test_fused_alt_dir_unions_worktree_files(env, tmp_path):
    """A directory output holding files in BOTH the alt staging tree and
    the worktree commits the union (alt wins per-path), exactly like the
    legacy copy-back + stage protocol."""
    repo, cluster, sched = env
    alt = str(tmp_path / "stage")
    make_job_script(repo.root, "res/slurm.sh", "echo from-alt > alt.txt")
    job_id = sched.schedule("slurm.sh", outputs=["res"], pwd="res", alt_dir=alt)
    cluster.wait(timeout=30)
    # a worktree-only file appears under the output dir before finish
    write(repo.root, "res/wt.txt", "from-worktree\n")
    (res,) = sched.finish(job_id=job_id)
    assert res.commit
    tree = repo.tree_of(res.commit)
    assert "res/alt.txt" in tree and "res/wt.txt" in tree
    assert open(os.path.join(repo.root, "res/alt.txt")).read() == "from-alt\n"
