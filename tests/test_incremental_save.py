"""Equivalence of the incremental commit engine with a from-scratch rebuild.

The incremental engine (DESIGN.md §4) must be a pure optimization: for any
sequence of adds, modifications, deletions, nested directories, and
annex-pointer files, ``save(engine="incremental")`` has to emit a tree oid
byte-identical to ``save(engine="full")`` on the same content. Two mirrored
repositories receive the same edits; after every step their tree oids and
flat tree maps are compared.
"""
import os
import random

import pytest

from repro.core.annex import make_pointer
from repro.core.fsio import GPFS, SimClock
from repro.core.hashing import annex_key_for_bytes
from repro.core.repo import Repository


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(p, mode) as f:
        f.write(data)


def delete(root, rel):
    os.unlink(os.path.join(root, rel))


def tree_oid(repo, commit_oid):
    return repo.objects.get_commit(commit_oid)["tree"]


@pytest.fixture
def pair(tmp_path):
    """Two repositories receiving identical edits: one saves incrementally,
    the other does full rebuilds."""
    a = Repository.init(str(tmp_path / "inc"), annex_threshold=64)
    b = Repository.init(str(tmp_path / "full"), annex_threshold=64)
    return a, b


def both(pair, fn):
    for repo in pair:
        fn(repo.root)


def save_both(pair, paths=None):
    a, b = pair
    ca = a.save(paths=paths, message="step", engine="incremental")
    cb = b.save(paths=paths, message="step", engine="full")
    assert tree_oid(a, ca) == tree_oid(b, cb)
    assert a.tree_of(ca) == b.tree_of(cb)
    return ca, cb


def test_incremental_equals_full_across_edit_sequence(pair):
    # adds, nested dirs, annexed (>= threshold) files
    both(pair, lambda r: write(r, "a.txt", "small"))
    both(pair, lambda r: write(r, "dir/sub/deep/x.txt", "nested"))
    both(pair, lambda r: write(r, "dir/big.bin", b"\x01" * 200))  # annexed
    save_both(pair)

    # modify one file in a deep spine; siblings must keep their oids
    both(pair, lambda r: write(r, "dir/sub/deep/x.txt", "changed"))
    save_both(pair, paths=["dir/sub/deep/x.txt"])

    # add a sibling subtree
    both(pair, lambda r: write(r, "dir/sub2/y.txt", "sibling"))
    save_both(pair, paths=["dir/sub2"])

    # deletions are only visible to worktree-wide saves
    both(pair, lambda r: delete(r, "a.txt"))
    both(pair, lambda r: delete(r, "dir/sub/deep/x.txt"))
    save_both(pair)

    # annex-pointer file staged as-is (content not present)
    key = annex_key_for_bytes(b"remote content")
    both(pair, lambda r: write(r, "ptr.bin", make_pointer(key)))
    ca, _ = save_both(pair, paths=["ptr.bin"])
    assert pair[0].tree_of(ca)["ptr.bin"] == {"t": "annex", "key": key}


def test_file_dir_replacement_keeps_engines_equivalent(pair):
    # commit a file, replace it with a directory, stage a path inside it
    both(pair, lambda r: write(r, "a", "plain file"))
    save_both(pair)
    both(pair, lambda r: delete(r, "a"))
    both(pair, lambda r: write(r, "a/b", "now nested"))
    ca, _ = save_both(pair, paths=["a/b"])
    flat = pair[0].tree_of(ca)
    assert "a/b" in flat and "a" not in flat  # dir replaced the stale blob

    # and back: replace the directory with a file, partial save
    both(pair, lambda r: delete(r, "a/b"))
    both(pair, lambda r: os.rmdir(os.path.join(r, "a")))
    both(pair, lambda r: write(r, "a", "file again"))
    ca, _ = save_both(pair, paths=["a"])
    flat = pair[0].tree_of(ca)
    assert flat["a"]["t"] == "blob" and "a/b" not in flat

    # worktree-wide save also notices a tracked file turned directory
    both(pair, lambda r: delete(r, "a"))
    both(pair, lambda r: write(r, "a/c", "dir via full save"))
    ca, _ = save_both(pair)
    flat = pair[0].tree_of(ca)
    assert "a/c" in flat and "a" not in flat

    # ... and a tracked directory turned file (deletions-only group under a
    # direct entry must not be treated as a file/directory conflict)
    both(pair, lambda r: delete(r, "a/c"))
    both(pair, lambda r: os.rmdir(os.path.join(r, "a")))
    both(pair, lambda r: write(r, "a", "dir became file"))
    ca, _ = save_both(pair)
    flat = pair[0].tree_of(ca)
    assert flat["a"]["t"] == "blob" and "a/c" not in flat


def test_incremental_equals_full_randomized(pair):
    """Property-style: a random edit script (adds/overwrites/deletes across a
    small path universe, mixed blob/annex sizes) keeps both engines in
    lockstep at every commit."""
    rng = random.Random(1234)
    universe = [
        f"{d}/{s}/f{i}.dat" if s else f"{d}/f{i}.dat"
        for d in ("p", "q/r", "q/z")
        for s in ("", "inner")
        for i in range(3)
    ]
    live: set[str] = set()
    for step in range(12):
        n_edits = rng.randint(1, 4)
        for _ in range(n_edits):
            path = rng.choice(universe)
            if path in live and rng.random() < 0.3:
                both(pair, lambda r, p=path: delete(r, p))
                live.discard(path)
            else:
                size = rng.choice([10, 30, 100, 300])  # blob or annexed
                payload = bytes([rng.randrange(256)]) * size
                both(pair, lambda r, p=path, d=payload: write(r, p, d))
                live.add(path)
        save_both(pair)  # worktree-wide: sees deletions too


def test_incremental_save_touches_only_dirty_spine(tmp_path):
    """The perf contract: an incremental save of one changed file performs
    O(depth) object-store ops, not O(repo files)."""
    clock = SimClock()
    repo = Repository.init(str(tmp_path / "repo"), profile=GPFS, clock=clock)
    for i in range(40):
        write(repo.root, f"jobs/{i:02d}/out.txt", f"result {i}")
    repo.save(message="all jobs")
    write(repo.root, "jobs/00/out.txt", "changed")
    ops_before = clock.meta_ops
    repo.save(paths=["jobs/00/out.txt"], message="one job")
    ops = clock.meta_ops - ops_before
    # stat (size routes the §9 staging) + read file + blob put + 3 spine
    # trees + commit + 2 ref ops, NOT ~40 dirs
    assert ops < 27, f"incremental save issued {ops} metadata ops"


def test_batched_finish_equals_sequential_tree(tmp_path):
    """Chained in-memory commits (the scheduler's batched finish) produce the
    same trees as one-at-a-time saves."""
    a = Repository.init(str(tmp_path / "a"))
    b = Repository.init(str(tmp_path / "b"))
    for r in (a, b):
        write(r.root, "base.txt", "base")
        r.save(message="base")
        for j in range(3):
            write(r.root, f"out/{j}.txt", f"val {j}")

    # a: plain sequential saves
    seq = [a.save(paths=[f"out/{j}.txt"], message=f"j{j}") for j in range(3)]
    # b: batched chain via commit_changes + single ref write
    base = b.head_commit()
    head, head_tree = base, b.objects.get_commit(base)["tree"]
    chain = []
    for j in range(3):
        changes = b.stage_paths([f"out/{j}.txt"])
        head, head_tree = b.commit_changes(
            changes, message=f"j{j}", base_commit=head, base_tree=head_tree
        )
        chain.append(head)
    b.set_branch(b.current_branch(), head)
    for ca, cb in zip(seq, chain):
        assert tree_oid(a, ca) == tree_oid(b, cb)
    assert a.tree_of(seq[-1]) == b.tree_of(chain[-1])


def test_merge_octopus_incremental_matches_union(tmp_path):
    repo = Repository.init(str(tmp_path / "repo"))
    write(repo.root, "base.txt", "base")
    base = repo.save(message="base")
    for j in range(4):
        repo.create_branch(f"job/{j}", at=base)
        write(repo.root, f"out/{j}.txt", f"output {j}")
        repo.save(paths=[f"out/{j}.txt"], message=f"job {j}", branch=f"job/{j}")
    m = repo.merge_octopus([f"job/{j}" for j in range(4)], message="octopus")
    flat = repo.tree_of(m)
    assert flat["base.txt"]["t"] == "blob"
    assert {f"out/{j}.txt" for j in range(4)} <= set(flat)
    assert len(repo.objects.get_commit(m)["parents"]) == 5
    # merged outputs are materialized in the worktree
    assert open(os.path.join(repo.root, "out/3.txt")).read() == "output 3"
