"""Remote annex tier tests (DESIGN.md §13).

Properties under test: chunk-level pushes move only absent content,
presence is one batched round trip, pulls fail over across replicas when a
site dies, drops are numcopies-safe against *fresh* probes only (cached
presence can never authorize one), stranded remote tmps are swept on the
next open, transfer retry/backoff charges are deterministic per seed, and
the jobdb location index stays a hint tier that verify() cross-checks.
"""
import os
import sqlite3

import pytest

import repro
from repro.core import NetFaultRule, NetProfile, NetworkFaultModel
from repro.core.chunks import ChunkParams
from repro.core.faults import (
    InjectedNetworkError,
    RemoteUnavailable,
    kill_token,
)
from repro.core.fsio import FS, NULL_FS, SimClock
from repro.core.jobdb import JobDB
from repro.core.remote import LAN, RemoteStore, push_keys
from repro.core.repo import Repository
from repro.core.session import Session
from repro.core import slurm as S


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


def make_session(tmp_path, net_faults=None, chunked=False, numcopies=1,
                 clock=None):
    root = str(tmp_path / "proj")
    os.makedirs(root, exist_ok=True)
    kw = {}
    if chunked:
        kw = dict(
            chunk_threshold=1 << 12,
            chunk_params=ChunkParams(min_size=1 << 9, avg_bits=10,
                                     max_size=1 << 13),
        )
    s = repro.open(
        root, create=True, annex_threshold=64, net_faults=net_faults,
        numcopies=numcopies, clock=clock, **kw,
    )
    return root, s


# --------------------------------------------------------------- push / pull
def test_push_pull_roundtrip_and_cold_restore(tmp_path):
    root, s = make_session(tmp_path)
    write(root, "data/a.dat", "a" * 500)
    write(root, "data/b.dat", "b" * 300)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA", net="lan")
    reports = s.push()
    assert len(reports) == 1 and reports[0]["keys_sent"] == 2
    assert reports[0]["bytes_sent"] == 800

    # idempotent: a second push moves nothing (batched presence pre-pass)
    r2 = s.push()[0]
    assert r2["keys_sent"] == 0 and r2["keys_skipped"] == 2
    assert r2["bytes_sent"] == 0

    # cold restore: drop local copies (replica verified), then fetch back
    s.drop("data/a.dat")
    s.drop("data/b.dat")
    ka = s.repo.annex_key_at("data/a.dat")
    assert not s.repo.annex.has(ka, fresh=True)
    rep = s.fetch()
    assert rep["keys_fetched"] == 2 and rep["bytes_received"] == 800
    s.repo.annex_get("data/a.dat")
    with open(os.path.join(root, "data/a.dat")) as f:
        assert f.read() == "a" * 500


def test_incremental_push_moves_only_changed_chunks(tmp_path):
    root, s = make_session(tmp_path, chunked=True)
    blob = bytearray(os.urandom(1 << 16))  # 64 KiB -> dozens of chunks
    with open(os.path.join(root, "big.dat"), "wb") as f:
        f.write(blob)
    s.save(message="v1")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA", net="lan")
    r1 = s.push()[0]
    assert r1["chunks_sent"] > 4
    cold_bytes = r1["bytes_sent"]
    assert cold_bytes >= len(blob)

    # ~1% churn: the content-defined cutter keeps most chunk boundaries,
    # so the second push moves a small fraction of the cold bytes
    blob[100:200] = os.urandom(100)
    with open(os.path.join(root, "big.dat"), "wb") as f:
        f.write(blob)
    s.save(message="v2")
    r2 = s.push()[0]
    assert r2["keys_sent"] == 1
    assert 0 < r2["bytes_sent"] < 0.5 * cold_bytes
    # the remote can reassemble the new version faithfully
    key = s.repo.annex_key_at("big.dat")
    out = str(tmp_path / "reassembled")
    store.copy_to(key, out)
    with open(out, "rb") as f:
        assert f.read() == bytes(blob)


def test_has_many_is_one_round_trip(tmp_path):
    clock = SimClock()
    root, s = make_session(tmp_path, clock=clock)
    for i in range(20):
        write(root, f"f{i}.dat", f"{i}" * 100)
    s.save(message="seed")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA",
                         net=NetProfile(name="slow", latency_s=0.5,
                                        up_bw=1e9, down_bw=1e9))
    keys = [s.repo.annex_key_at(f"f{i}.dat") for i in range(20)]
    t0 = store.fs.clock.total
    assert store.has_many(keys, fresh=True) == set()
    elapsed = store.fs.clock.total - t0
    # 20 per-key round trips would cost >= 10 s; the batch costs ~1 RTT
    assert elapsed < 2 * 0.5


# ------------------------------------------------------- failover / faults
def test_pull_fails_over_to_live_replica(tmp_path):
    # only pulls issue "recv" requests, so the outage hits the pull's first
    # download attempt from siteA — after the pushes completed cleanly
    model = NetworkFaultModel(
        seed=3,
        rules=[NetFaultRule(op="recv", remote="siteA", kind="outage", nth=1)],
    )
    root, s = make_session(tmp_path, net_faults=model)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA", net="lan")
    s.add_remote(str(tmp_path / "siteB"), name="siteB", net="wan")
    s.push()  # both replicas hold the content
    s.drop("x.dat", force=True)

    # siteA dies on the pull's download request:
    # the pull must complete from siteB, reporting the failover
    rep = s.pull()
    assert rep["keys_fetched"] == 1
    assert rep["failovers"] >= 1
    assert set(rep["sources"].values()) == {"siteB"}
    a = s.repo.remote_by_name("siteA")
    assert not a.available

    # push to every *available* remote skips the dead one
    write(root, "y.dat", "y" * 200)
    s.save(message="more")
    reports = s.push()
    assert [r["remote"] for r in reports] == ["siteB"]
    # an explicit push to the dead site surfaces the outage
    with pytest.raises(RemoteUnavailable):
        s.push(remote="siteA")


def test_pull_raises_when_no_replica_serves(tmp_path):
    root, s = make_session(tmp_path)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    s.drop("x.dat", force=True)
    s.repo.remote_by_name("siteA").mark_unavailable()
    with pytest.raises((RemoteUnavailable, FileNotFoundError)):
        s.pull()


def test_transient_errors_retried_with_seeded_backoff(tmp_path):
    def run(sub):
        clock = SimClock()
        model = NetworkFaultModel(
            seed=11,
            rules=[NetFaultRule(op="send", kind="error", every=2, times=4)],
            max_retries=4,
            backoff_base_s=0.05,
        )
        root, s = make_session(tmp_path / sub, net_faults=model, clock=clock)
        write(root, "x.dat", "x" * 5000)
        write(root, "y.dat", "y" * 5000)
        s.save(message="seed")
        s.add_remote(str(tmp_path / sub / "siteA"), name="siteA", net="lan")
        rep = s.push()[0]
        assert rep["keys_sent"] == 2
        assert rep["retries"] >= 1
        # content landed despite the injected failures
        store = s.repo.remote_by_name("siteA")
        for p in ("x.dat", "y.dat"):
            assert store.has(s.repo.annex_key_at(p), fresh=True)
        return rep["retries"], clock.total

    # same seed, same schedule: retries and backoff *charges* are identical
    assert run("r1") == run("r2")


def test_stall_charges_clock_and_times_out(tmp_path):
    clock = SimClock()
    net = NetProfile(name="flaky", latency_s=1e-3, up_bw=1e9, down_bw=1e9,
                     timeout_s=2.0)
    model = NetworkFaultModel(
        seed=0,
        rules=[
            # first request hangs past the timeout (transient, retried);
            # the retry stalls 0.5 s but completes. The second rule's
            # counter only advances on requests the first rule let through,
            # so nth=1 means "the retry".
            NetFaultRule(op="send", kind="stall", stall_s=10.0, nth=1,
                         times=1),
            NetFaultRule(op="send", kind="stall", stall_s=0.5, nth=1,
                         times=1),
        ],
    )
    root, s = make_session(tmp_path, net_faults=model, clock=clock)
    write(root, "x.dat", "x" * 300)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA", net=net)
    t0 = clock.total
    rep = s.push()[0]
    assert rep["keys_sent"] == 1 and rep["retries"] == 1
    # the client waited: a full timeout (2 s, not the 10 s stall), one
    # backoff, and the 0.5 s second stall are all on the clock
    assert clock.total - t0 >= 2.0 + 0.5


def test_retries_exhausted_surface_the_error(tmp_path):
    model = NetworkFaultModel(
        seed=0, max_retries=2,
        rules=[NetFaultRule(op="send", kind="error")],  # every send fails
    )
    root, s = make_session(tmp_path, net_faults=model)
    write(root, "x.dat", "x" * 300)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    with pytest.raises(InjectedNetworkError):
        s.push()


# ------------------------------------------------------------ numcopies
def test_drop_refused_until_replica_verified(tmp_path):
    root, s = make_session(tmp_path)  # numcopies = 1
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    with pytest.raises(RuntimeError, match="refusing to drop"):
        s.drop("x.dat")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    with pytest.raises(RuntimeError, match="refusing to drop"):
        s.drop("x.dat")  # remote configured but still empty
    s.push()
    s.drop("x.dat")  # one verified replica satisfies numcopies=1
    key = s.repo.annex_key_at("x.dat")
    assert not s.repo.annex.has(key, fresh=True)


def test_numcopies_two_requires_two_replicas(tmp_path):
    root, s = make_session(tmp_path, numcopies=2)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    with pytest.raises(RuntimeError, match="numcopies=2"):
        s.drop("x.dat")
    s.add_remote(str(tmp_path / "siteB"), name="siteB")
    s.push(remote="siteB")
    s.drop("x.dat")


def test_stale_cached_presence_cannot_authorize_drop(tmp_path):
    """The drop-safety property: the remote's known-key set is warm (the
    push populated it), then the replica loses the object behind our back.
    A presence cache must never authorize the drop — verified_copies goes
    through fresh probes, sees the loss, and refuses."""
    root, s = make_session(tmp_path)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    key = s.repo.annex_key_at("x.dat")
    assert store._is_known(key)  # cached presence says it is there
    os.remove(store._path(key))  # the site silently lost it
    assert store.has(key) is True  # the stale cache still lies...
    with pytest.raises(RuntimeError, match="refusing to drop"):
        s.drop("x.dat")  # ...but can not authorize the drop
    # an unreachable replica confirms nothing either
    write(root, "y.dat", "y" * 200)
    s.save(message="more")
    s.push()
    store.mark_unavailable()
    with pytest.raises(RuntimeError, match="refusing to drop"):
        s.drop("y.dat")


def test_unavailable_remote_confirms_nothing(tmp_path):
    model = NetworkFaultModel(
        seed=0, max_retries=1,
        rules=[NetFaultRule(op="query", kind="error")],  # probes all fail
    )
    root, s = make_session(tmp_path, net_faults=model)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    with pytest.raises((RuntimeError, InjectedNetworkError)):
        s.drop("x.dat")


# -------------------------------------------------- stranded remote tmps
def test_disconnect_strands_remote_tmp_swept_on_open(tmp_path):
    """A mid-stream disconnect kills the link before the remote-side tmp is
    published or cleaned (a dead link runs no remote cleanup). The tmp is
    owner-stamped; once the writer is provably dead, the next open of the
    store sweeps it."""
    model = NetworkFaultModel(
        seed=0, max_retries=0,
        rules=[NetFaultRule(op="send", kind="disconnect", nth=2, times=1)],
    )
    root, s = make_session(tmp_path, net_faults=model)
    write(root, "x.dat", "x" * (3 << 20))  # several streamed blocks
    s.save(message="seed")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA")
    with pytest.raises(InjectedNetworkError, match="disconnect"):
        s.push()
    litter = [n for n in os.listdir(store.root) if n.startswith("tmp-")]
    assert len(litter) == 1  # the half-uploaded object is stranded

    # same incarnation still owns the tmp: a sweep must NOT reclaim it
    assert store.count_stale_tmps(max_age_s=None) == 0

    # the client dies; reopening the site store reclaims the litter
    kill_token(store.fs.token)
    store2 = RemoteStore(store.root, name="siteA")
    assert [n for n in os.listdir(store2.root)
            if n.startswith("tmp-")] == []

    # and the interrupted push now completes exactly-once
    s2 = Session(Repository(root, fs=FS(NULL_FS)))
    rep = s2.recover()
    assert rep["pushes_resumed"] == 1
    assert s2.verify()["divergence"] == 0
    key = s2.repo.annex_key_at("x.dat")
    assert s2.repo.remote_by_name("siteA").has(key, fresh=True)


# ----------------------------------------------------- locations / whereis
def test_locations_recorded_and_whereis(tmp_path):
    root, s = make_session(tmp_path)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    key = s.repo.annex_key_at("x.dat")
    w = s.whereis(["x.dat"])
    assert set(w[key]["stores"]) == {"local", "siteA"}
    assert w[key]["recorded"] == ["siteA"]
    # drop + pull moves the copy; whereis keeps both views coherent
    s.drop("x.dat")
    w = s.whereis(["x.dat"], fresh=True)
    assert w[key]["stores"] == ["siteA"]
    s.pull()
    w = s.whereis(["x.dat"], fresh=True)
    assert set(w[key]["stores"]) == {"local", "siteA"}


def test_verify_flags_stale_locations_as_warning(tmp_path):
    root, s = make_session(tmp_path)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    key = s.repo.annex_key_at("x.dat")
    os.remove(store._path(key))  # site lost the object; the hint is stale
    rep = s.verify()
    kinds = [i["kind"] for i in rep["issues"]]
    assert "stale-location" in kinds
    # the hint tier is a warning, never divergence
    assert rep["divergence"] == 0
    s.verify(repair=True)
    db = JobDB(s.repo.repro_dir)
    assert db.locations_of([key])[key] == []


def test_verify_repairs_remote_manifest_divergence(tmp_path):
    root, s = make_session(tmp_path, chunked=True)
    with open(os.path.join(root, "big.dat"), "wb") as f:
        f.write(os.urandom(1 << 15))
    s.save(message="seed")
    store = s.add_remote(str(tmp_path / "siteA"), name="siteA")
    s.push()
    key = s.repo.annex_key_at("big.dat")
    truth = s.repo.annex.manifest_of(key)
    assert truth is not None
    # corrupt the remote manifest: rebind the key to a subset of chunks
    from repro.core.annex import encode_chunk_manifest

    bad = encode_chunk_manifest(key, truth[:1], store.chunk_params)
    with open(store._path(key), "wb") as f:
        f.write(bad)
    rep = s.verify()
    assert "remote-manifest-divergence" in [i["kind"] for i in rep["issues"]]
    assert rep["divergence"] > 0
    s.verify(repair=True)
    rep2 = s.verify()
    assert rep2["divergence"] == 0
    assert store.manifest_of(key) == truth


# --------------------------------------------------------- jobdb migration
def test_jobdb_v3_to_v4_migration(tmp_path):
    repro_dir = str(tmp_path / ".repro")
    os.makedirs(repro_dir)
    db_path = os.path.join(repro_dir, "jobdb.sqlite")
    JobDB(repro_dir)  # lands at the current version
    conn = sqlite3.connect(db_path)
    assert conn.execute("PRAGMA user_version").fetchone()[0] == 5
    # rebuild a v3-shaped db: runcache present, no annex_locations and no
    # pipeline tier tables
    conn.execute("DROP TABLE annex_locations")
    conn.execute("DROP TABLE job_deps")
    conn.execute("DROP TABLE job_pipeline")
    conn.execute("PRAGMA user_version = 0")  # force shape detection
    conn.commit()
    conn.close()
    db = JobDB(repro_dir)
    db.locations_record("siteA", ["SHA256-s1--ab"])
    assert db.locations_of(["SHA256-s1--ab"]) == {"SHA256-s1--ab": ["siteA"]}
    db.locations_forget("siteA")
    assert db.locations_all() == []
    conn = sqlite3.connect(db_path)
    assert conn.execute("PRAGMA user_version").fetchone()[0] == 5
    conn.close()


# ------------------------------------------------------- scheduler hookup
def test_finish_push_to_replicates_outputs(tmp_path):
    root, s = make_session(tmp_path)
    s.add_remote(str(tmp_path / "backup"), name="backup")
    write(root, "j.sh", "#!/bin/bash\nprintf 'x%.0s' {1..300} > out.dat\n")
    job_ids = s.submit_many(
        [repro.RunSpec(script="j.sh", outputs=["out.dat"])]
    )
    s.wait()
    res = s.finish(push_to="backup")
    assert all(r.state == S.COMPLETED for r in res)
    key = s.repo.annex_key_at("out.dat")
    assert s.repo.remote_by_name("backup").has(key, fresh=True)
    # the location index learned about the replica
    assert "backup" in s.scheduler.db.locations_of([key])[key]
    del job_ids
    s.close()


# ----------------------------------------------------------- config plumb
def test_remotes_persist_in_config_and_reopen(tmp_path):
    root, s = make_session(tmp_path)
    s.add_remote(str(tmp_path / "siteA"), name="siteA", net="wan")
    with pytest.raises(ValueError, match="duplicate|already|siteA"):
        s.add_remote(str(tmp_path / "elsewhere"), name="siteA")
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    s.push()
    s2 = repro.open(root)
    store = s2.repo.remote_by_name("siteA")
    assert store.net.name == "wan"
    key = s2.repo.annex_key_at("x.dat")
    assert store.has(key, fresh=True)


def test_push_pull_against_plain_store_still_works(tmp_path):
    """net_retry and the transfer orchestration degrade gracefully to a
    plain same-filesystem AnnexStore (no fault model, no retries)."""
    from repro.core.annex import AnnexStore

    root, s = make_session(tmp_path)
    write(root, "x.dat", "x" * 400)
    s.save(message="seed")
    plain = AnnexStore(str(tmp_path / "plain"), FS(NULL_FS), name="plain")
    rep = push_keys(s.repo, plain, journal=False)
    assert rep["keys_sent"] == 1
    assert plain.has(s.repo.annex_key_at("x.dat"), fresh=True)
