"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, shape + finiteness assertions, and
decode-vs-forward consistency (the serving path computes the same function
as the training path)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.models.params import init_params
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step

ARCHS = configs.ARCH_IDS


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )
    }
    if cfg.enc_dec:
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, S // cfg.enc_len_ratio, cfg.d_model)),
            jnp.bfloat16,
        )
    if cfg.vision_len_ratio:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, S // cfg.vision_len_ratio, cfg.d_model)),
            jnp.bfloat16,
        )
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
        batch["positions3"] = jnp.asarray(np.broadcast_to(pos, (3, B, S)))
    return batch


@pytest.fixture(scope="module")
def model(request):
    return None


def _setup(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(T.param_defs(cfg), seed=0)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: T.forward_train(cfg, None, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/Inf in logits"
    assert bool(jnp.isfinite(aux)), "NaN aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_and_stays_finite(arch):
    cfg, params = _setup(arch)
    opt = AdamW(lr=5e-3, moment_dtype=cfg.opt_moment_dtype)
    step_fn = jax.jit(make_train_step(cfg, None, opt))
    opt_state = opt.init(params)
    batch = make_batch(cfg, B=2, S=32)
    losses = []
    for _ in range(5):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    # same batch 5x: loss must drop (sanity that grads flow through every path)
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill(0..t-1) + decode_step(t) must reproduce forward logits at t.

    Run in fp32: in bf16 the two paths are *numerically* different programs
    (GEMV vs GEMM reduction order) and discrete top-k routing amplifies the
    rounding noise; fp32 isolates actual wiring errors."""
    cfg = configs.get_smoke(arch)
    params = init_params(T.param_defs(cfg), seed=0, dtype=jnp.float32)
    B, S = 2, 32
    n_decode = 4
    batch = make_batch(cfg, B, S)
    full_logits, _ = jax.jit(lambda p, b: T.forward_train(cfg, None, p, b))(
        params, batch
    )
    prompt = S - n_decode
    pbatch = dict(batch)
    pbatch["tokens"] = batch["tokens"][:, :prompt]
    if cfg.vision_len_ratio:
        pbatch["positions3"] = batch["positions3"][:, :, :prompt]
    caches, logits = jax.jit(
        lambda p, b: T.prefill(cfg, None, p, b, cache_len=S)
    )(params, pbatch)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, None, p, c, t, pos))

    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, prompt - 1], np.float32),
        rtol=3e-2, atol=3e-2,
        err_msg=f"{arch}: prefill last-logits mismatch",
    )
    for i in range(n_decode - 1):
        tok = batch["tokens"][:, prompt + i : prompt + i + 1]
        logits, caches = step(params, caches, tok, jnp.asarray(prompt + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, prompt + i], np.float32),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch}: decode step {i} mismatch",
        )


@pytest.mark.parametrize("arch", ["internlm2_20b", "rwkv6_1_6b", "jamba_1_5_large_398b"])
def test_scan_vs_unrolled_layers(arch):
    """lax.scan over stacked layers == python-loop over layers."""
    cfg, params = _setup(arch)
    batch = make_batch(cfg, B=1, S=16)
    l_scan, _ = jax.jit(lambda p, b: T.forward_train(cfg, None, p, b))(params, batch)
    cfg2 = cfg.replace(scan_layers=False)
    l_unroll, _ = jax.jit(lambda p, b: T.forward_train(cfg2, None, p, b))(params, batch)
    np.testing.assert_allclose(
        np.asarray(l_scan, np.float32), np.asarray(l_unroll, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_sliding_window_limits_attention():
    """With SWA, a token far outside the window cannot influence logits."""
    cfg = configs.get_smoke("mixtral_8x22b")  # window = 8
    params = init_params(T.param_defs(cfg), seed=0)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 32))
    t2 = toks.copy()
    t2[0, 0] = (t2[0, 0] + 7) % cfg.vocab_size  # mutate a token outside window
    f = jax.jit(lambda p, b: T.forward_train(cfg, None, p, b))
    l1, _ = f(params, {"tokens": jnp.asarray(toks, jnp.int32)})
    l2, _ = f(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    np.testing.assert_array_equal(
        np.asarray(l1[0, -1], np.float32), np.asarray(l2[0, -1], np.float32)
    )


def test_full_configs_match_assignment_table():
    rows = {
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen3_0_6b": (28, 1024, 16, 8, 3072, 151936),
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6_1_6b": (24, 2048, 32, 32, 7168, 65536),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, D, H, KV, F, V) in rows.items():
        cfg = configs.get(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
               cfg.vocab_size)
        assert got == (L, D, H, KV, F, V), (arch, got)
    # MoE details
    assert configs.get("arctic_480b").moe.n_experts == 128
    assert configs.get("arctic_480b").moe.dense_residual
    assert configs.get("mixtral_8x22b").moe.n_experts == 8
    assert configs.get("jamba_1_5_large_398b").moe.n_experts == 16
    # published-size sanity: param_counts within 5% of the checkpoint sizes
    for arch, total_b in [("internlm2_20b", 19.9), ("qwen3_0_6b", 0.6),
                          ("arctic_480b", 480), ("mixtral_8x22b", 141),
                          ("jamba_1_5_large_398b", 398)]:
        n = configs.get(arch).param_counts()["total"] / 1e9
        assert abs(n - total_b) / total_b < 0.08, (arch, n)
