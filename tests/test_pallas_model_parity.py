"""End-to-end parity: the model with Pallas kernels forced on (interpret mode
on CPU) must match the pure-jnp paths."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.models.params import init_params


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mixtral_8x22b", "rwkv6_1_6b",
                                  "jamba_1_5_large_398b"])
def test_pallas_on_vs_off(arch):
    cfg_off = configs.get_smoke(arch).replace(use_pallas="off")
    cfg_on = cfg_off.replace(use_pallas="on")
    params = init_params(T.param_defs(cfg_off), seed=0, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    B, S = 2, 64  # multiple of every kernel chunk
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg_off.vocab_size, (B, S)), jnp.int32)}
    l_off, _ = jax.jit(lambda p, b: T.forward_train(cfg_off, None, p, b))(params, batch)
    l_on, _ = jax.jit(lambda p, b: T.forward_train(cfg_on, None, p, b))(params, batch)
    np.testing.assert_allclose(
        np.asarray(l_on, np.float32), np.asarray(l_off, np.float32),
        rtol=2e-3, atol=2e-3,
    )
