"""Tests for §5.5 protected names/prefixes conflict detection, including
hypothesis property tests of the invariants."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core.conflicts import (
    OutputConflict,
    ProtectedOutputs,
    WildcardOutputError,
    normalize,
    proper_prefixes,
)


def test_normalize():
    assert normalize("./dira/dirb/dirc/") == "dira/dirb/dirc"
    assert normalize("a/./b/../c") == "a/c"
    with pytest.raises(ValueError):
        normalize("../escape")
    with pytest.raises(ValueError):
        normalize(".")


def test_proper_prefixes_matches_paper_example():
    # paper §5.5: ./dira/dirb/dirc/ -> [./dira/dirb/, ./dira/]
    assert proper_prefixes("dira/dirb/dirc") == ["dira/dirb", "dira"]
    assert proper_prefixes("file.txt") == []


def test_check_1_same_name_conflicts():
    p = ProtectedOutputs()
    p.check_and_add_all(["out/dir1"], job_id=1)
    with pytest.raises(OutputConflict) as e:
        p.check("out/dir1")
    assert e.value.other_job == 1


def test_check_2_superdirectory_of_other_job():
    p = ProtectedOutputs()
    p.check_and_add_all(["dira/dirb/dirc"], job_id=1)
    # claiming dira/dirb would claim a super-directory of job 1's output
    with pytest.raises(OutputConflict):
        p.check("dira/dirb")
    with pytest.raises(OutputConflict):
        p.check("dira")


def test_check_3_subdirectory_of_claimed_dir():
    p = ProtectedOutputs()
    p.check_and_add_all(["dira/dirb"], job_id=1)
    # job 1 owns dira/dirb exclusively incl. everything inside (§5.5)
    with pytest.raises(OutputConflict):
        p.check("dira/dirb/deeper/file.txt")


def test_disjoint_directories_coexist():
    p = ProtectedOutputs()
    p.check_and_add_all(["jobs/1/out"], job_id=1)
    p.check_and_add_all(["jobs/2/out"], job_id=2)  # no conflict
    p.check_and_add_all(["jobs/1b"], job_id=3)  # sibling with common prefix str
    assert p.names["jobs/2/out"] == 2


def test_release_unprotects():
    p = ProtectedOutputs()
    p.check_and_add_all(["a/b"], job_id=1)
    p.release(1)
    p.check_and_add_all(["a/b"], job_id=2)  # reusable after release (§5.2)


def test_wildcards_rejected():
    p = ProtectedOutputs()
    for bad in ["out/*.csv", "results/?", "d[0-9]/x", "a{b,c}"]:
        with pytest.raises(WildcardOutputError):
            p.check(bad)


def test_intra_job_nesting_rejected():
    p = ProtectedOutputs()
    with pytest.raises(OutputConflict):
        p.check_and_add_all(["a/b", "a/b/c"], job_id=1)
    # failed add must not leave partial protection behind
    p2 = ProtectedOutputs()
    with pytest.raises(OutputConflict):
        p2.check_and_add_all(["x/y", "x/y"], job_id=1)


if HAVE_HYPOTHESIS:
    path_segments = st.lists(
        st.text(alphabet="abcdefg", min_size=1, max_size=3), min_size=1, max_size=4
    )

    @st.composite
    def path_sets(draw):
        return [
            "/".join(draw(path_segments))
            for _ in range(draw(st.integers(min_value=1, max_value=8)))
        ]

    @given(path_sets())
    @settings(max_examples=200, deadline=None)
    def test_property_no_two_jobs_share_overlapping_outputs(paths):
        """Invariant: after any sequence of schedules, no accepted output is
        equal to, an ancestor of, or a descendant of an output owned by a
        different job."""
        p = ProtectedOutputs()
        accepted: dict[str, int] = {}
        for job_id, path in enumerate(paths):
            try:
                p.check_and_add_all([path], job_id)
                accepted[normalize(path)] = job_id
            except OutputConflict:
                pass
        names = list(accepted)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                if accepted[a] == accepted[b]:
                    continue
                assert a != b
                assert not a.startswith(b + "/"), (a, b)
                assert not b.startswith(a + "/"), (a, b)

    @given(path_sets())
    @settings(max_examples=100, deadline=None)
    def test_property_release_restores_schedulability(paths):
        """Anything accepted then released must be acceptable again."""
        p = ProtectedOutputs()
        for job_id, path in enumerate(paths):
            try:
                p.check_and_add_all([path], job_id)
            except OutputConflict:
                continue
            p.release(job_id)
            p.check_and_add_all([path], job_id + 10_000)  # must not raise
            p.release(job_id + 10_000)
