"""Elastic-scaling test: checkpoint on one 'mesh', restore under a DIFFERENT
mesh — run in a subprocess with 8 forced host devices so this test process
keeps its single default device."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.repo import Repository
from repro.distributed.sharding import make_rules
from repro.models import transformer as T
from repro.models.params import init_params, param_shardings
from repro.optim.adamw import AdamW
from repro.train.checkpoint import CheckpointManager

root = sys.argv[1]
cfg = configs.get_smoke("qwen3_0_6b")

# --- "old cluster": 4x2 mesh, train-ish state, checkpoint
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
rules_a = make_rules(mesh_a)
defs_a = T.param_defs(cfg, rules_a)
params = init_params(defs_a, seed=0)
params = jax.device_put(params, param_shardings(defs_a, mesh_a))
opt = AdamW()
opt_state = opt.init(params)
repo = Repository.init(root)
ckpt = CheckpointManager(repo)
ckpt.save(7, params, opt_state, data_step=7)

# --- "new cluster": 2x4 mesh (different shape) — elastic restore
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
rules_b = make_rules(mesh_b)
defs_b = T.param_defs(cfg, rules_b)
shardings = {"params": param_shardings(defs_b, mesh_b),
             "opt_state": {"m": param_shardings(defs_b, mesh_b),
                            "v": param_shardings(defs_b, mesh_b),
                            "step": NamedSharding(mesh_b, P())}}
state, manifest = ckpt.restore(shardings=shardings)
assert manifest["step"] == 7

# bitwise identity across the re-shard
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# and the new leaves actually live on the new mesh
leaf = jax.tree.leaves(state["params"])[0]
assert leaf.sharding.mesh.shape == {"data": 2, "model": 4}, leaf.sharding
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path / "repo")],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_OK" in out.stdout
