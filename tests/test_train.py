"""Training-substrate tests: optimizer, data determinism, checkpoint/restart
(fault tolerance), preemption-resume bitwise identity, elastic re-sharding,
gradient compression, async checkpointing."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.repo import Repository
from repro.data.tokens import SyntheticTokens
from repro.models import transformer as T
from repro.models.params import init_params
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.optim.compression import compress_int8, decompress_int8, ef_compress_tree
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import train_segment
from repro.train.steps import greedy_decode, make_train_step


CFG = configs.get_smoke("qwen3_0_6b")


def leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ------------------------------------------------------------------ data
def test_synthetic_tokens_deterministic_and_shardable():
    ds = SyntheticTokens(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    g1 = ds.global_batch_at(5)
    g2 = ds.global_batch_at(5)
    np.testing.assert_array_equal(g1, g2)
    assert not np.array_equal(g1, ds.global_batch_at(6))
    # shards partition the canonical global batch — elastic re-sharding safe
    parts2 = [ds.shard_batch_at(5, i, 2) for i in range(2)]
    parts4 = [ds.shard_batch_at(5, i, 4) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts2), g1)
    np.testing.assert_array_equal(np.concatenate(parts4), g1)


# ------------------------------------------------------------------ optim
def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5
    assert float(lr(50)) < 1e-3


def test_int8_compression_roundtrip_and_ef():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    deq = decompress_int8(q, s, x.shape)
    assert float(jnp.abs(deq - x).max()) < float(jnp.abs(x).max()) / 100
    # error feedback: residual carries exactly the quantization error
    grads = {"w": x}
    g1, r1 = ef_compress_tree(grads, None)
    np.testing.assert_allclose(
        np.asarray(g1["w"] + r1["w"]), np.asarray(x), rtol=1e-6, atol=1e-6
    )


# ------------------------------------------------------- checkpoint/restart
@pytest.fixture
def repo(tmp_path):
    return Repository.init(str(tmp_path / "repo"), annex_threshold=1024)


def test_checkpoint_roundtrip(repo):
    params = init_params(T.param_defs(CFG), seed=0)
    opt = AdamW()
    opt_state = opt.init(params)
    ckpt = CheckpointManager(repo)
    oid = ckpt.save(10, params, opt_state, data_step=10)
    assert repo.resolve(oid)
    state, manifest = ckpt.restore()
    assert manifest["step"] == 10
    assert leaves_equal(state["params"], params)
    assert leaves_equal(state["opt_state"], opt_state)


def test_checkpoint_dedup_across_steps(repo):
    """Content-addressed annex: identical leaves across checkpoints share
    storage keys (free dedup for unchanged weights)."""
    params = init_params(T.param_defs(CFG), seed=0)
    opt_state = AdamW().init(params)
    ckpt = CheckpointManager(repo)
    ckpt.save(1, params, opt_state)
    n_keys_1 = len(repo.annex.keys())
    ckpt.save(2, params, opt_state)  # identical content
    n_keys_2 = len(repo.annex.keys())
    # every weight leaf deduplicates; only the manifest (contains the step
    # number) is new
    assert n_keys_2 - n_keys_1 <= 1


def test_async_checkpoint(repo):
    params = init_params(T.param_defs(CFG), seed=0)
    opt_state = AdamW().init(params)
    ckpt = CheckpointManager(repo)
    ckpt.save_async(5, params, opt_state)
    ckpt.wait()
    state, manifest = ckpt.restore()
    assert manifest["step"] == 5
    assert leaves_equal(state["params"], params)


def test_async_checkpoint_failure_is_reraised(repo):
    """A write failure on the async worker surfaces at the next sync point
    (wait() or the next save_async) instead of being swallowed."""
    params = {"w": np.ones(4, np.float32)}
    opt_state = {"step": np.int32(0)}
    ckpt = CheckpointManager(repo)
    orig_write = ckpt._write

    def failing(*a, **k):
        raise RuntimeError("injected write failure")

    ckpt._write = failing
    ckpt.save_async(1, params, opt_state)
    with pytest.raises(RuntimeError, match="injected write failure"):
        ckpt.wait()
    ckpt.wait()  # the failure was consumed by the re-raise, not sticky
    # the same failure also surfaces from a back-to-back save_async
    ckpt.save_async(2, params, opt_state)
    with pytest.raises(RuntimeError, match="injected write failure"):
        ckpt.save_async(3, params, opt_state)
    # after recovery the manager is fully usable
    ckpt._write = orig_write
    ckpt.save_async(4, params, opt_state)
    ckpt.wait()
    state, manifest = ckpt.restore()
    assert manifest["step"] == 4
    assert np.array_equal(np.asarray(state["params"]["w"]), params["w"])


def test_checkpoints_cache_is_incremental(repo, monkeypatch):
    """checkpoints() is cached by ref tip: an unchanged HEAD reads zero
    commits, an advanced HEAD walks only the commits added since — so
    latest() in a long campaign never re-scans the whole log."""
    params = {"w": np.arange(8, dtype=np.float32)}
    opt_state = {"step": np.int32(0)}
    ckpt = CheckpointManager(repo)
    for step in (1, 2, 3):
        ckpt.save(step, params, opt_state)
    assert [s for _, s in ckpt.checkpoints()] == [3, 2, 1]

    calls = []
    orig = repo.objects.get_commit
    monkeypatch.setattr(
        repo.objects, "get_commit",
        lambda oid: (calls.append(oid) or orig(oid)),
    )
    assert [s for _, s in ckpt.checkpoints()] == [3, 2, 1]
    assert calls == []  # unchanged head: answered from cache
    ckpt.save(4, params, opt_state)
    calls.clear()
    assert [s for _, s in ckpt.checkpoints()] == [4, 3, 2, 1]
    assert len(calls) == 1  # only the commit added since the last call
    # a fresh manager (cold cache) agrees — the cache is an optimization,
    # not a source of truth
    assert CheckpointManager(repo).checkpoints() == ckpt.checkpoints()


def test_preemption_resume_bitwise_identical(tmp_path):
    """Kill-and-resume == uninterrupted run, bit for bit (deterministic data
    + init + optimizer). This is the paper's reproducibility property applied
    to training jobs."""
    ds = SyntheticTokens(vocab_size=CFG.vocab_size, seq_len=16, global_batch=4, seed=1)

    repo_a = Repository.init(str(tmp_path / "a"))
    res_a = train_segment(repo_a, CFG, ds, n_steps=6, ckpt_every=2, seed=0)

    repo_b = Repository.init(str(tmp_path / "b"))
    train_segment(repo_b, CFG, ds, n_steps=3, ckpt_every=3, seed=0)  # "preempted"
    res_b = train_segment(repo_b, CFG, ds, n_steps=6, ckpt_every=3, seed=0)  # resume

    sa, _ = CheckpointManager(repo_a).restore()
    sb, _ = CheckpointManager(repo_b).restore()
    assert leaves_equal(sa["params"], sb["params"])
    assert leaves_equal(sa["opt_state"]["m"], sb["opt_state"]["m"])
    assert res_a.end_step == res_b.end_step == 6


def test_elastic_restore_respects_shardings(repo):
    """Restore under different 'mesh': leaves land with requested shardings
    (simulated here with single-device shardings; the multi-device version is
    exercised in the dry-run tests via subprocess)."""
    params = init_params(T.param_defs(CFG), seed=0)
    opt_state = AdamW().init(params)
    ckpt = CheckpointManager(repo)
    ckpt.save(1, params, opt_state)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev),
        {"params": params, "opt_state": opt_state},
    )
    state, _ = ckpt.restore(shardings=shardings)
    leaf = jax.tree.leaves(state["params"])[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)
    assert leaves_equal(state["params"], params)


def test_train_segment_loss_decreases(tmp_path):
    repo = Repository.init(str(tmp_path / "r"))
    ds = SyntheticTokens(vocab_size=CFG.vocab_size, seq_len=16, global_batch=4, seed=2)
    res = train_segment(repo, CFG, ds, n_steps=10, ckpt_every=10, seed=0)
    assert np.isfinite(res.final_loss)
    assert res.checkpoint_commit is not None
    # the checkpoint commit carries a machine-actionable record
    from repro.core.records import RunRecord
    rec = RunRecord.from_message(
        repo.objects.get_commit(res.checkpoint_commit)["message"]
    )
    assert rec.extras["checkpoint_step"] == 10


def test_greedy_decode_runs():
    params = init_params(T.param_defs(CFG), seed=0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 8)), jnp.int32)}
    out = greedy_decode(CFG, None, params, batch, n_tokens=4, cache_len=16)
    assert out.shape == (2, 4)
    assert int(out.max()) < CFG.vocab_size


def test_global_norm_matches_numpy():
    tree = {"a": jnp.asarray([3.0]), "b": {"c": jnp.asarray([4.0])}}
    assert abs(float(global_norm(tree)) - 5.0) < 1e-6
