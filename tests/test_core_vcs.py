"""Unit tests for the version store: objects, trees, commits, annex, merges."""
import os

import pytest

from repro.core.annex import AnnexStore, make_pointer, parse_pointer
from repro.core.fsio import FS, GPFS, LOCAL_XFS, NULL_FS, SimClock
from repro.core.hashing import (
    annex_key_for_bytes,
    parse_annex_key,
    verify_annex_key,
)
from repro.core.objects import ObjectStore
from repro.core.repo import ConflictError, Repository


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(p, mode) as f:
        f.write(data)


# ---------------------------------------------------------------- hashing
def test_annex_key_roundtrip():
    data = b"hello world" * 100
    key = annex_key_for_bytes(data)
    size, hx = parse_annex_key(key)
    assert size == len(data)
    assert verify_annex_key(key, data)
    assert not verify_annex_key(key, data + b"x")


def test_pointer_roundtrip():
    key = annex_key_for_bytes(b"payload")
    ptr = make_pointer(key)
    assert parse_pointer(ptr) == key
    assert parse_pointer(b"not a pointer") is None
    assert parse_pointer(b"x" * 10_000) is None


# ---------------------------------------------------------------- objects
def test_object_store_roundtrip(tmp_path):
    store = ObjectStore(str(tmp_path / "objects"), FS(NULL_FS))
    oid = store.put_blob(b"some data")
    assert store.has(oid)
    kind, payload = store.get(oid)
    assert (kind, payload) == ("blob", b"some data")
    # identical content -> identical oid (content addressing)
    assert store.put_blob(b"some data") == oid


def test_object_store_trees_and_commits(tmp_path):
    store = ObjectStore(str(tmp_path / "objects"), FS(NULL_FS))
    t = store.put_tree({"a.txt": {"t": "blob", "oid": "0" * 64}})
    c = store.put_commit({"tree": t, "parents": [], "author": "x",
                          "timestamp": 1.0, "message": "m"})
    assert store.get_commit(c)["tree"] == t
    with pytest.raises(TypeError):
        store.get_blob(t)


# ---------------------------------------------------------------- repository
def test_save_checkout_roundtrip(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root, annex_threshold=100)
    write(root, "small.txt", "small")
    write(root, "dir/big.bin", b"\x01" * 1000)  # >= threshold -> annexed
    c1 = repo.save(message="first")
    tree = repo.tree_of(c1)
    assert tree["small.txt"]["t"] == "blob"
    assert tree["dir/big.bin"]["t"] == "annex"

    # mutate, save, check history
    write(root, "small.txt", "changed")
    c2 = repo.save(paths=["small.txt"], message="second")
    assert c2 != c1
    assert repo.objects.get_commit(c2)["parents"] == [c1]

    # checkout old version restores contents
    repo.checkout(c1)
    assert open(os.path.join(root, "small.txt")).read() == "small"
    assert open(os.path.join(root, "dir/big.bin"), "rb").read() == b"\x01" * 1000


def test_save_no_change_no_commit(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root)
    write(root, "a.txt", "a")
    c1 = repo.save(message="first")
    c_again = repo.save(message="no-op")
    assert c_again == c1


def test_nested_trees_only_dirty_dirs(tmp_path):
    """Hierarchical trees: sibling dirs keep the same subtree oid across
    commits that don't touch them (this is what keeps local-FS finish flat)."""
    root = str(tmp_path / "repo")
    repo = Repository.init(root)
    for j in range(3):
        write(root, f"jobs/{j}/out.txt", f"result {j}")
    c1 = repo.save(message="all")
    write(root, "jobs/0/out.txt", "changed")
    c2 = repo.save(paths=["jobs/0/out.txt"], message="update job0")

    def subtree_oid(commit, name):
        top = repo.objects.get_tree(repo.objects.get_commit(commit)["tree"])
        jobs = repo.objects.get_tree(top["jobs"]["oid"])
        return jobs[name]["oid"]

    assert subtree_oid(c1, "1") == subtree_oid(c2, "1")
    assert subtree_oid(c1, "0") != subtree_oid(c2, "0")


def test_branches_and_octopus_merge(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root)
    write(root, "base.txt", "base")
    base = repo.save(message="base")
    # three "job" branches with disjoint outputs
    for j in range(3):
        repo.create_branch(f"job/{j}", at=base)
    for j in range(3):
        repo.switch(f"job/{j}")
        write(root, f"out/{j}.txt", f"output {j}")
        repo.save(paths=[f"out/{j}.txt"], message=f"job {j}", branch=f"job/{j}")
    repo.switch("main")
    m = repo.merge_octopus([f"job/{j}" for j in range(3)], message="octopus")
    commit = repo.objects.get_commit(m)
    assert len(commit["parents"]) == 4  # HEAD + 3 branches
    tree = repo.tree_of(m)
    assert {f"out/{j}.txt" for j in range(3)} <= set(tree)
    # worktree materialized
    assert open(os.path.join(root, "out/2.txt")).read() == "output 2"


def test_octopus_merge_conflict(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root)
    write(root, "base.txt", "base")
    base = repo.save(message="base")
    for j in range(2):
        repo.create_branch(f"job/{j}", at=base)
        repo.switch(f"job/{j}")
        write(root, "same.txt", f"conflicting {j}")
        repo.save(paths=["same.txt"], message=f"job {j}", branch=f"job/{j}")
    repo.switch("main")
    with pytest.raises(ConflictError):
        repo.merge_octopus(["job/0", "job/1"])


def test_log_traverses_all_parents(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root)
    write(root, "a.txt", "a")
    repo.save(message="c1")
    write(root, "a.txt", "b")
    repo.save(message="c2")
    msgs = [c["message"] for _, c in repo.log()]
    assert msgs == ["c2", "c1"]


# ---------------------------------------------------------------- annex
def test_annex_get_drop_whereis(tmp_path):
    root = str(tmp_path / "repo")
    repo = Repository.init(root, annex_threshold=10)
    write(root, "big.bin", b"\x02" * 100)
    repo.save(message="add big")
    key = repo.annex_key_at("big.bin")
    assert repo.whereis(key) == ["local"]

    # cannot drop the last copy
    with pytest.raises(RuntimeError):
        repo.annex_drop("big.bin")

    # push to a remote store, then drop
    remote = AnnexStore(str(tmp_path / "s3"), repo.fs, name="s3")
    assert repo.annex_push(remote) == 1
    repo.add_annex_remote(str(tmp_path / "s3"))
    repo.annex_drop("big.bin")
    data = open(os.path.join(root, "big.bin"), "rb").read()
    assert parse_pointer(data) == key

    # get fetches it back from the remote
    assert repo.annex_get("big.bin")
    assert open(os.path.join(root, "big.bin"), "rb").read() == b"\x02" * 100


def test_clone_knows_annexed_files_without_content(tmp_path):
    src_root = str(tmp_path / "src")
    src = Repository.init(src_root, annex_threshold=10)
    write(src_root, "data.bin", b"\x03" * 50)
    write(src_root, "notes.txt", "tiny")
    src.save(message="initial")

    dst = Repository.clone(src, str(tmp_path / "dst"))
    assert dst.dsid == src.dsid
    # text file has content, annexed file is a pointer until get
    assert open(os.path.join(dst.root, "notes.txt")).read() == "tiny"
    ptr = open(os.path.join(dst.root, "data.bin"), "rb").read()
    key = parse_pointer(ptr)
    assert key is not None
    assert dst.annex_get("data.bin")
    assert open(os.path.join(dst.root, "data.bin"), "rb").read() == b"\x03" * 50


# ---------------------------------------------------------------- fs model
def test_fs_profiles_charge_virtual_time(tmp_path):
    clock = SimClock()
    fs = FS(GPFS, clock)
    fs.write_bytes(str(tmp_path / "f.bin"), b"x" * 1_000_000)
    t1 = clock.snapshot()
    assert t1 > 0
    fs.read_bytes(str(tmp_path / "f.bin"))
    assert clock.snapshot() > t1


def test_gpfs_degrades_with_directory_pressure(tmp_path):
    """Parallel-FS metadata ops degrade with the entry count of the touched
    directory (the paper's repo-size effect: object-store shards accumulate
    one entry per stored object)."""
    clock = SimClock()
    fs = FS(GPFS, clock)
    fs.preload_dir_entries(str(tmp_path), GPFS.degrade_threshold + 100_000)
    before = clock.snapshot()
    fs.exists(str(tmp_path / "x"))
    degraded_cost = clock.snapshot() - before
    fs2 = FS(GPFS, SimClock())
    fs2.exists(str(tmp_path / "x"))
    assert degraded_cost > fs2.clock.snapshot() * 5

    # an op in a *different, small* directory is not taxed by the big one
    fs4 = FS(GPFS, SimClock())
    fs4.preload_dir_entries(str(tmp_path / "big"), 10_000_000)
    fs4.exists(str(tmp_path / "small" / "x"))
    assert fs4.clock.snapshot() == pytest.approx(GPFS.meta_op_s)

    # local FS never degrades
    fs3 = FS(LOCAL_XFS, SimClock())
    fs3.preload_dir_entries(str(tmp_path), 10_000_000)
    fs3.exists(str(tmp_path / "x"))
    assert fs3.clock.snapshot() == pytest.approx(LOCAL_XFS.meta_op_s)


def test_fs_tracks_directory_entries(tmp_path):
    fs = FS(GPFS, SimClock())
    d = str(tmp_path / "d")
    fs.write_bytes(d + "/a.txt", b"a")
    fs.write_bytes(d + "/b.txt", b"b")
    fs.write_bytes(d + "/a.txt", b"a2")  # overwrite: no new entry
    assert fs.dir_entry_count(d) == 2
    assert fs.n_files == 2
    fs.unlink(d + "/a.txt")
    assert fs.dir_entry_count(d) == 1
    assert fs.n_files == 1


def test_object_store_caches_skip_fs_probes(tmp_path):
    clock = SimClock()
    store = ObjectStore(str(tmp_path / "objects"), FS(GPFS, clock))
    oid = store.put_blob(b"cached payload")
    ops_after_first = clock.meta_ops
    assert store.put_blob(b"cached payload") == oid  # known oid: no fs ops
    assert store.has(oid)
    assert clock.meta_ops == ops_after_first
    t = store.put_tree({"a": {"t": "blob", "oid": oid}})
    ops = clock.meta_ops
    assert store.get_tree(t) == {"a": {"t": "blob", "oid": oid}}  # cached parse
    assert clock.meta_ops == ops

    store.disable_caches()  # seed-era behavior: every put probes again
    store.put_blob(b"cached payload")
    assert clock.meta_ops > ops
