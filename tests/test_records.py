"""Tests for machine-actionable reproducibility records (paper §3)."""
import os

import pytest

from repro.core.records import (
    BEGIN,
    END,
    RunFailed,
    RunRecord,
    rerun,
    run,
)
from repro.core.repo import Repository


def write(root, rel, data):
    p = os.path.join(root, rel)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    with open(p, "w") as f:
        f.write(data)


@pytest.fixture
def repo(tmp_path):
    return Repository.init(str(tmp_path / "repo"), annex_threshold=50)


def test_record_message_roundtrip():
    rec = RunRecord(
        cmd="./scripts/run.sh 14", dsid="d5f31a22", inputs=["data/in.csv"],
        outputs=["data/out.csv"], slurm_job_id=11452054,
        slurm_outputs=["log.slurm-11452054.out"],
    )
    msg = rec.to_message("Solve N=14")
    assert BEGIN in msg and END in msg
    back = RunRecord.from_message(msg)
    assert back.cmd == rec.cmd
    assert back.inputs == rec.inputs
    assert back.slurm_job_id == 11452054
    assert RunRecord.from_message("no record here") is None


def test_run_commits_outputs_with_record(repo):
    write(repo.root, "input.txt", "5\n")
    repo.save(message="add input")
    oid = run(
        repo,
        cmd="python3 -c \"print(int(open('input.txt').read())**2, file=open('result.txt','w'))\"",
        inputs=["input.txt"],
        outputs=["result.txt"],
        message="square the input",
    )
    assert open(os.path.join(repo.root, "result.txt")).read().strip() == "25"
    commit = repo.objects.get_commit(oid)
    rec = RunRecord.from_message(commit["message"])
    assert rec.exit == 0
    assert rec.outputs == ["result.txt"]
    assert rec.dsid == repo.dsid


def test_run_failure_does_not_commit(repo):
    head_before = repo.head_commit()
    with pytest.raises(RunFailed):
        run(repo, cmd="exit 3", outputs=["whatever.txt"])
    assert repo.head_commit() == head_before


def test_rerun_bitwise_identical_no_new_commit(repo):
    write(repo.root, "input.txt", "7\n")
    repo.save(message="add input")
    oid = run(
        repo,
        cmd="python3 -c \"print(int(open('input.txt').read())*2, file=open('out.txt','w'))\"",
        inputs=["input.txt"],
        outputs=["out.txt"],
    )
    head_before = repo.head_commit()
    report = rerun(repo, oid)
    assert report["bitwise"] is True
    assert report["new_commit"] is None
    assert repo.head_commit() == head_before


def test_rerun_with_changed_input_new_commit_and_chain(repo):
    write(repo.root, "input.txt", "7\n")
    repo.save(message="add input")
    oid = run(
        repo,
        cmd="python3 -c \"print(int(open('input.txt').read())*2, file=open('out.txt','w'))\"",
        inputs=["input.txt"],
        outputs=["out.txt"],
    )
    # change the input (paper §3 step 6: "the new ones will be used")
    write(repo.root, "input.txt", "100\n")
    repo.save(paths=["input.txt"], message="new input")
    report = rerun(repo, oid)
    assert report["bitwise"] is False
    assert report["new_commit"] is not None
    assert open(os.path.join(repo.root, "out.txt")).read().strip() == "200"
    rec = RunRecord.from_message(repo.objects.get_commit(report["new_commit"])["message"])
    assert rec.chain == [oid]


def test_rerun_nondeterministic_detected(repo):
    oid = run(
        repo,
        cmd="python3 -c \"import uuid; open('rand.txt','w').write(uuid.uuid4().hex)\"",
        outputs=["rand.txt"],
    )
    report = rerun(repo, oid, report_only=True)
    assert report["bitwise"] is False
    assert report["outputs"]["rand.txt"] is False


def test_rerun_fetches_annexed_inputs(tmp_path):
    """Machine-actionability across clones: rerun works from a fresh clone
    whose annexed inputs are pointers (the paper's idealized use case)."""
    src = Repository.init(str(tmp_path / "src"), annex_threshold=10)
    write(src.root, "data.csv", "1,2,3,4,5,6,7,8,9,10\n" * 10)  # annexed (big)
    src.save(message="data")
    oid = run(
        src,
        cmd="python3 -c \"rows=open('data.csv').readlines(); open('n.txt','w').write(str(len(rows)))\"",
        inputs=["data.csv"],
        outputs=["n.txt"],
    )
    clone = Repository.clone(src, str(tmp_path / "clone"))
    report = rerun(clone, oid)
    assert report["bitwise"] is True
    assert open(os.path.join(clone.root, "n.txt")).read() == "10"
