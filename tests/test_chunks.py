"""Chunk tier (DESIGN.md §12): content-defined cutter properties, pointer v2,
chunk manifests, delta-sized ingest, reassembly, and orphan-chunk sweeping."""
import os

import pytest

import repro
from repro.core.annex import (
    encode_chunk_manifest,
    make_pointer,
    parse_chunk_manifest,
    parse_pointer,
    parse_pointer_full,
)
from repro.core.chunks import (
    ChunkParams,
    Cutter,
    _candidates_python,
    cut_bytes,
)
from repro.core.fsio import FS, NULL_FS, SimClock
from repro.core.hashing import annex_key_for_bytes, is_chunk_key
from repro.core.repo import Repository

# small geometry so a few hundred KiB of data exercises every code path
PARAMS = ChunkParams(min_size=1 << 9, avg_bits=10, max_size=1 << 13)


def _data(n, seed=0):
    """Deterministic pseudo-random bytes without numpy in the loop."""
    out = bytearray()
    x = seed * 2654435761 % (1 << 32) or 1
    while len(out) < n:
        x = (x * 1103515245 + 12345) & 0xFFFFFFFF
        out += x.to_bytes(4, "little")
    return bytes(out[:n])


# ------------------------------------------------------------- the cutter
def test_chunks_concatenate_to_stream_and_respect_bounds():
    data = _data(200_000)
    chunks = cut_bytes(data, PARAMS)
    assert b"".join(chunks) == data
    assert len(chunks) > 3  # the geometry actually cuts
    for c in chunks[:-1]:
        assert PARAMS.min_size <= len(c) <= PARAMS.max_size
    assert 0 < len(chunks[-1]) <= PARAMS.max_size  # tail may undershoot min


def test_boundaries_independent_of_feed_segmentation():
    data = _data(150_000, seed=3)
    whole = cut_bytes(data, PARAMS)
    # re-feed the identical stream in pathological block sizes (1-byte
    # blocks force the pure-python scan; big blocks take the numpy scan)
    for sizes in ([1] * 64 + [7000] * 100, [131072, 131072], [13] * 20000):
        cutter = Cutter(PARAMS)
        got, off = [], 0
        for s in sizes:
            if off >= len(data):
                break
            got.extend(cutter.feed(data[off:off + s]))
            off += s
        got.extend(cutter.feed(data[off:]))
        got.extend(cutter.finish())
        assert got == whole, f"segmentation {sizes[:3]}... shifted boundaries"


def test_numpy_and_python_scans_are_bit_identical():
    data = _data(64_000, seed=5)
    # one-shot feed of >=1024 bytes goes through the numpy scan
    vec = cut_bytes(data, PARAMS)
    # sub-1024-byte blocks always take _candidates_python
    cutter = Cutter(PARAMS)
    py = []
    for off in range(0, len(data), 500):
        py.extend(cutter.feed(data[off:off + 500]))
    py.extend(cutter.finish())
    assert py == vec
    # and the raw candidate sets agree
    from repro.core.chunks import _candidates_numpy

    assert _candidates_numpy(data, 10) == _candidates_python(data, 10)


def test_constant_runs_fall_back_to_fixed_size_cuts():
    """All-ones mixed-hash residue: runs of a constant byte (zero pages in
    checkpoints) yield no candidates, so the cutter emits max_size slabs
    instead of degenerating into per-byte boundaries."""
    for byte in (b"\x00", b"\xff"):
        data = byte * (PARAMS.max_size * 3 + 100)
        chunks = cut_bytes(data, PARAMS)
        assert [len(c) for c in chunks] == [
            PARAMS.max_size, PARAMS.max_size, PARAMS.max_size, 100,
        ]


def test_localized_edit_preserves_most_chunks():
    """The delta-dedup property itself: overwrite ~2% of the stream and the
    chunk multiset changes only around the edit."""
    data = bytearray(_data(300_000, seed=9))
    before = {c for c in cut_bytes(bytes(data), PARAMS)}
    data[150_000:156_000] = _data(6_000, seed=77)
    after = {c for c in cut_bytes(bytes(data), PARAMS)}
    shared = len(before & after)
    assert shared / len(after) > 0.8, (shared, len(after))


def test_chunk_params_validation_and_json_roundtrip():
    with pytest.raises(ValueError):
        ChunkParams(min_size=0)
    with pytest.raises(ValueError):
        ChunkParams(min_size=10, max_size=5)
    with pytest.raises(ValueError):
        ChunkParams(avg_bits=64)
    p = ChunkParams(min_size=5, avg_bits=8, max_size=9)
    assert ChunkParams.from_json(p.to_json()) == p


# --------------------------------------------------- pointer v2 + manifest
def test_pointer_v2_roundtrip_and_v1_compat():
    key = annex_key_for_bytes(b"hello world")
    v1, v2 = make_pointer(key), make_pointer(key, chunked=True)
    assert parse_pointer_full(v1) == (key, False)
    assert parse_pointer_full(v2) == (key, True)
    # a v1 parser (first token only) reads both
    assert parse_pointer(v1) == key and parse_pointer(v2) == key
    assert parse_pointer_full(b"not a pointer") is None


def test_manifest_rejects_foreign_key():
    """A manifest is only a manifest *for its own key path* — magic-prefixed
    real content stored under some other key parses as ordinary bytes."""
    key = annex_key_for_bytes(b"x" * 100)
    mf = encode_chunk_manifest(key, ["SHA256C-s4--" + "0" * 64], PARAMS)
    assert parse_chunk_manifest(mf, key)["chunks"]
    other = annex_key_for_bytes(mf)
    assert parse_chunk_manifest(mf, other) is None
    assert parse_chunk_manifest(b"#%REPRO-CHUNKS%#\nnot json", key) is None


# ------------------------------------------------------ chunked annex path
def chunked_repo(tmp_path, **kw):
    clock = SimClock()
    repo = Repository.init(
        str(tmp_path / "repo"), clock=clock, annex_threshold=256,
        chunk_threshold=1 << 12, chunk_params=PARAMS, **kw,
    )
    return repo, clock


def test_put_bytes_routes_through_chunk_tier_above_threshold(tmp_path):
    repo, _ = chunked_repo(tmp_path)
    big, small = _data(50_000, seed=1), _data(1_000, seed=2)
    kb = annex_key_for_bytes(big)
    repo.annex.put_bytes(kb, big)
    assert repo.annex.manifest_of(kb), "big object must store as a manifest"
    assert any(is_chunk_key(k) for k in repo.annex.keys())
    assert repo.annex.read(kb) == big  # transparent reassembly + verification
    ks = annex_key_for_bytes(small)
    repo.annex.put_bytes(ks, small)
    assert repo.annex.manifest_of(ks) is None  # below threshold: legacy path


def test_second_generation_ingests_only_the_delta(tmp_path):
    repo, clock = chunked_repo(tmp_path)
    gen1 = bytearray(_data(200_000, seed=4))
    k1 = repo.annex.put_stream(iter([bytes(gen1)]), chunked=True)
    b_full = clock.bytes_written
    gen2 = bytearray(gen1)
    gen2[100_000:103_000] = _data(3_000, seed=5)  # ~1.5% churn
    k2 = repo.annex.put_stream(iter([bytes(gen2)]), chunked=True)
    delta = clock.bytes_written - b_full
    assert k1 != k2
    assert delta < 0.2 * b_full, (delta, b_full)
    assert repo.annex.read(k2) == bytes(gen2)


def test_copy_to_materializes_chunked_objects(tmp_path):
    repo, _ = chunked_repo(tmp_path)
    data = _data(30_000, seed=6)
    key = repo.annex.put_stream(iter([data]), chunked=True)
    dst = str(tmp_path / "out.bin")
    repo.annex.copy_to(key, dst)
    with open(dst, "rb") as f:
        assert f.read() == data


def test_save_checkout_roundtrip_marks_entries_chunked(tmp_path):
    repo, _ = chunked_repo(tmp_path)
    data = _data(40_000, seed=8)
    p = os.path.join(repo.root, "blob.bin")
    with open(p, "wb") as f:
        f.write(data)
    oid = repo.save(paths=["blob.bin"], message="big file")
    entry = repo.entry_at(oid, "blob.bin")
    assert entry["t"] == "annex" and entry.get("chunked") is True
    # checkout elsewhere reassembles the worktree file from chunks
    os.unlink(p)
    repo.checkout(oid)
    with open(p, "rb") as f:
        assert f.read() == data


def test_gc_sweeps_orphan_chunks_not_shared_ones(tmp_path):
    root = str(tmp_path / "proj")
    os.makedirs(root)
    s = repro.open(
        root, create=True, annex_threshold=256,
        chunk_threshold=1 << 12, chunk_params=PARAMS,
    )
    annex = s.repo.annex
    a = bytearray(_data(60_000, seed=10))
    ka = annex.put_stream(iter([bytes(a)]), chunked=True)
    b = bytearray(a)
    b[10_000:11_000] = _data(1_000, seed=11)
    kb = annex.put_stream(iter([bytes(b)]), chunked=True)
    n_chunks = sum(1 for k in annex.keys() if is_chunk_key(k))
    # drop one manifest: its exclusive chunks orphan, shared ones must stay
    annex.drop(kb)
    stats = s.gc()
    swept = stats["chunks_swept"]
    assert 0 < swept < n_chunks, (swept, n_chunks)
    assert annex.read(ka) == bytes(a)  # survivor fully intact
    # idempotent: a second sweep finds nothing
    assert s.gc()["chunks_swept"] == 0
    s.close()


def test_clone_propagates_chunk_config_and_fetches_chunked(tmp_path):
    repo, _ = chunked_repo(tmp_path)
    data = _data(25_000, seed=12)
    p = os.path.join(repo.root, "w.bin")
    with open(p, "wb") as f:
        f.write(data)
    repo.save(paths=["w.bin"], message="w")
    clone = Repository.clone(repo, str(tmp_path / "clone"))
    assert clone.annex.chunk_aware
    key = annex_key_for_bytes(data)
    assert not clone.annex.has(key)  # annexed content stays behind
    clone.annex_fetch_key(key, chunked=True)
    assert clone.annex.read(key) == data
