"""The paper's review scenario (§3): a repository is shared as the
"reproducibility appendix" of a paper; a reviewer clones it WITHOUT the bulk
data and machine-actionably re-creates the results, hash-verified.

Run:  PYTHONPATH=src python examples/review_rerun.py
"""
import os
import sys
import tempfile

import repro
from repro.core import Repository, Session


def main() -> int:
    work = tempfile.mkdtemp(prefix="repro_review_")

    # ---- the AUTHORS' side: produce results via recorded runs
    s = repro.open(os.path.join(work, "paper_repo"), create=True,
                   annex_threshold=512)
    authors = s.repo
    with open(os.path.join(authors.root, "generate.py"), "w") as f:
        f.write(
            "import numpy as np\n"
            "rng = np.random.Generator(np.random.Philox(key=7))\n"
            "data = rng.normal(size=4096)\n"
            "np.save('measurements.npy', data)\n"
        )
    with open(os.path.join(authors.root, "analyze.py"), "w") as f:
        f.write(
            "import numpy as np\n"
            "d = np.load('measurements.npy')\n"
            "hist, _ = np.histogram(d, bins=16, range=(-4, 4))\n"
            "open('figure3.csv', 'w').write(','.join(map(str, hist)))\n"
        )
    s.save(message="analysis code")
    c_data = s.run(cmd="python3 generate.py", outputs=["measurements.npy"],
                   message="raw measurements")
    c_fig = s.run(cmd="python3 analyze.py", inputs=["measurements.npy"],
                  outputs=["figure3.csv"], message="Figure 3 histogram")
    print(f"== authors committed: data {c_data[:12]}, figure {c_fig[:12]}")

    # ---- the REVIEWER's side: clone has records but no annexed content
    reviewer = Session(Repository.clone(authors, os.path.join(work, "reviewer_clone")))
    spec = reviewer.spec_of(c_fig)  # the exact spec, no message parsing
    print(f"== reviewer sees spec for Figure 3: cmd={spec.cmd!r}, "
          f"inputs={list(spec.inputs)} (spec_id {spec.spec_id[:12]}...)")

    # the data file is a pointer until fetched/reproduced
    head = open(os.path.join(reviewer.repo.root, "measurements.npy"), "rb").read(20)
    print(f"== measurements.npy in clone starts with: {head[:15]!r} (pointer)")

    # reproduce the whole chain: first the data, then the figure
    r1 = reviewer.rerun(c_data)
    r2 = reviewer.rerun(c_fig)
    print(f"== rerun data bitwise={r1['bitwise']}, figure bitwise={r2['bitwise']}")
    assert r1["bitwise"] and r2["bitwise"]
    print("== reviewer verified the paper's Figure 3 without ever downloading "
          "the data. OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
