"""Multisite campaign: the DESIGN.md §13 remote annex tier end-to-end —
run a small campaign, replicate its annexed outputs to two sites, drop
the local copies under the numcopies invariant, then cold-restore with
one site down (replica failover over an injected whole-site outage).

  1. chunked repository with ``numcopies=2``; a three-job campaign
     produces annexed binary outputs
  2. drop is REFUSED while fewer than two fresh-verified replicas exist
     (nothing cached can authorize a drop)
  3. `Session.push` replicates chunk-level to siteA (LAN) and siteB
     (WAN); `whereis` shows live + recorded locations
  4. drop every local copy + gc: the worktree holds pointers, content
     lives only on the sites
  5. reopen with a seeded `NetworkFaultModel` that takes siteA down;
     `Session.fetch` fails over to siteB and restores every key,
     bit-for-bit; `Session.verify` reports zero divergence

Run:  PYTHONPATH=src python examples/multisite_campaign.py
"""
import hashlib
import os
import tempfile

import repro
from repro import NetFaultRule, NetworkFaultModel, RunSpec
from repro.core.chunks import ChunkParams
from repro.core.fsio import SimClock

N_JOBS = 3
OUT_KIB = 96


def sha(path: str) -> str:
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def main() -> None:
    work = tempfile.mkdtemp(prefix="repro_multisite_")
    root = os.path.join(work, "project")
    clock = SimClock()

    # -- 1. chunked repo, numcopies=2: a drop needs TWO verified replicas
    s = repro.open(
        root, create=True, clock=clock, numcopies=2,
        annex_threshold=1 << 10, chunk_threshold=16 << 10,
        chunk_params=ChunkParams(min_size=2 << 10, avg_bits=13,
                                 max_size=32 << 10),
    )
    print(f"== repository at {root} (numcopies=2)")

    outs = []
    for j in range(N_JOBS):
        out = f"field_{j}.bin"
        spec = RunSpec(
            cmd=(
                'python3 -c "import random; random.seed(%d); '
                "open('%s','wb').write(bytes(random.getrandbits(8) "
                'for _ in range(%d)))"' % (j, out, OUT_KIB << 10)
            ),
            outputs=[out],
            message=f"job {j}",
        )
        s.run(spec)
        outs.append(out)
    digests = {p: sha(os.path.join(root, p)) for p in outs}
    print(f"== campaign done: {N_JOBS} jobs, "
          f"{N_JOBS * OUT_KIB} KiB of annexed outputs")

    # -- 2. drop refused until numcopies replicas are fresh-verified
    try:
        s.drop(outs[0])
        raise AssertionError("drop must be refused with zero replicas")
    except RuntimeError as e:
        print(f"== drop refused (as it must be):\n   {e}")

    # -- 3. replicate to two sites: LAN next door, WAN across the country
    s.add_remote(os.path.join(work, "siteA"), name="siteA", net="lan")
    s.add_remote(os.path.join(work, "siteB"), name="siteB", net="wan")
    t0 = clock.snapshot()
    for rep in s.push():  # one report per site; chunk-level, journaled
        print(f"== pushed {rep['keys_sent']} keys "
              f"({rep['bytes_sent']} bytes, {rep['chunks_sent']} chunks) "
              f"-> {rep['remote']}")
    print(f"== simulated transfer time: {clock.snapshot() - t0:.2f} s")
    where = s.whereis([outs[0]])
    for key, loc in where.items():
        print(f"== whereis {outs[0]}: live={sorted(loc['stores'])} "
              f"recorded={sorted(loc['recorded'])}")

    # -- 4. now the drop is safe: two fresh probes vouch for every key
    for p in outs:
        s.drop(p)
    s.gc()  # sweep the orphaned local chunks
    print("== local copies dropped; worktree holds pointers, content "
          "lives on siteA + siteB")
    s.close()

    # -- 5. cold-restore with siteA DOWN: the first request to it marks
    #       the whole site dead; every fetch fails over to siteB
    outage = NetworkFaultModel(seed=3, rules=[
        NetFaultRule(op="*", remote="siteA", kind="outage", nth=1),
    ])
    s = repro.open(root, clock=clock, net_faults=outage)
    t0 = clock.snapshot()
    rep = s.fetch()  # == pull every annex key HEAD references
    print(f"== cold restore: {rep['keys_fetched']} keys "
          f"({rep['bytes_received']} bytes) with {rep['failovers']} "
          f"failover(s), sources={sorted(set(rep['sources'].values()))}, "
          f"{clock.snapshot() - t0:.2f} sim s over the WAN")
    for p in outs:
        s.repo.annex_get(p)
        assert sha(os.path.join(root, p)) == digests[p], p
    print("== every output restored bit-for-bit from the surviving site")

    report = s.verify()
    assert report["divergence"] == 0, report
    print(f"== verify: divergence={report['divergence']} "
          f"(warnings={len(report.get('warnings', []))})")
    s.close()
    print("== ok")


if __name__ == "__main__":
    main()
