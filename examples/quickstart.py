"""Quickstart: the paper's workflow end-to-end in two minutes on a laptop.

  1. init a repository; version code + (annexed) data
  2. machine-actionable `run` + bitwise-verified `rerun`
  3. schedule concurrent Slurm jobs on ONE clone with output-conflict
     protection; finish with per-job provenance records + octopus merge
  4. clone without annexed content; reproduce an output from its record

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

from repro.core import (
    LocalSlurmCluster,
    OutputConflict,
    Repository,
    RunRecord,
    SlurmScheduler,
    rerun,
    run,
)


def main() -> None:
    work = tempfile.mkdtemp(prefix="repro_quickstart_")
    root = os.path.join(work, "project")
    repo = Repository.init(root, annex_threshold=1024)
    print(f"== repository at {root} (dsid {repo.dsid[:8]}...)")

    # -- 1. version some input data (large file -> annexed automatically)
    with open(os.path.join(root, "params.txt"), "w") as f:
        f.write("14\n")
    with open(os.path.join(root, "table.bin"), "wb") as f:
        f.write(bytes(range(256)) * 64)  # 16 KiB -> annexed
    c0 = repo.save(message="inputs")
    print(f"== committed inputs: {c0[:12]}")

    # -- 2. datalad-run equivalent: execute + record + commit
    oid = run(
        repo,
        cmd="python3 -c \"n=int(open('params.txt').read()); "
        "open('result.txt','w').write(str(n*n))\"",
        inputs=["params.txt"],
        outputs=["result.txt"],
        message="Solve N=14",
    )
    print(f"== ran + recorded: {oid[:12]} -> result.txt =",
          open(os.path.join(root, "result.txt")).read())

    report = rerun(repo, oid)
    print(f"== rerun bitwise identical: {report['bitwise']} (no new commit)")

    # -- 3. concurrent Slurm jobs on one clone
    cluster = LocalSlurmCluster(max_workers=4)
    sched = SlurmScheduler(repo, cluster, cli_startup_s=0.0)
    for j in range(4):
        d = os.path.join(root, "jobs", str(j))
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "slurm.sh"), "w") as f:
            f.write(f"#!/bin/bash\necho computed-{j} > answer.txt\n")
    repo.save(message="job scripts")
    for j in range(4):
        sched.schedule("slurm.sh", outputs=[f"jobs/{j}/answer.txt"], pwd=f"jobs/{j}")
    try:  # overlapping outputs are refused at schedule time (§5.5)
        sched.schedule("slurm.sh", outputs=["jobs/0"], pwd="jobs/0")
    except OutputConflict as e:
        print(f"== conflict correctly refused: {e}")
    cluster.wait(timeout=60)
    results = sched.finish(octopus=True)
    print(f"== finished {len(results)} jobs; octopus merge "
          f"{repo.head_commit()[:12]} with "
          f"{len(repo.objects.get_commit(repo.head_commit())['parents'])} parents")

    # -- 4. clone (annex content stays behind), reproduce from the record
    clone = Repository.clone(repo, os.path.join(work, "clone"))
    rec = RunRecord.from_message(clone.objects.get_commit(oid)["message"])
    print(f"== clone sees record: cmd={rec.cmd!r}")
    report = rerun(clone, oid)
    print(f"== reproduced in clone, bitwise: {report['bitwise']}")
    cluster.shutdown()
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
