"""Quickstart: the paper's workflow end-to-end in two minutes on a laptop,
through the Session API — every execution path is driven by a declarative
RunSpec, the machine-actionable single source of truth.

  1. `repro.open(..., create=True)` a repository; version code + (annexed)
     data with `Session.save`
  2. machine-actionable `Session.run` + bitwise-verified `Session.rerun`
     (the spec rides in the commit itself: `Session.spec_of` recovers it
     verbatim, equal spec_id — no message parsing)
  3. submit concurrent Slurm jobs on ONE clone as a single `submit_many`
     batch (one CLI-startup charge, one jobdb transaction, one shared
     output-conflict pass); finish with per-job provenance records +
     octopus merge
  4. clone without annexed content; reproduce an output from its record

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

import repro
from repro import RunSpec
from repro.core import OutputConflict, Repository, Session

def main() -> None:
    work = tempfile.mkdtemp(prefix="repro_quickstart_")
    root = os.path.join(work, "project")
    s = repro.open(root, create=True, annex_threshold=1024)
    print(f"== repository at {root} (dsid {s.dsid[:8]}...)")

    # -- 1. version some input data (large file -> annexed automatically)
    with open(os.path.join(root, "params.txt"), "w") as f:
        f.write("14\n")
    with open(os.path.join(root, "table.bin"), "wb") as f:
        f.write(bytes(range(256)) * 64)  # 16 KiB -> annexed
    c0 = s.save(message="inputs")
    print(f"== committed inputs: {c0[:12]}")

    # -- 2. declarative run: the RunSpec is validated at construction and
    #       embedded verbatim in the provenance record
    spec = RunSpec(
        cmd="python3 -c \"n=int(open('params.txt').read()); "
        "open('result.txt','w').write(str(n*n))\"",
        inputs=["params.txt"],
        outputs=["result.txt"],
        message="Solve N=14",
    )
    oid = s.run(spec)
    print(f"== ran + recorded: {oid[:12]} -> result.txt =",
          open(os.path.join(root, "result.txt")).read())
    # rerun replays the exact spec (equal content address), hash-verified
    assert s.spec_of(oid).spec_id == spec.spec_id
    report = s.rerun(oid)
    print(f"== rerun bitwise identical: {report['bitwise']} (no new commit), "
          f"spec_id {report['spec_id'][:12]}...")

    # -- 3. concurrent Slurm jobs on one clone, submitted as ONE batch
    for j in range(4):
        d = os.path.join(root, "jobs", str(j))
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "slurm.sh"), "w") as f:
            f.write(f"#!/bin/bash\necho computed-{j} > answer.txt\n")
    s.save(message="job scripts")
    s.submit_many([
        RunSpec(script="slurm.sh", outputs=[f"jobs/{j}/answer.txt"], pwd=f"jobs/{j}")
        for j in range(4)
    ])
    try:  # overlapping outputs are refused at schedule time (§5.5)
        s.submit(RunSpec(script="slurm.sh", outputs=["jobs/0"], pwd="jobs/0"))
    except OutputConflict as e:
        print(f"== conflict correctly refused: {e}")
    s.wait(timeout=60)
    results = s.finish(octopus=True)
    print(f"== finished {len(results)} jobs; octopus merge "
          f"{s.head()[:12]} with "
          f"{len(s.repo.objects.get_commit(s.head())['parents'])} parents")

    # -- 4. clone (annex content stays behind), reproduce from the record
    clone = Session(Repository.clone(s.repo, os.path.join(work, "clone")))
    rec_spec = clone.spec_of(oid)
    print(f"== clone sees spec: cmd={rec_spec.cmd!r} "
          f"(spec_id {rec_spec.spec_id[:12]}...)")
    report = clone.rerun(oid)
    print(f"== reproduced in clone, bitwise: {report['bitwise']}")
    s.close()
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
