"""Pipeline campaign: the DESIGN.md §14 DAG engine end-to-end — a
3-stage preprocess -> train -> evaluate chain submitted as ONE campaign,
a mid-campaign failure whose dependents are cancelled for free, and a
cache-aware replay that re-executes only the failed cone.

  1. three chained ``RunSpec``s; :class:`repro.Pipeline` infers the
     edges from output -> input overlap (no explicit wiring) and batches
     the DAG into topological levels
  2. ``Session.run_pipeline`` submits one ``submit_many`` per level,
     chained with Slurm ``afterok`` dependencies — the client never
     polls between stages
  3. the train stage is broken on purpose: Slurm cancels ``evaluate``
     the moment ``train`` fails (afterok cascade), and
     ``finish(close_failed_jobs=True)`` closes both rows — the
     dependent as ``cancelled-dependency``
  4. the script is fixed and the SAME pipeline is resubmitted:
     ``preprocess`` short-circuits from the §11 run cache (scripts are
     declared as inputs, so its key is unchanged) while ``train`` and
     ``evaluate`` — the failed cone — really re-execute

Run:  PYTHONPATH=src python examples/pipeline_campaign.py
"""
import os
import tempfile

import repro
from repro import Pipeline, RunSpec


def write(root: str, rel: str, text: str) -> None:
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def script(root: str, rel: str, body: str) -> None:
    write(root, rel, "#!/bin/bash\nset -e\n" + body + "\n")


def statuses(s, jobs) -> dict:
    return {n: s.scheduler.db.get(j)["status"] for n, j in jobs.items()}


def main() -> None:
    work = tempfile.mkdtemp(prefix="repro_pipeline_")
    root = os.path.join(work, "project")
    s = repro.open(root, create=True, annex_threshold=256)
    print(f"== repository at {root}")

    # -- 1. three chained stages; edges are INFERRED from the data flow.
    # Scripts are declared as inputs so editing one invalidates exactly
    # its stage in the run cache.
    script(root, "preprocess.sh",
           "mkdir -p data; printf 'clean%.0s' {1..80} > data/clean.txt")
    script(root, "train.sh", "exit 42  # broken on purpose (fixed below)")
    script(root, "evaluate.sh",
           "mkdir -p report; wc -c < model/weights.bin > report/score.txt")
    stages = {
        "preprocess": RunSpec(
            script="preprocess.sh", inputs=["preprocess.sh"],
            outputs=["data/clean.txt"],
        ),
        "train": RunSpec(
            script="train.sh", inputs=["train.sh", "data/clean.txt"],
            outputs=["model/weights.bin"],
        ),
        "evaluate": RunSpec(
            script="evaluate.sh",
            inputs=["evaluate.sh", "model/weights.bin"],
            outputs=["report/score.txt"],
        ),
    }
    pipeline = Pipeline(stages)
    print(f"== inferred edges: {pipeline.edges()}")
    print(f"== topological levels: {pipeline.levels()}")

    # -- 2+3. one campaign, one submit batch per level. train fails, so
    # Slurm cancels evaluate without it ever starting; close_failed_jobs
    # closes the failed row and its cancelled dependent.
    out = s.run_pipeline(pipeline, close_failed_jobs=True)
    st = statuses(s, out["jobs"])
    print(f"== mid-campaign failure: {st}")
    assert st["preprocess"] == "finished"
    assert st["train"] == "closed-failed"
    assert st["evaluate"] == "cancelled-dependency"

    # -- 4. fix the broken stage and replay the SAME pipeline: only the
    # failed cone (train + evaluate) re-executes; preprocess comes back
    # from the run cache as a memoized provenance commit.
    script(root, "train.sh",
           "mkdir -p model; cat data/clean.txt > model/weights.bin")
    out2 = s.run_pipeline(Pipeline(stages))
    st2 = statuses(s, out2["jobs"])
    print(f"== replay from cache:   {st2}")
    assert st2["preprocess"] == "memoized"
    assert st2["train"] == "finished"
    assert st2["evaluate"] == "finished"

    score = open(os.path.join(root, "report/score.txt")).read().strip()
    print(f"== report/score.txt = {score} bytes of weights")
    assert score == "400"
    assert s.verify()["divergence"] == 0
    print("== pipeline campaign: failure cascaded, replay re-ran only "
          "the failed cone, provenance verified")
    s.close()


if __name__ == "__main__":
    main()
