"""End-to-end driver: the paper's §7 outlook scenario.

A surrogate model is trained on the *moving* output set of an HPC
simulation campaign:

  1. "simulation" Slurm jobs produce token shards, scheduled through the
     DataLad-Slurm protocol and committed in batches as they finish —
     every shard is annexed, every job has a reproducibility record;
  2. a training dataset is pinned to a COMMIT HASH (the paper's point:
     "this commit hash is sufficient provenance information for the DNN
     model to identify precisely which training data set was used");
  3. a transformer LM trains on that dataset; checkpoints are committed to
     the same repository with records chaining model -> data commit;
  4. more simulations finish; training continues from the checkpoint on the
     bigger data commit — the lineage is the commit DAG;
  5. the phase-1 simulations are re-submitted verbatim: the §11 run cache
     recognizes every execution key and publishes memoized provenance
     commits instead of touching Slurm — bit-identical outputs, full
     records, zero compute.

Defaults are laptop-sized (~8M params, 60 steps). --model-dim 768
--layers 12 --steps 300 gives the ~100M-param configuration; the code path
is identical.

Run:  PYTHONPATH=src python examples/surrogate_campaign.py [--steps N]
"""
import argparse
import io
import os
import sys
import tempfile

import numpy as np

import repro
from repro import RunSpec
from repro.core.records import RunRecord
from repro.configs.base import ModelConfig
from repro.data.tokens import RepoTokenDataset
from repro.optim.adamw import AdamW
from repro.train.loop import train_segment

SIM_JOB = """#!/bin/bash
# "HPC simulation": deterministically synthesize a token shard
python3 - <<'EOF'
import numpy as np, os
seed = int(os.environ["SLURM_ARRAY_TASK_ID"]) + {base}
rng = np.random.Generator(np.random.Philox(key=seed))
tokens = rng.integers(0, {vocab}, size=65536, dtype=np.int32)
np.save("shard.npy", tokens)
EOF
"""


def run_simulation_batch(s, base: int, n_jobs: int) -> str:
    """Submit n_jobs 'simulations' as ONE submit_many batch (one CLI-startup
    charge, one jobdb transaction, one shared conflict pass); finish; return
    the data commit hash."""
    repo = s.repo
    d = os.path.join(repo.root, "campaign", f"batch_{base}")
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "sim.sh"), "w") as f:
        f.write(SIM_JOB.format(base=base, vocab=4096))
    s.save(message=f"simulation scripts batch {base}")
    # per-task dirs, one declarative spec each, submitted as a single batch
    specs = []
    for t in range(n_jobs):
        td = os.path.join(d, str(t))
        os.makedirs(td, exist_ok=True)
        with open(os.path.join(td, "slurm.sh"), "w") as f:
            f.write(SIM_JOB.format(base=base + t, vocab=4096).replace(
                '["SLURM_ARRAY_TASK_ID"]', '.get("SLURM_ARRAY_TASK_ID","0")'))
        specs.append(RunSpec(
            script="slurm.sh",
            outputs=[f"campaign/batch_{base}/{t}/shard.npy"],
            pwd=f"campaign/batch_{base}/{t}",
            message=f"simulation {base}+{t}",
        ))
    s.submit_many(specs)
    s.wait(timeout=300)
    results = s.finish(octopus=True)
    assert all(r.state == "COMPLETED" for r in results), results
    commit = s.head()
    print(f"  committed {len(results)} simulation jobs -> data commit {commit[:12]}")
    return commit


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--sim-jobs", type=int, default=4)
    args = ap.parse_args()

    work = tempfile.mkdtemp(prefix="repro_campaign_")
    s = repro.open(os.path.join(work, "campaign_repo"), create=True,
                   annex_threshold=4096, max_workers=4)
    repo = s.repo
    print(f"== campaign repository {repo.root}")

    cfg = ModelConfig(
        name="surrogate-lm", family="dense",
        n_layers=args.layers, d_model=args.model_dim,
        n_heads=max(4, args.model_dim // 64), n_kv_heads=max(2, args.model_dim // 128),
        d_ff=args.model_dim * 3, vocab_size=4096, remat=False,
    )
    n = cfg.param_counts()["total"]
    print(f"== surrogate model: {n/1e6:.1f}M params")

    # ---- phase 1: first simulation batch + training on its commit
    print("== phase 1: simulations")
    data_commit = run_simulation_batch(s, 0, args.sim_jobs)
    ds = RepoTokenDataset(repo, data_commit, prefix="campaign",
                          seq_len=256, global_batch=4)
    print(f"  dataset at {data_commit[:12]}: {len(ds.files)} shards")
    res = train_segment(repo, cfg, ds, n_steps=args.steps // 2,
                        ckpt_every=max(10, args.steps // 4),
                        optimizer=AdamW(lr=3e-4), seed=0)
    print(f"  trained to step {res.end_step}, loss {res.final_loss:.3f}, "
          f"checkpoint {res.checkpoint_commit[:12]}")

    # ---- phase 2: more simulations land; resume on the bigger dataset
    print("== phase 2: more simulations + resumed training")
    data_commit2 = run_simulation_batch(s, 100, args.sim_jobs)
    ds2 = RepoTokenDataset(repo, data_commit2, prefix="campaign",
                           seq_len=256, global_batch=4)
    print(f"  dataset at {data_commit2[:12]}: {len(ds2.files)} shards")
    res2 = train_segment(repo, cfg, ds2, n_steps=args.steps,
                         ckpt_every=max(10, args.steps // 4),
                         optimizer=AdamW(lr=3e-4), seed=0)
    print(f"  resumed {res2.start_step} -> {res2.end_step}, "
          f"loss {res2.final_loss:.3f}")

    # ---- phase 3: resubmit phase 1 verbatim — the run cache answers
    print("== phase 3: run-cache replay of the phase-1 simulations")
    replay = [RunSpec(
        script="slurm.sh",
        outputs=[f"campaign/batch_0/{t}/shard.npy"],
        pwd=f"campaign/batch_0/{t}",
        message=f"simulation 0+{t}",
    ) for t in range(args.sim_jobs)]
    ids = s.submit_many(replay)  # identical execution keys: no sbatch runs
    rows = [s.scheduler.db.get(j) for j in ids]
    n_memo = sum(1 for r in rows if r["status"] == "memoized")
    assert n_memo == len(replay) and all(r["slurm_id"] is None for r in rows)
    print(f"  {n_memo}/{len(replay)} specs memoized — zero Slurm submissions")
    head = s.repo.head_commit()
    rec = RunRecord.from_message(s.repo.objects.get_commit(head)["message"])
    print(f"  head {head[:12]} is a memoized record of {rec.memoized_of[:12]}; "
          f"spec_id {s.spec_of(head).spec_id[:12]} reconstructs exactly")

    # ---- provenance: walk the commit DAG
    print("== provenance (newest first):")
    shown = 0
    for oid, commit in repo.log():
        title = commit["message"].splitlines()[0][:72]
        print(f"  {oid[:12]} {title}")
        shown += 1
        if shown > 12:
            print("  ...")
            break
    s.close()
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
