"""Checkpoint campaign walkthrough: training state as versioned data.

A tiny "training loop" saves three checkpoints through the chunked annex
(DESIGN.md §12), then restores the middle one — demonstrating:

  1. every checkpoint is a commit: `CheckpointManager.save` streams each
     leaf into the annex as a `.npy` artifact and commits pointers + a
     manifest with a machine-actionable record (the RunSpec rides in the
     commit object itself)
  2. content-defined chunking makes step-over-step saves delta-sized:
     with ~3% of each tensor changing per step, step 2 and 3 ingest only
     the chunks the churn touched (watch `bytes_written` per save)
  3. restore is by commit — `checkpoints()` lists (commit, step), and
     restoring the *middle* checkpoint returns state bit-identical to
     what was saved, bf16 included

Run:  PYTHONPATH=src python examples/train_campaign.py
"""
import os
import tempfile

import ml_dtypes
import numpy as np

from repro.core import records as R
from repro.core.chunks import ChunkParams
from repro.core.fsio import GPFS_STRIPED, SimClock
from repro.core.repo import Repository
from repro.train.checkpoint import CheckpointManager


def main() -> None:
    work = tempfile.mkdtemp(prefix="repro_campaign_")
    clock = SimClock()
    repo = Repository.init(
        os.path.join(work, "project"),
        profile=GPFS_STRIPED, clock=clock,
        annex_threshold=64 << 10,
        chunk_threshold=256 << 10,
        chunk_params=ChunkParams(min_size=8 << 10, avg_bits=14,
                                 max_size=64 << 10),
    )
    print(f"== repository at {repo.root} (chunk tier on)")

    # -- a sharded model: one f32 layer, one bf16 embedding, Adam moments
    rng = np.random.default_rng(0)
    params = {
        "layer": rng.standard_normal((512, 1024), dtype=np.float32),
        "embed": rng.standard_normal((512, 1024), dtype=np.float32)
        .astype(ml_dtypes.bfloat16),
    }
    opt_state = {
        "m": {"layer": np.zeros((512, 1024), np.float32)},
        "step": np.int32(0),
    }

    ckpt = CheckpointManager(repo)
    saved_embed = {}
    for step in (1, 2, 3):
        if step > 1:
            # ~3% of each tensor drifts per step — the rest is the bytes
            # of the previous checkpoint
            for leaf in (params["layer"], params["embed"],
                         opt_state["m"]["layer"]):
                flat = leaf.reshape(-1)
                n = flat.size // 32
                off = int(rng.integers(0, flat.size - n))
                flat[off:off + n] = rng.standard_normal(
                    n, dtype=np.float32).astype(leaf.dtype)
            opt_state["step"] = np.int32(step)
        b0 = clock.bytes_written
        oid = ckpt.save(step, params, opt_state, data_step=step)
        saved_embed[step] = np.asarray(params["embed"]).copy()
        print(f"== step {step}: commit {oid[:12]} "
              f"ingested {(clock.bytes_written - b0) / 2**20:.2f} MiB")

    # -- the campaign is ordinary history: (commit, step), newest first
    cps = ckpt.checkpoints()
    print("== checkpoints:", [(oid[:8], step) for oid, step in cps])

    # -- restore the MIDDLE checkpoint by its commit
    middle_oid = dict((step, oid) for oid, step in cps)[2]
    state, manifest = ckpt.restore(middle_oid)
    assert manifest["step"] == 2
    restored = np.asarray(state["params"]["embed"])
    assert restored.dtype == ml_dtypes.bfloat16
    assert restored.tobytes() == saved_embed[2].tobytes()
    assert int(state["opt_state"]["step"]) == 2
    spec = R.spec_of(repo, middle_oid)  # the commit carries its RunSpec
    print("== restored step 2 bit-identical (bf16 embed verified), "
          f"spec: {spec.cmd!r}")
    print(f"== modeled FS time for the whole campaign: "
          f"{clock.snapshot():.2f}s")
    print("== OK")


if __name__ == "__main__":
    main()
