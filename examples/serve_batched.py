"""Batched serving of a small LM: prefill + decode with KV caches, request
batching, and per-request latency stats. The serving states are exactly the
structures the decode dry-run lowers at production scale (launch/dryrun.py).

Run:  PYTHONPATH=src python examples/serve_batched.py [--requests 8]
"""
import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.models.params import init_params
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--arch", default="qwen3_0_6b")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = init_params(T.param_defs(cfg), seed=0)
    cache_len = args.prompt_len + args.gen_tokens
    prefill = jax.jit(make_prefill_step(cfg, None, cache_len=cache_len))
    step = jax.jit(make_decode_step(cfg, None), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    B = args.requests
    prompts = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}

    t0 = time.perf_counter()
    caches, logits = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]

    generated = [tok]
    lat = []
    for i in range(args.gen_tokens - 1):
        t0 = time.perf_counter()
        logits, caches = step(params, caches, tok,
                              jnp.asarray(args.prompt_len + i, jnp.int32))
        logits = jax.block_until_ready(logits)
        lat.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)

    lat_ms = np.array(lat[1:]) * 1e3  # skip first (includes compile)
    print(f"model          : {cfg.name} ({args.arch})")
    print(f"batch          : {B} requests x {args.prompt_len} prompt tokens")
    print(f"prefill        : {t_prefill*1e3:.1f} ms "
          f"({B*args.prompt_len/t_prefill:.0f} tok/s incl. compile)")
    print(f"decode/step    : p50={np.percentile(lat_ms,50):.2f} ms "
          f"p95={np.percentile(lat_ms,95):.2f} ms")
    print(f"throughput     : {B*1e3/np.mean(lat_ms):.0f} tok/s at batch {B}")
    print(f"sample request0: {np.asarray(out[0])[:12]}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
