"""Bytes-heavy finish: the data plane under the §9 concurrent-transfer model.

Every benchmark so far was metadata-dominated; this one makes *bytes*
dominate (checkpoints, simulation dumps — the workloads SciDataFlow-style
tools target): each job leaves ``files_per_job`` outputs of ``mib_per_file``
MiB in its --alt-dir staging tree, and one ``slurm-finish`` call commits the
whole batch. Three cases, all on the ``GPFS_STRIPED`` profile (aggregate
bandwidth = 8x one stream — parallelism is measurable, serial is honest):

  ingest_seed       seed-era data plane (``data_plane="legacy"``): deep-copy
                    every output back into the worktree (read + write), then
                    stage it (read whole + annex write) — every byte read
                    twice and written twice, strictly serially.
  ingest_fused      single-pass pipeline: hash-while-write straight from the
                    alt tree into the annex, worktree copy by rename — every
                    byte read once and written once, still serial.
  ingest_pipelined  same pipeline fanned across ``ingest_workers`` threads:
                    overlapping §9 stream sessions split the profile's
                    aggregate bandwidth, so the batch completes in ~an
                    aggregate-saturated makespan instead of a sum of
                    per-stream times.

Rows land in ``BENCH_ingest.json``; ``python -m benchmarks.run
--check-ingest`` gates (a) fused ``bytes_read`` ~2x below seed at equal
output volume and (b) pipelined sim time < 0.5x the fused-serial time.
"""
from __future__ import annotations

import os

from repro.core.fsio import GPFS_STRIPED
from repro.core.spec import RunSpec

from .common import cleanup, make_env, timer

TRIVIAL_JOB = "#!/bin/bash\ntrue\n"

CASES = (
    # (case, data_plane, ingest_workers)
    ("ingest_seed", "legacy", 0),
    ("ingest_fused", "fused", 0),
    ("ingest_pipelined", "fused", 8),
)


def _write_output(path: str, header: bytes, size: int) -> None:
    """One synthetic job output: unique header + a hole of zeros (sparse on
    disk, but every modeled byte is really read/hashed/written by ingest)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(header)
        f.truncate(size)


def run(n_jobs: int = 8, files_per_job: int = 8, mib_per_file: int = 64,
        cases=None) -> list[dict]:
    size = mib_per_file << 20
    total_bytes = n_jobs * files_per_job * size
    rows = []
    for case, data_plane, workers in CASES:
        if cases is not None and case not in cases:
            continue
        root, repo, cluster, sched, clock = make_env(
            GPFS_STRIPED, max_workers=n_jobs, ingest_workers=workers
        )
        alt_root = os.path.join(root, "pfs_stage")
        specs = []
        for j in range(n_jobs):
            d = os.path.join(repo.root, "jobs", str(j))
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, "slurm.sh"), "w") as f:
                f.write(TRIVIAL_JOB)
            specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                                 pwd=f"jobs/{j}", alt_dir=alt_root))
        ids = sched.submit_many(specs)
        cluster.wait(timeout=600)
        # the jobs' real outputs land in the alt staging tree (plain writes:
        # producing them is the job's cost, not the data plane's)
        for j in range(n_jobs):
            for i in range(files_per_job):
                _write_output(
                    os.path.join(alt_root, "jobs", str(j), f"out_{i}.bin"),
                    b"job %d file %d\n" % (j, i), size,
                )
        sim0, read0, written0 = clock.snapshot(), clock.bytes_read, clock.bytes_written
        with timer() as t:
            results = sched.finish(data_plane=data_plane)
        committed = [r for r in results if r.commit]
        assert len(committed) == n_jobs, results
        sim_s = clock.snapshot() - sim0
        rows.append({
            "bench": "ingest",
            "case": case,
            "data_plane": data_plane,
            "ingest_workers": workers,
            "n_jobs": n_jobs,
            "files_per_job": files_per_job,
            "mib_per_file": mib_per_file,
            "output_bytes": total_bytes,
            "sim_s_total": sim_s,
            "sim_s_per_job": sim_s / n_jobs,
            "bytes_read": clock.bytes_read - read0,
            "bytes_written": clock.bytes_written - written0,
            "wall_s_total": t["s"],
        })
        cluster.shutdown()
        cleanup(root)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
