"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark case) plus a
summary of the paper-claim checks. Roofline terms (deliverable g) are
produced by ``repro.launch.roofline`` from the dry-run artifacts; this file
covers the paper's own evaluation (Figures 6-10).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import bench_conflicts, bench_finish, bench_octopus, bench_schedule

    rows = []
    print("# running bench_schedule (paper Fig. 7/8) ...", file=sys.stderr)
    rows += bench_schedule.run()
    print("# running bench_finish (paper Fig. 9/10) ...", file=sys.stderr)
    rows += bench_finish.run()
    print("# running bench_conflicts (§5.5) ...", file=sys.stderr)
    rows += bench_conflicts.run()
    print("# running bench_octopus (Fig. 6 / A2) ...", file=sys.stderr)
    rows += bench_octopus.run()

    print("name,us_per_call,derived")
    claims = []
    sched = {}
    for r in rows:
        if r["bench"] == "schedule":
            name = f"schedule/{r['case']}/{r['outputs_per_job']}out"
            us = r["wall_us_per_job"]
            derived = f"sim={r['sim_s_per_job']:.3f}s_per_job"
            sched[(r["case"], r["outputs_per_job"])] = r
        elif r["bench"] == "finish":
            name = f"finish/{r['case']}/{r['repo_files']}files"
            us = r["wall_us_per_job"]
            derived = f"sim={r['sim_s_per_job']:.3f}s_per_job"
        elif r["bench"] == "conflict_check":
            name = f"conflicts/{r['scheduled_jobs']}jobs"
            us = r["wall_us_per_check"]
            derived = "per_output_check"
        else:
            name = f"octopus/{r['n_jobs']}jobs"
            us = r["wall_us_total"]
            derived = f"parents={r['merge_parents']}"
        print(f"{name},{us:.1f},{derived}")

    # ---- paper-claim checks -------------------------------------------
    for n_out in (4, 8, 12):
        pfs = sched[("schedule_pfs", n_out)]
        alt = sched[("schedule_altdir", n_out)]
        base = sched[("pure_sbatch", n_out)]
        off_pfs = pfs["sim_s_per_job"] - base["sim_s_per_job"]
        claims.append(
            ("C2: schedule offset %d outputs (paper: ~0.35-0.7s, const)" % n_out,
             0.2 < off_pfs < 1.0
             and abs(pfs["sim_s_last_quartile"] - pfs["sim_s_first_quartile"])
             < 0.5 * pfs["sim_s_per_job"],
             f"offset={off_pfs:.2f}s alt={alt['sim_s_per_job'] - base['sim_s_per_job']:.2f}s")
        )
    fin = {(r["case"], r["repo_files"]): r for r in rows if r["bench"] == "finish"}
    blow = fin[("finish_pfs", 200_000)]["sim_s_per_job"]
    small = fin[("finish_pfs", 1_000)]["sim_s_per_job"]
    alt_big = fin[("finish_altdir", 200_000)]["sim_s_per_job"]
    claims.append(("C3: parallel-FS finish blowup past 50k files (paper: >10s/job)",
                   blow > 10.0 and blow > 5 * small, f"{small:.2f}s -> {blow:.2f}s"))
    claims.append(("C3: --alt-dir stays flat (paper: 0.6-1.7s/job)",
                   alt_big < 3.0, f"{alt_big:.2f}s at 200k files"))
    conf = {r["scheduled_jobs"]: r for r in rows if r["bench"] == "conflict_check"}
    claims.append(("§5.5: conflict check ~O(1) in scheduled jobs",
                   conf[50_000]["wall_us_per_check"] < 20 * conf[100]["wall_us_per_check"],
                   f"{conf[100]['wall_us_per_check']:.0f}us@100 -> "
                   f"{conf[50_000]['wall_us_per_check']:.0f}us@50k"))

    print()
    print("# paper-claim checks")
    ok = True
    for name, passed, detail in claims:
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
