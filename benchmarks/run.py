"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark case) plus a
summary of the paper-claim checks, and writes ``BENCH_finish.json``
(repo_files -> sim_s_per_job rows) so the finish-scaling trajectory is
tracked across PRs. Roofline terms (deliverable g) are produced by
``repro.launch.roofline`` from the dry-run artifacts; this file covers the
paper's own evaluation (Figures 6-10).

``python -m benchmarks.run --check-finish`` runs only a two-point finish
sweep (1k and 100k repo files, incremental engine) as a fast perf-regression
gate: it fails if the per-job finish cost at 100k files exceeds 3x the cost
at 1k files.

``python -m benchmarks.run --check-schedule`` runs the spec-layer batching
benchmark (per-job ``submit`` vs one ``submit_many`` for 64 jobs), writes
``BENCH_schedule.json``, and fails unless the batched submission costs
< 0.5x the sum of the individual submissions on the sim clock.

``python -m benchmarks.run --check-pack`` runs the pack-layer aging gate
(``finish_packed`` at 1k and 200k repo files), writes ``BENCH_pack.json``,
and fails if the packed per-job finish cost at 200k files exceeds 1.1x the
1k-file cost — i.e. if compaction stops flattening the repository-aging
slope the incremental engine still had.

``python -m benchmarks.run --check-ingest`` runs the bytes-heavy data-plane
benchmark (8 jobs x 8x64 MiB --alt-dir outputs, one finish batch), writes
``BENCH_ingest.json``, and fails unless (a) single-pass ingest charges
<= 0.6x the seed path's ``bytes_read`` at equal output volume and (b) the
pipelined concurrent finish completes in < 0.5x the fused-serial sim time.

``python -m benchmarks.run --check-faults`` runs the robustness cost
benchmark (journaled vs unjournaled finish, mid-batch crash + recover),
writes ``BENCH_faults.json``, and fails unless (a) the intent journal keeps
finish within 1.15x of the unjournaled cost and (b) recovering a half-crashed
batch costs less than re-finishing the whole batch, at zero divergence.

``python -m benchmarks.run --check-cache`` runs the run-cache benchmark
(a 1000-spec campaign swept cold, then re-swept at 90% overlap), writes
``BENCH_cache.json``, and fails unless (a) the warm sweep costs <= 0.15x
the cold sweep on the sim clock, (b) cached specs submit nothing to Slurm
(warm submissions == the novel count), and (c) every memoized provenance
record reconstructs to a spec with the original ``spec_id``.

``python -m benchmarks.run --check-ckpt`` runs the checkpoint-campaign
benchmark (a 20-step campaign at ~3% per-step churn, chunked vs whole-object
annex), writes ``BENCH_ckpt.json``, and fails unless (a) chunked steady-state
per-step ingest is <= 0.15x the unchunked per-step ingest, (b) every step of
the campaign restores bit-identical (incl. bf16), and (c) a warm
delta-restore moves <= 0.2x the bytes of the cold restore.

``python -m benchmarks.run --check-remote`` runs the remote annex tier
benchmark (a 16-object chunked campaign pushed/pulled over the simulated
WAN link, clean and degraded), writes ``BENCH_remote.json``, and fails
unless (a) the incremental push at ~3% churn moves <= 0.2x the cold push's
bytes and (b) the degraded-network pull completes — every key restored —
within the bounded per-operation retry budget.

``python -m benchmarks.run --check-dag`` runs the pipeline DAG benchmark
(a 3-level fan campaign — prep feeding 40 train->eval chains — submitted
as one afterok-chained ``submit_pipeline`` call, then replayed after one
train script is invalidated), writes ``BENCH_dag.json``, and fails unless
(a) the 3-level campaign costs exactly 3 submit batches, (b) afterok
ordering held on every edge, (c) the partial replay costs <= 0.3x the
cold campaign on the sim clock, and (d) the replay resubmits only the
invalidated cone (2 submissions, every other stage memoized).

``python -m benchmarks.run --check-all`` runs all nine gates in one
invocation and exits non-zero if any failed.
"""
from __future__ import annotations

import json
import os
import sys

BENCH_FINISH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_finish.json")
BENCH_SCHEDULE_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_schedule.json")
BENCH_PACK_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_pack.json")
BENCH_INGEST_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_ingest.json")
BENCH_FAULTS_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json")
BENCH_CACHE_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_cache.json")
BENCH_CKPT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_ckpt.json")
BENCH_REMOTE_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_remote.json")
BENCH_DAG_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_dag.json")


def _write_rows_json(
    rows: list[dict], bench: str, json_path: str, fields: tuple[str, ...],
    merge: bool = False,
) -> None:
    """Project ``rows`` tagged ``bench`` onto ``fields`` and write (or,
    with ``merge``, update rows in place keyed by ``(case, repo_files)`` —
    partial sweeps like the --check-* gates keep the rest of the tracked
    trajectory)."""
    out_rows = [
        {
            "case": r["case"],
            "engine": r.get("engine", "incremental"),
            "repo_files": r["repo_files"],
            **{f: r.get(f, 0.0) for f in fields},
        }
        for r in rows
        if r["bench"] == bench
    ]
    path = os.path.normpath(json_path)
    if merge and os.path.exists(path):
        with open(path) as f:
            old = {(r["case"], r["repo_files"]): r for r in json.load(f)}
        old.update({(r["case"], r["repo_files"]): r for r in out_rows})
        out_rows = [old[k] for k in sorted(old)]
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _write_finish_json(rows: list[dict], merge: bool = False) -> None:
    _write_rows_json(
        rows, "finish", BENCH_FINISH_JSON,
        ("sim_s_per_job", "wall_us_per_job"), merge,
    )


def _write_pack_json(rows: list[dict], merge: bool = False) -> None:
    _write_rows_json(
        rows, "finish_pack", BENCH_PACK_JSON,
        ("sim_s_per_job", "repack_sim_s", "wall_us_per_job"), merge,
    )


def _pack_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    pack = {
        (r["case"], r["repo_files"]): r for r in rows
        if r["bench"] == "finish_pack"
    }
    claims = []
    if ("finish_packed", 200_000) in pack and ("finish_packed", 1_000) in pack:
        big = pack[("finish_packed", 200_000)]
        small = pack[("finish_packed", 1_000)]
        claims.append((
            "pack layer: aging slope ~0 (packed finish at 200k files"
            " within 1.1x of 1k)",
            big["sim_s_per_job"] <= 1.1 * small["sim_s_per_job"],
            f"{small['sim_s_per_job']:.2f}s -> {big['sim_s_per_job']:.2f}s"
            f" (repack amortized {big.get('repack_sim_s', 0.0):.0f}s once)",
        ))
    sizes = sorted(rf for c, rf in pack if c == "finish_packed")
    if len(sizes) >= 3:
        worst = max(pack[("finish_packed", rf)]["sim_s_per_job"] for rf in sizes)
        base = pack[("finish_packed", sizes[0])]["sim_s_per_job"]
        claims.append((
            f"pack layer: flat out to {max(sizes)} files"
            " (every point within 1.15x of the smallest)",
            worst <= 1.15 * base,
            f"{base:.2f}s .. {worst:.2f}s over {sizes}",
        ))
    return claims


def _write_ingest_json(rows: list[dict]) -> None:
    out_rows = [
        {k: r[k] for k in (
            "case", "data_plane", "ingest_workers", "n_jobs", "files_per_job",
            "mib_per_file", "output_bytes", "sim_s_total", "sim_s_per_job",
            "bytes_read", "bytes_written", "wall_s_total",
        )}
        for r in rows
        if r["bench"] == "ingest"
    ]
    path = os.path.normpath(BENCH_INGEST_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _ingest_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    ing = {r["case"]: r for r in rows if r["bench"] == "ingest"}
    claims = []
    if "ingest_seed" in ing and "ingest_fused" in ing:
        seed, fused = ing["ingest_seed"], ing["ingest_fused"]
        claims.append((
            "data plane: single-pass ingest charges ~2x less bytes_read than"
            " the seed path at equal output volume",
            fused["bytes_read"] <= 0.6 * seed["bytes_read"],
            f"seed={seed['bytes_read'] / 2**30:.2f}GiB"
            f" fused={fused['bytes_read'] / 2**30:.2f}GiB"
            f" ({fused['bytes_read'] / seed['bytes_read']:.2f}x),"
            f" writes {seed['bytes_written'] / 2**30:.2f}->"
            f"{fused['bytes_written'] / 2**30:.2f}GiB",
        ))
    if "ingest_fused" in ing and "ingest_pipelined" in ing:
        ser, par = ing["ingest_fused"], ing["ingest_pipelined"]
        claims.append((
            f"data plane: pipelined finish ({par['ingest_workers']} streams)"
            " < 0.5x the serial sim time at aggregate-bandwidth saturation",
            par["sim_s_total"] < 0.5 * ser["sim_s_total"],
            f"serial={ser['sim_s_total']:.2f}s"
            f" pipelined={par['sim_s_total']:.2f}s"
            f" ({par['sim_s_total'] / ser['sim_s_total']:.2f}x)",
        ))
    return claims


def check_ingest() -> None:
    """Bytes-heavy data-plane gate: single-pass ingest must ~halve charged
    reads, and the pipelined concurrent finish must beat 0.5x serial."""
    from . import bench_ingest

    rows = bench_ingest.run()
    _write_ingest_json(rows)
    ok = True
    for name, passed, detail in _ingest_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_faults_json(rows: list[dict]) -> None:
    out_rows = [
        {
            "case": r["case"],
            "n_jobs": r["n_jobs"],
            "repo_files": r["repo_files"],
            "sim_s_total": r["sim_s_total"],
            "sim_s_per_job": r["sim_s_per_job"],
            "wall_s_total": r["wall_s_total"],
        }
        for r in rows
        if r["bench"] == "faults"
    ]
    path = os.path.normpath(BENCH_FAULTS_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _faults_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    flt = {r["case"]: r for r in rows if r["bench"] == "faults"}
    claims = []
    if "finish_journal" in flt and "finish_nojournal" in flt:
        jrn, raw = flt["finish_journal"], flt["finish_nojournal"]
        claims.append((
            "robustness: intent journal keeps finish within 1.15x of"
            " unjournaled",
            jrn["sim_s_per_job"] <= 1.15 * raw["sim_s_per_job"],
            f"nojournal={raw['sim_s_per_job']:.3f}s"
            f" journal={jrn['sim_s_per_job']:.3f}s"
            f" ({jrn['sim_s_per_job'] / raw['sim_s_per_job']:.3f}x)",
        ))
    if "recover_midbatch" in flt and "finish_journal" in flt:
        rec, jrn = flt["recover_midbatch"], flt["finish_journal"]
        claims.append((
            "robustness: recovering a half-crashed batch costs less than"
            " re-finishing it, at zero divergence",
            rec["sim_s_total"] < jrn["sim_s_total"],
            f"recover={rec['sim_s_total']:.2f}s"
            f" ({rec['recovered_jobs']} jobs) vs"
            f" full finish={jrn['sim_s_total']:.2f}s",
        ))
    return claims


def check_faults() -> None:
    """Robustness cost gate: the exactly-once machinery (intent journal,
    crash recovery) must stay cheap. bench_faults itself asserts zero
    divergence after recovery; a failed assertion fails the gate."""
    from . import bench_faults

    rows = bench_faults.run()
    _write_faults_json(rows)
    ok = True
    for name, passed, detail in _faults_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_cache_json(rows: list[dict]) -> None:
    out_rows = [
        {
            "case": r["case"],
            "n_jobs": r["n_jobs"],
            "overlap": r["overlap"],
            "n_hits": r["n_hits"],
            "n_novel": r["n_novel"],
            "slurm_submissions": r["slurm_submissions"],
            "spec_roundtrip_ok": r["spec_roundtrip_ok"],
            "sim_s_total": r["sim_s_total"],
            "sim_s_per_job": r["sim_s_per_job"],
            "wall_s_total": r["wall_s_total"],
        }
        for r in rows
        if r["bench"] == "cache"
    ]
    path = os.path.normpath(BENCH_CACHE_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _cache_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    cache = {r["case"]: r for r in rows if r["bench"] == "cache"}
    if "sweep_cold" not in cache or "sweep_warm" not in cache:
        return []
    cold, warm = cache["sweep_cold"], cache["sweep_warm"]
    return [
        (
            f"run cache: {warm['n_jobs']}-spec sweep at"
            f" {warm['overlap']:.0%} overlap <= 0.15x the cold sweep",
            warm["sim_s_total"] <= 0.15 * cold["sim_s_total"],
            f"cold={cold['sim_s_total']:.1f}s warm={warm['sim_s_total']:.1f}s"
            f" ({warm['sim_s_total'] / cold['sim_s_total']:.3f}x,"
            f" {warm['n_hits']} hits)",
        ),
        (
            "run cache: cached specs submit nothing to Slurm",
            warm["slurm_submissions"] == warm["n_novel"]
            and warm["n_hits"] + warm["n_novel"] == warm["n_jobs"],
            f"{warm['slurm_submissions']} submissions for"
            f" {warm['n_novel']} novel specs ({warm['n_hits']} memoized)",
        ),
        (
            "run cache: memoized records reconstruct the original spec_id",
            bool(warm["spec_roundtrip_ok"]),
            f"{warm['n_hits']} memoized commits spec-verified",
        ),
    ]


def check_cache() -> None:
    """Run-cache gate: memoized re-submission must short-circuit (<= 0.15x
    cold, zero Slurm submissions for cached specs) and stay provenance-
    exact (memoized records reconstruct the original spec)."""
    from . import bench_cache

    rows = bench_cache.run()
    _write_cache_json(rows)
    ok = True
    for name, passed, detail in _cache_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_ckpt_json(rows: list[dict]) -> None:
    out_rows = [
        {
            "case": r["case"],
            "n_steps": r["n_steps"],
            "churn": r["churn"],
            "state_bytes": r["state_bytes"],
            "full_ingest_bytes": r["full_ingest_bytes"],
            "steady_bytes_per_step": r["steady_bytes_per_step"],
            "full_ingest_sim_s": r["full_ingest_sim_s"],
            "steady_sim_s_per_step": r["steady_sim_s_per_step"],
            "cold_restore_bytes": r["cold_restore_bytes"],
            "delta_restore_bytes": r["delta_restore_bytes"],
            "restore_serial_sim_s": r["restore_serial_sim_s"],
            "restore_parallel_sim_s": r["restore_parallel_sim_s"],
            "fetch_workers": r["fetch_workers"],
            "restore_bitwise_ok": r["restore_bitwise_ok"],
            "wall_s_total": r["wall_s_total"],
        }
        for r in rows
        if r["bench"] == "ckpt"
    ]
    path = os.path.normpath(BENCH_CKPT_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _ckpt_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    ckpt = {r["case"]: r for r in rows if r["bench"] == "ckpt"}
    if "ckpt_whole" not in ckpt or "ckpt_chunked" not in ckpt:
        return []
    whole, chunked = ckpt["ckpt_whole"], ckpt["ckpt_chunked"]
    ratio = chunked["steady_bytes_per_step"] / whole["steady_bytes_per_step"]
    delta_ratio = (
        chunked["delta_restore_bytes"] / chunked["cold_restore_bytes"]
        if chunked["cold_restore_bytes"] else 1.0
    )
    return [
        (
            f"chunked annex: {chunked['churn']:.0%}-churn campaign ingests"
            " <= 0.15x the whole-object bytes per step",
            ratio <= 0.15,
            f"whole={whole['steady_bytes_per_step'] / 2**20:.2f}MiB/step"
            f" chunked={chunked['steady_bytes_per_step'] / 2**20:.2f}MiB/step"
            f" ({ratio:.3f}x)",
        ),
        (
            "chunked annex: every campaign step restores bit-identical"
            " (incl. bf16)",
            bool(whole["restore_bitwise_ok"])
            and bool(chunked["restore_bitwise_ok"]),
            f"{whole['n_steps']} whole + {chunked['n_steps']} chunked steps"
            " digest-verified",
        ),
        (
            "chunked annex: warm delta-restore moves <= 0.2x the cold"
            " restore's bytes",
            delta_ratio <= 0.2,
            f"cold={chunked['cold_restore_bytes'] / 2**20:.2f}MiB"
            f" delta={chunked['delta_restore_bytes'] / 2**20:.2f}MiB"
            f" ({delta_ratio:.3f}x)",
        ),
    ]


def check_ckpt() -> None:
    """Checkpoint-campaign gate: the chunk tier must turn a ~3%-churn
    campaign into delta-sized ingests and fetches, without ever giving up
    bit-identical restore."""
    from . import bench_ckpt

    rows = bench_ckpt.run()
    _write_ckpt_json(rows)
    ok = True
    for name, passed, detail in _ckpt_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_remote_json(rows: list[dict]) -> None:
    out_rows = [
        {
            "case": r["case"],
            "n_objs": r["n_objs"],
            "bytes_moved": r["bytes_moved"],
            "chunks_moved": r["chunks_moved"],
            "retries": r["retries"],
            "failovers": r["failovers"],
            "sim_s": r["sim_s"],
            "wall_s": r["wall_s"],
        }
        for r in rows
        if r["bench"] == "remote"
    ]
    path = os.path.normpath(BENCH_REMOTE_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _remote_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    rem = {r["case"]: r for r in rows if r["bench"] == "remote"}
    claims = []
    if "push_cold" in rem and "push_incremental" in rem:
        cold, inc = rem["push_cold"], rem["push_incremental"]
        ratio = (
            inc["bytes_moved"] / cold["bytes_moved"]
            if cold["bytes_moved"] else 1.0
        )
        claims.append((
            f"remote tier: incremental push at {inc['churn']:.0%} churn"
            " moves <= 0.2x the cold push's bytes",
            ratio <= 0.2,
            f"cold={cold['bytes_moved'] / 2**20:.2f}MiB"
            f" ({cold['chunks_moved']} chunks)"
            f" incremental={inc['bytes_moved'] / 2**20:.2f}MiB"
            f" ({inc['chunks_moved']} chunks, {ratio:.3f}x)",
        ))
    if "pull_degraded" in rem:
        deg = rem["pull_degraded"]
        claims.append((
            "remote tier: degraded-network pull completes within the"
            " bounded retry budget",
            bool(deg["completed"]) and deg["retries"] <= deg["retry_budget"],
            f"{deg['n_objs']} keys restored, {deg['retries']} retries"
            f" (budget {deg['retry_budget']}),"
            f" sim {deg['sim_s']:.1f}s vs clean {deg['clean_sim_s']:.1f}s",
        ))
    return claims


def check_remote() -> None:
    """Remote annex tier gate: chunk-level delta push must keep a churn
    campaign's transfer delta-sized, and the retry/backoff machinery must
    carry a pull through a degraded link without unbounded retries."""
    from . import bench_remote

    rows = bench_remote.run()
    _write_remote_json(rows)
    ok = True
    for name, passed, detail in _remote_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_dag_json(rows: list[dict]) -> None:
    out_rows = [
        {
            "case": r["case"],
            "n_stages": r["n_stages"],
            "n_levels": r["n_levels"],
            "submit_batches": r["submit_batches"],
            "slurm_submissions": r["slurm_submissions"],
            "n_memoized": r["n_memoized"],
            "all_finished": r["all_finished"],
            "deps_ok": r["deps_ok"],
            "sim_s_total": r["sim_s_total"],
            "wall_s_total": r["wall_s_total"],
        }
        for r in rows
        if r["bench"] == "dag"
    ]
    path = os.path.normpath(BENCH_DAG_JSON)
    with open(path, "w") as f:
        json.dump(out_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(out_rows)} rows)", file=sys.stderr)


def _dag_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    dag = {r["case"]: r for r in rows if r["bench"] == "dag"}
    if "campaign_cold" not in dag or "campaign_replay" not in dag:
        return []
    cold, warm = dag["campaign_cold"], dag["campaign_replay"]
    cone = 2  # one invalidated train stage + its eval dependent
    return [
        (
            f"pipeline DAG: {cold['n_stages']}-stage {cold['n_levels']}-level"
            " campaign submits in one batch per level",
            cold["submit_batches"] == cold["n_levels"]
            and cold["slurm_submissions"] == cold["n_stages"],
            f"{cold['submit_batches']} batches for"
            f" {cold['slurm_submissions']} jobs",
        ),
        (
            "pipeline DAG: afterok ordering held on every edge",
            bool(cold["all_finished"]) and bool(cold["deps_ok"]),
            f"{cold['n_stages']} stages finished, edges point at producers",
        ),
        (
            "pipeline DAG: partial replay <= 0.3x the cold campaign",
            warm["sim_s_total"] <= 0.3 * cold["sim_s_total"],
            f"cold={cold['sim_s_total']:.1f}s warm={warm['sim_s_total']:.1f}s"
            f" ({warm['sim_s_total'] / cold['sim_s_total']:.3f}x)",
        ),
        (
            "pipeline DAG: replay resubmits only the invalidated cone",
            warm["slurm_submissions"] == cone
            and warm["n_memoized"] == warm["n_stages"] - cone
            and bool(warm["all_finished"]),
            f"{warm['slurm_submissions']} resubmissions,"
            f" {warm['n_memoized']}/{warm['n_stages']} memoized",
        ),
    ]


def check_dag() -> None:
    """Pipeline DAG gate: a multi-level campaign must submit as one
    topological batch per level with afterok ordering intact, and a
    partial replay must re-execute only the invalidated cone at a
    fraction of the cold campaign's cost."""
    from . import bench_dag

    rows = bench_dag.run()
    _write_dag_json(rows)
    ok = True
    for name, passed, detail in _dag_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def _write_schedule_json(rows: list[dict]) -> None:
    batch_rows = [
        {
            "case": r["case"],
            "n_jobs": r["n_jobs"],
            "sim_s_total": r["sim_s_total"],
            "sim_s_per_job": r["sim_s_per_job"],
            "wall_us_per_job": r["wall_us_per_job"],
        }
        for r in rows
        if r["bench"] == "schedule_batch"
    ]
    path = os.path.normpath(BENCH_SCHEDULE_JSON)
    with open(path, "w") as f:
        json.dump(batch_rows, f, indent=1)
        f.write("\n")
    print(f"# wrote {path} ({len(batch_rows)} rows)", file=sys.stderr)


def _schedule_batch_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    batch = {r["case"]: r for r in rows if r["bench"] == "schedule_batch"}
    if "submit_many" not in batch or "submit_per_job" not in batch:
        return []
    many = batch["submit_many"]["sim_s_total"]
    single = batch["submit_per_job"]["sim_s_total"]
    n = batch["submit_many"]["n_jobs"]
    return [(
        f"spec layer: submit_many({n}) < 0.5x the sum of per-job submits",
        many < 0.5 * single,
        f"batched={many:.2f}s vs per-job={single:.2f}s "
        f"({many / single:.2f}x)",
    )]


def _finish_claims(fin: dict) -> list[tuple[str, bool, str]]:
    claims = []
    if ("finish_pfs_legacy", 200_000) in fin and ("finish_pfs_legacy", 1_000) in fin:
        blow = fin[("finish_pfs_legacy", 200_000)]["sim_s_per_job"]
        small = fin[("finish_pfs_legacy", 1_000)]["sim_s_per_job"]
        claims.append((
            "C3: full-rebuild finish blows up past 50k files on the parallel FS"
            " (paper: >10s/job)",
            blow > 10.0 and blow > 5 * small, f"{small:.2f}s -> {blow:.2f}s",
        ))
    if ("finish_altdir", 200_000) in fin:
        alt_big = fin[("finish_altdir", 200_000)]["sim_s_per_job"]
        claims.append(("C3: --alt-dir stays flat (paper: 0.6-1.7s/job)",
                       alt_big < 3.0, f"{alt_big:.2f}s at 200k files"))
    if ("finish_pfs", 200_000) in fin and ("finish_pfs", 1_000) in fin:
        inc_big = fin[("finish_pfs", 200_000)]["sim_s_per_job"]
        inc_small = fin[("finish_pfs", 1_000)]["sim_s_per_job"]
        claims.append((
            "incremental engine: finish ~flat on the parallel FS"
            " (200k files within 2x of 1k)",
            inc_big < 2.0 * inc_small, f"{inc_small:.2f}s -> {inc_big:.2f}s",
        ))
    if ("finish_pfs", 100_000) in fin and ("finish_pfs", 1_000) in fin:
        mid = fin[("finish_pfs", 100_000)]["sim_s_per_job"]
        inc_small = fin[("finish_pfs", 1_000)]["sim_s_per_job"]
        claims.append((
            "perf-regression gate: finish at 100k files <= 3x the 1k cost",
            mid <= 3.0 * inc_small, f"{inc_small:.2f}s -> {mid:.2f}s",
        ))
    return claims


def check_finish() -> None:
    """Fast regression gate on finish scaling (incremental engine only)."""
    from . import bench_finish

    # same jobs_per_size as the full sweep so merged rows share one methodology
    rows = bench_finish.run(sizes=(1_000, 100_000), cases=("finish_pfs",))
    _write_finish_json(rows, merge=True)
    fin = {(r["case"], r["repo_files"]): r for r in rows}
    ok = True
    for name, passed, detail in _finish_claims(fin):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def check_pack() -> None:
    """Fast regression gate on the pack layer's aging curve: packed finish
    at 200k repo files must stay within 1.1x of the 1k cost."""
    from . import bench_finish

    rows = bench_finish.run(
        cases=("finish_packed",), aging_sizes=(1_000, 200_000)
    )
    _write_pack_json(rows, merge=True)
    ok = True
    for name, passed, detail in _pack_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def check_schedule() -> None:
    """Fast regression gate on the spec layer's batched submission: 64 jobs
    through one ``submit_many`` must cost < 0.5x the sum of 64 individual
    submissions on the sim clock."""
    from . import bench_schedule

    rows = bench_schedule.run_batched(n_jobs=64)
    _write_schedule_json(rows)
    ok = True
    for name, passed, detail in _schedule_batch_claims(rows):
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


def main() -> None:
    from . import (
        bench_cache, bench_ckpt, bench_conflicts, bench_dag, bench_faults,
        bench_finish, bench_ingest, bench_octopus, bench_remote,
        bench_schedule,
    )

    rows = []
    print("# running bench_schedule (paper Fig. 7/8) ...", file=sys.stderr)
    rows += bench_schedule.run()
    print("# running bench_schedule batching (spec layer) ...", file=sys.stderr)
    rows += bench_schedule.run_batched()
    print("# running bench_finish (paper Fig. 9/10) ...", file=sys.stderr)
    rows += bench_finish.run()
    print("# running bench_ingest (data plane, §9) ...", file=sys.stderr)
    rows += bench_ingest.run()
    print("# running bench_faults (robustness cost, §10) ...", file=sys.stderr)
    rows += bench_faults.run()
    print("# running bench_cache (run cache, §11) ...", file=sys.stderr)
    rows += bench_cache.run()
    print("# running bench_ckpt (chunked data plane, §12) ...", file=sys.stderr)
    rows += bench_ckpt.run()
    print("# running bench_remote (remote tier, §13) ...", file=sys.stderr)
    rows += bench_remote.run()
    print("# running bench_dag (pipeline DAG, §14) ...", file=sys.stderr)
    rows += bench_dag.run()
    print("# running bench_conflicts (§5.5) ...", file=sys.stderr)
    rows += bench_conflicts.run()
    print("# running bench_octopus (Fig. 6 / A2) ...", file=sys.stderr)
    rows += bench_octopus.run()

    _write_finish_json(rows)
    _write_schedule_json(rows)
    _write_pack_json(rows)
    _write_ingest_json(rows)
    _write_faults_json(rows)
    _write_cache_json(rows)
    _write_ckpt_json(rows)
    _write_remote_json(rows)
    _write_dag_json(rows)

    print("name,us_per_call,derived")
    claims = []
    sched = {}
    for r in rows:
        if r["bench"] == "schedule":
            name = f"schedule/{r['case']}/{r['outputs_per_job']}out"
            us = r["wall_us_per_job"]
            derived = f"sim={r['sim_s_per_job']:.3f}s_per_job"
            sched[(r["case"], r["outputs_per_job"])] = r
        elif r["bench"] == "schedule_batch":
            name = f"schedule_batch/{r['case']}/{r['n_jobs']}jobs"
            us = r["wall_us_per_job"]
            derived = f"sim={r['sim_s_per_job']:.3f}s_per_job"
        elif r["bench"] in ("finish", "finish_pack"):
            name = f"finish/{r['case']}/{r['repo_files']}files"
            us = r["wall_us_per_job"]
            derived = f"sim={r['sim_s_per_job']:.3f}s_per_job"
        elif r["bench"] == "ingest":
            name = f"ingest/{r['case']}/{r['n_jobs']}jobs"
            us = r["wall_s_total"] * 1e6 / r["n_jobs"]
            derived = f"sim={r['sim_s_total']:.3f}s_total"
        elif r["bench"] == "faults":
            name = f"faults/{r['case']}/{r['n_jobs']}jobs"
            us = r["wall_s_total"] * 1e6 / r["n_jobs"]
            derived = f"sim={r['sim_s_total']:.3f}s_total"
        elif r["bench"] == "cache":
            name = f"cache/{r['case']}/{r['n_jobs']}jobs"
            us = r["wall_s_total"] * 1e6 / r["n_jobs"]
            derived = f"sim={r['sim_s_total']:.3f}s_total"
        elif r["bench"] == "ckpt":
            name = f"ckpt/{r['case']}/{r['n_steps']}steps"
            us = r["wall_s_total"] * 1e6 / r["n_steps"]
            derived = (
                f"steady={r['steady_bytes_per_step'] / 2**20:.2f}MiB_per_step"
            )
        elif r["bench"] == "remote":
            name = f"remote/{r['case']}/{r['n_objs']}objs"
            us = r["wall_s"] * 1e6 / r["n_objs"]
            derived = f"moved={r['bytes_moved'] / 2**20:.2f}MiB"
        elif r["bench"] == "dag":
            name = f"dag/{r['case']}/{r['n_stages']}stages"
            us = r["wall_s_total"] * 1e6 / r["n_stages"]
            derived = f"sim={r['sim_s_total']:.3f}s_total"
        elif r["bench"] == "conflict_check":
            name = f"conflicts/{r['scheduled_jobs']}jobs"
            us = r["wall_us_per_check"]
            derived = "per_output_check"
        else:
            name = f"octopus/{r['n_jobs']}jobs"
            us = r["wall_us_total"]
            derived = f"parents={r['merge_parents']}"
        print(f"{name},{us:.1f},{derived}")

    # ---- paper-claim checks -------------------------------------------
    for n_out in (4, 8, 12):
        pfs = sched[("schedule_pfs", n_out)]
        alt = sched[("schedule_altdir", n_out)]
        base = sched[("pure_sbatch", n_out)]
        off_pfs = pfs["sim_s_per_job"] - base["sim_s_per_job"]
        claims.append(
            ("C2: schedule offset %d outputs (paper: ~0.35-0.7s, const)" % n_out,
             0.2 < off_pfs < 1.0
             and abs(pfs["sim_s_last_quartile"] - pfs["sim_s_first_quartile"])
             < 0.5 * pfs["sim_s_per_job"],
             f"offset={off_pfs:.2f}s alt={alt['sim_s_per_job'] - base['sim_s_per_job']:.2f}s")
        )
    fin = {(r["case"], r["repo_files"]): r for r in rows if r["bench"] == "finish"}
    claims += _finish_claims(fin)
    claims += _pack_claims(rows)
    claims += _schedule_batch_claims(rows)
    claims += _ingest_claims(rows)
    claims += _faults_claims(rows)
    claims += _cache_claims(rows)
    claims += _ckpt_claims(rows)
    claims += _remote_claims(rows)
    claims += _dag_claims(rows)
    conf = {r["scheduled_jobs"]: r for r in rows if r["bench"] == "conflict_check"}
    claims.append(("§5.5: conflict check ~O(1) in scheduled jobs",
                   conf[50_000]["wall_us_per_check"] < 20 * conf[100]["wall_us_per_check"],
                   f"{conf[100]['wall_us_per_check']:.0f}us@100 -> "
                   f"{conf[50_000]['wall_us_per_check']:.0f}us@50k"))

    print()
    print("# paper-claim checks")
    ok = True
    for name, passed, detail in claims:
        ok &= passed
        print(f"# [{'PASS' if passed else 'FAIL'}] {name}: {detail}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--check-all" in args:
        # all nine gates in one invocation; report every failure, then exit
        failed = []
        for name, gate in (
            ("finish", check_finish), ("schedule", check_schedule),
            ("pack", check_pack), ("ingest", check_ingest),
            ("faults", check_faults), ("cache", check_cache),
            ("ckpt", check_ckpt), ("remote", check_remote),
            ("dag", check_dag),
        ):
            print(f"# --check-{name} ...", file=sys.stderr)
            try:
                gate()
            except SystemExit as e:
                if e.code:
                    failed.append(name)
        if failed:
            print(f"# FAILED gates: {', '.join(failed)}", file=sys.stderr)
            raise SystemExit(1)
        raise SystemExit(0)
    ran_gate = False
    if "--check-finish" in args:
        check_finish()
        ran_gate = True
    if "--check-schedule" in args:
        check_schedule()
        ran_gate = True
    if "--check-pack" in args:
        check_pack()
        ran_gate = True
    if "--check-ingest" in args:
        check_ingest()
        ran_gate = True
    if "--check-faults" in args:
        check_faults()
        ran_gate = True
    if "--check-cache" in args:
        check_cache()
        ran_gate = True
    if "--check-ckpt" in args:
        check_ckpt()
        ran_gate = True
    if "--check-remote" in args:
        check_remote()
        ran_gate = True
    if "--check-dag" in args:
        check_dag()
        ran_gate = True
    if not ran_gate:
        main()
