"""Shared benchmark scaffolding.

Two clocks are reported for every paper-reproduction benchmark:
  - ``wall``: real wall time of the code path on this container (the cost of
    our in-process implementation), and
  - ``sim``: modeled filesystem/Slurm seconds from the virtual clock
    (repro.core.fsio), calibrated to the paper's GPFS/XFS/Slurm measurements
    — this is the quantity to compare against the paper's figures.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from contextlib import contextmanager

from repro.core.fsio import FS, GPFS, LOCAL_XFS, FSProfile, SimClock
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.slurm import LocalSlurmCluster

JOB_BODY = """#!/bin/bash
for i in $(seq 1 20); do echo "line $i for job $SLURM_JOB_ID"; done > out.txt
bzip2 -kf out.txt
{extra}
"""


def make_env(profile: FSProfile, n_extra_outputs: int = 0, max_workers: int = 8):
    """Repository + cluster + scheduler on the given FS profile."""
    root = tempfile.mkdtemp(prefix=f"bench_{profile.name}_")
    clock = SimClock()
    repo = Repository.init(os.path.join(root, "repo"), profile=profile,
                           clock=clock, annex_threshold=256)
    cluster = LocalSlurmCluster(
        max_workers=max_workers, clock=clock, sbatch_cost_s=0.05, sacct_cost_s=0.02
    )
    sched = SlurmScheduler(repo, cluster)
    return root, repo, cluster, sched, clock


def write_job_dir(repo, j: int, n_extra_outputs: int = 0) -> list[str]:
    """One sub-directory per job with the Slurm job script inside (paper's
    experiment setup). Returns the job's output paths."""
    d = os.path.join(repo.root, "jobs", str(j))
    os.makedirs(d, exist_ok=True)
    extra = "\n".join(
        f"md5sum out.txt out.txt.bz2 > hash_{i}.txt" for i in range(n_extra_outputs)
    )
    with open(os.path.join(d, "slurm.sh"), "w") as f:
        f.write(JOB_BODY.format(extra=extra))
    return [f"jobs/{j}"]


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def cleanup(root):
    shutil.rmtree(root, ignore_errors=True)
