"""Shared benchmark scaffolding.

Two clocks are reported for every paper-reproduction benchmark:
  - ``wall``: real wall time of the code path on this container (the cost of
    our in-process implementation), and
  - ``sim``: modeled filesystem/Slurm seconds from the virtual clock
    (repro.core.fsio), calibrated to the paper's GPFS/XFS/Slurm measurements
    — this is the quantity to compare against the paper's figures.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from contextlib import contextmanager

from repro.core.fsio import FS, GPFS, LOCAL_XFS, FSProfile, SimClock
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.slurm import LocalSlurmCluster

JOB_BODY = """#!/bin/bash
for i in $(seq 1 20); do echo "line $i for job $SLURM_JOB_ID"; done > out.txt
bzip2 -kf out.txt
{extra}
"""


def make_env(profile: FSProfile, n_extra_outputs: int = 0, max_workers: int = 8,
             auto_repack_threshold: int | None = None,
             ingest_workers: int = 0):
    """Repository + cluster + scheduler on the given FS profile.

    ``auto_repack_threshold`` defaults to None (auto-repack OFF) so the
    aging-trajectory cases keep the accumulated directory pressure they are
    measuring; the packed cases enable it explicitly. ``ingest_workers``
    sets finish()'s data-plane fan-out width (0 = serial)."""
    root = tempfile.mkdtemp(prefix=f"bench_{profile.name}_")
    clock = SimClock()
    repo = Repository.init(os.path.join(root, "repo"), profile=profile,
                           clock=clock, annex_threshold=256)
    cluster = LocalSlurmCluster(
        max_workers=max_workers, clock=clock, sbatch_cost_s=0.05, sacct_cost_s=0.02
    )
    sched = SlurmScheduler(repo, cluster,
                           auto_repack_threshold=auto_repack_threshold,
                           ingest_workers=ingest_workers)
    return root, repo, cluster, sched, clock


def seed_repo_files(repo, n_files: int, files_per_dir: int = 50) -> None:
    """Emulate a repository that has already accumulated ``n_files`` committed
    files (the paper's independent variable).

    Materializes the *tree objects* of a synthetic base commit for real —
    ``data/d<i>/f<j>`` entries sharing one blob — so a full-rebuild save walks
    a genuinely large tree, and seeds the modeled entry counts of the object
    store's shard directories to ``n_files / 256`` (one entry per object the
    repository would have accumulated), which is what parallel-FS metadata
    latency degrades with. Charges accrued during seeding happen before the
    benchmark snapshots the clock, so they never pollute per-job figures.
    """
    if n_files <= 0:
        return
    blob_oid = repo.objects.put_blob(b"seeded file payload\n")
    flat = {}
    for i in range(0, n_files, files_per_dir):
        d = f"data/d{i // files_per_dir:05d}"
        for j in range(min(files_per_dir, n_files - i)):
            flat[f"{d}/f{j:03d}"] = {"t": "blob", "oid": blob_oid}
    tree_oid = repo._write_nested(flat)
    branch = repo.current_branch()
    base = repo.branch_head(branch)
    commit_oid = repo.objects.put_commit({
        "tree": tree_oid,
        "parents": [base] if base else [],
        "author": "seed",
        "timestamp": time.time(),
        "message": f"synthetic base: {n_files} files",
    })
    repo.set_branch(branch, commit_oid)
    per_shard = n_files // 256
    for shard in range(256):
        repo.fs.preload_dir_entries(
            os.path.join(repo.objects.root, f"{shard:02x}"), per_shard
        )
    repo.fs.n_files += n_files


def write_job_dir(repo, j: int, n_extra_outputs: int = 0) -> list[str]:
    """One sub-directory per job with the Slurm job script inside (paper's
    experiment setup). Returns the job's output paths."""
    d = os.path.join(repo.root, "jobs", str(j))
    os.makedirs(d, exist_ok=True)
    extra = "\n".join(
        f"md5sum out.txt out.txt.bz2 > hash_{i}.txt" for i in range(n_extra_outputs)
    )
    with open(os.path.join(d, "slurm.sh"), "w") as f:
        f.write(JOB_BODY.format(extra=extra))
    return [f"jobs/{j}"]


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def cleanup(root):
    shutil.rmtree(root, ignore_errors=True)
