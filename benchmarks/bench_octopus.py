"""Paper Figure 6 / artifact A2 (contribution C4): per-job branches merged
with a single N-parent octopus merge after concurrent Slurm jobs."""
from __future__ import annotations

from repro.core.fsio import LOCAL_XFS
from repro.core.spec import RunSpec

from .common import cleanup, make_env, timer, write_job_dir


def run(n_jobs: int = 8) -> list[dict]:
    root, repo, cluster, sched, clock = make_env(LOCAL_XFS)
    import os
    with open(os.path.join(repo.root, "README"), "w") as f:
        f.write("octopus demo\n")
    repo.save(message="base")
    specs = []
    for j in range(n_jobs):
        write_job_dir(repo, j)
        specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                             pwd=f"jobs/{j}"))
    sched.submit_many(specs)
    cluster.wait(timeout=600)
    with timer() as t:
        results = sched.finish(octopus=True)
    cluster.shutdown()
    head = repo.head_commit()
    merge = repo.objects.get_commit(head)
    assert len(merge["parents"]) == n_jobs + 1, "octopus merge shape"
    assert all(r.branch for r in results)
    row = {
        "bench": "octopus",
        "n_jobs": n_jobs,
        "merge_parents": len(merge["parents"]),
        "wall_us_total": t["s"] * 1e6,
        "branches": len(repo.branches()),
    }
    cleanup(root)
    return [row]


if __name__ == "__main__":
    for r in run():
        print(r)
