"""Pipeline DAG benchmark (DESIGN.md §14): what do topological batching
and cache-aware partial replay buy at campaign scale?

A 3-level fan campaign — one ``prep`` stage feeding W parallel
``train_i`` chains, each feeding an ``eval_i`` stage (1 + 2W stages,
2W afterok edges):

  campaign_cold    the whole DAG submitted as ONE ``submit_pipeline``
                   call: one topologically-batched ``submit_many`` per
                   level (3 batches however wide the fan), dependents
                   chained with afterok so nothing polls between levels.
  campaign_replay  one train script is edited (scripts are declared as
                   inputs, so its stage's execution key changes) and the
                   identical pipeline is resubmitted: every stage outside
                   the invalidated cone short-circuits from the §11 run
                   cache; exactly train_k + eval_k re-execute.

The gate (benchmarks/run.py ``--check-dag``) holds four claims:
  (a) the 3-level campaign costs exactly 3 submit batches,
  (b) afterok ordering holds: every eval stage consumed its parent's
      output (the scripts fail hard if started early) and every recorded
      dependency edge points at its producing stage,
  (c) the partial replay costs <= 0.3x the cold campaign on the sim
      clock, and
  (d) the replay resubmits ONLY the invalidated cone (2 Slurm
      submissions; all other stages close as 'memoized').

Rows are tagged ``bench="dag"`` and land in ``BENCH_dag.json``.
"""
from __future__ import annotations

import os
import tempfile

from repro.core.dag import Pipeline
from repro.core.fsio import GPFS, SimClock
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.slurm import LocalSlurmCluster
from repro.core.spec import RunSpec

from .common import cleanup, timer

N_CHAINS = 40  # W parallel train->eval chains off one prep stage

_PREP = "#!/bin/bash\nmkdir -p data; printf 'd%.0s' {1..400} > data/seed.dat\n"
_TRAIN = "#!/bin/bash\nset -e\ncat data/seed.dat data/seed.dat > model{i}.bin\n"
_EVAL = "#!/bin/bash\nset -e\nwc -c < model{i}.bin > score{i}.txt\n"


def _make_env():
    root = tempfile.mkdtemp(prefix="bench_dag_")
    clock = SimClock()
    repo = Repository.init(
        os.path.join(root, "repo"), profile=GPFS, clock=clock,
        annex_threshold=256,
    )
    cluster = LocalSlurmCluster(
        max_workers=8, clock=clock, sbatch_cost_s=0.05, sacct_cost_s=0.02
    )
    sched = SlurmScheduler(repo, cluster)
    return root, repo, cluster, sched, clock


def _write(repo, rel: str, data: str) -> None:
    with open(os.path.join(repo.root, rel), "w") as f:
        f.write(data)


def _pipeline(repo, n_chains: int) -> Pipeline:
    """Scripts are declared as inputs so editing one invalidates exactly
    its stage's cache entry (spec.execution_key keys declared inputs)."""
    _write(repo, "prep.sh", _PREP)
    stages = {
        "prep": RunSpec(
            script="prep.sh", inputs=["prep.sh"], outputs=["data/seed.dat"]
        )
    }
    for i in range(n_chains):
        _write(repo, f"train{i}.sh", _TRAIN.format(i=i))
        _write(repo, f"eval{i}.sh", _EVAL.format(i=i))
        stages[f"train{i}"] = RunSpec(
            script=f"train{i}.sh",
            inputs=[f"train{i}.sh", "data/seed.dat"],
            outputs=[f"model{i}.bin"],
        )
        stages[f"eval{i}"] = RunSpec(
            script=f"eval{i}.sh",
            inputs=[f"eval{i}.sh", f"model{i}.bin"],
            outputs=[f"score{i}.txt"],
        )
    return Pipeline(stages)


def _campaign(repo, cluster, sched, pipeline):
    """submit_pipeline -> wait -> finish, counting submit_many batches."""
    clock = repo.fs.clock
    batches: list[int] = []
    real = sched.submit_many

    def counting(specs, **kw):
        batches.append(len(specs))
        return real(specs, **kw)

    sched.submit_many = counting
    s0 = clock.snapshot()
    try:
        with timer() as t:
            jobs = sched.submit_pipeline(pipeline)
            open_rows = [
                r for jid in jobs.values()
                if (r := sched.db.get(jid)) and r["status"] == "scheduled"
            ]
            if open_rows:
                cluster.wait([r["slurm_id"] for r in open_rows], timeout=600)
            sched.finish()
    finally:
        del sched.submit_many  # restore the bound method
    return jobs, batches, clock.snapshot() - s0, t["s"]


def run(n_chains: int = N_CHAINS) -> list[dict]:
    root, repo, cluster, sched, clock = _make_env()
    try:
        n_stages = 1 + 2 * n_chains
        pipeline = _pipeline(repo, n_chains)
        assert len(pipeline.levels()) == 3

        jobs, batches, cold_sim, cold_wall = _campaign(
            repo, cluster, sched, pipeline
        )
        rows = {n: sched.db.get(j) for n, j in jobs.items()}
        cold_finished = all(r["status"] == "finished" for r in rows.values())
        # afterok claim (b), structural half: every recorded edge points
        # from the stage that produces the dependent's input
        deps_ok = cold_finished
        for i in range(n_chains):
            parents = sched.db.parents_of(jobs[f"eval{i}"])
            deps_ok &= [p["stage"] for p in parents] == [f"train{i}"]

        # invalidate one chain: the edited script is a declared input, so
        # train0's execution key changes and eval0 rides its cone
        # (_pipeline rewrites the stock scripts, so edit after building)
        replay = _pipeline(repo, n_chains)
        _write(repo, "train0.sh", _TRAIN.format(i=0) + "# retuned\n")
        jobs2, batches2, warm_sim, warm_wall = _campaign(
            repo, cluster, sched, replay
        )
        rows2 = {n: sched.db.get(j) for n, j in jobs2.items()}
        n_memo = sum(1 for r in rows2.values() if r["status"] == "memoized")
        n_slurm = sum(
            1 for r in rows2.values() if r["slurm_id"] is not None
        )
        replay_ok = all(
            r["status"] in ("finished", "memoized") for r in rows2.values()
        )

        base = {"bench": "dag", "n_stages": n_stages, "n_levels": 3}
        return [
            {
                **base, "case": "campaign_cold",
                "submit_batches": len(batches),
                "slurm_submissions": sum(batches),
                "n_memoized": 0,
                "all_finished": bool(cold_finished),
                "deps_ok": bool(deps_ok),
                "sim_s_total": cold_sim, "wall_s_total": cold_wall,
            },
            {
                **base, "case": "campaign_replay",
                "submit_batches": len(batches2),
                "slurm_submissions": n_slurm,
                "n_memoized": n_memo,
                "all_finished": bool(replay_ok),
                "deps_ok": bool(deps_ok),
                "sim_s_total": warm_sim, "wall_s_total": warm_wall,
            },
        ]
    finally:
        cluster.shutdown()
        cleanup(root)


if __name__ == "__main__":
    for r in run():
        print(r)
