"""§5.5 feasibility: output-conflict checking cost vs number of currently
scheduled jobs (the paper observed no measurable growth up to 10 000 jobs;
the N/P-set algorithm is O(depth) per output)."""
from __future__ import annotations

import numpy as np

from repro.core.conflicts import ProtectedOutputs

from .common import timer


def run(sizes=(100, 1_000, 10_000, 50_000)) -> list[dict]:
    rows = []
    for n in sizes:
        prot = ProtectedOutputs()
        for j in range(n):
            prot.check_and_add_all([f"jobs/{j // 100}/{j}/outdir"], j)
        # measure checks against a DB of n protected outputs
        with timer() as t:
            for i in range(1_000):
                prot.check_and_add_all([f"probe/{n}/{i}/outdir"], 10**6 + i)
        rows.append({
            "bench": "conflict_check",
            "scheduled_jobs": n,
            "wall_us_per_check": t["s"] / 1_000 * 1e6,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
