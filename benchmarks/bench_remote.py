"""Remote annex tier benchmark (DESIGN.md §13): what does the transfer
protocol cost over a realistic link, and what does chunk-level delta push
buy a multisite campaign?

Two campaigns on the WAN preset (30 ms RTT, 1 Gb/s up / 2 Gb/s down, four
parallel streams per direction):

  push_cold         N fresh chunked objects pushed to an empty site: every
                    chunk moves, plus one manifest bind and one batched
                    presence round trip per object set.
  push_incremental  ~3% contiguous churn per object, re-saved, re-pushed:
                    the batched presence pre-pass skips every unchanged
                    chunk, so only the churn footprint moves.
  pull_cold         the same content restored into an emptied local annex
                    (drop + gc, content only on the site) over a clean
                    link.
  pull_degraded     the same cold restore over a degraded link: seeded
                    transient request errors and sub-timeout stalls on
                    every direction. The transfer must *complete* — every
                    key restored — with the retry count bounded by the
                    fault model's per-operation budget.

The local filesystem is the null profile, so sim seconds isolate the
link: round trips, bandwidth, stalls, and backoff charges.

The gate (benchmarks/run.py ``--check-remote``) holds two claims:
  (a) the incremental push moves <= 0.2x the cold push's bytes at ~3%
      churn,
  (b) the degraded-network pull completes (all keys restored) within the
      bounded retry budget (<= max_retries per remote operation).

Rows are tagged ``bench="remote"`` and land in ``BENCH_remote.json``.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

import repro
from repro.core import NetFaultRule, NetworkFaultModel
from repro.core.chunks import ChunkParams
from repro.core.fsio import SimClock

from .common import cleanup, timer

N_OBJS = 16
OBJ_BYTES = 128 << 10
CHURN = 0.03

# ~8 KiB average chunks: a 3% contiguous churn region of a 128 KiB object
# touches a handful of chunks, not the object
CHUNK_THRESHOLD = 16 << 10
CHUNK_PARAMS = ChunkParams(min_size=2 << 10, avg_bits=13, max_size=32 << 10)

MAX_RETRIES = 4


def _open(root, clock=None, net_faults=None, create=False):
    kw = dict(
        annex_threshold=1 << 10, chunk_threshold=CHUNK_THRESHOLD,
        chunk_params=CHUNK_PARAMS,
    ) if create else {}  # an existing repo's stored config wins
    return repro.open(
        root, create=create, clock=clock, net_faults=net_faults, **kw
    )


def _write_objs(proj, blobs):
    for i, blob in enumerate(blobs):
        with open(os.path.join(proj, f"obj{i:03d}.dat"), "wb") as f:
            f.write(blob)


def _row(case, n_objs, rep, sim_s, wall_s, **extra):
    return {
        "bench": "remote", "case": case, "n_objs": n_objs,
        "bytes_moved": rep.get("bytes_sent", rep.get("bytes_received", 0)),
        "chunks_moved": rep.get("chunks_sent", rep.get("chunks_fetched", 0)),
        "retries": rep.get("retries", 0),
        "failovers": rep.get("failovers", 0),
        "sim_s": sim_s, "wall_s": wall_s,
        **extra,
    }


def _push_campaign(n_objs: int) -> list[dict]:
    root = tempfile.mkdtemp(prefix="bench_remote_push_")
    proj = os.path.join(root, "proj")
    os.makedirs(proj)
    clock = SimClock()
    try:
        rng = np.random.default_rng(11)
        blobs = [
            bytearray(rng.integers(0, 256, OBJ_BYTES, dtype=np.uint8)
                      .tobytes())
            for _ in range(n_objs)
        ]
        s = _open(proj, clock=clock, create=True)
        _write_objs(proj, blobs)
        s.save(message="v1")
        s.add_remote(os.path.join(root, "siteA"), name="siteA", net="wan")

        s0 = clock.snapshot()
        with timer() as t:
            cold = s.push()[0]
        rows = [_row("push_cold", n_objs, cold, clock.snapshot() - s0,
                     t["s"], total_bytes=n_objs * OBJ_BYTES)]

        # ~3% contiguous churn per object, the checkpoint-campaign shape
        for blob in blobs:
            n = max(1, int(len(blob) * CHURN))
            off = int(rng.integers(0, len(blob) - n + 1))
            blob[off:off + n] = rng.integers(0, 256, n, dtype=np.uint8) \
                .tobytes()
        _write_objs(proj, blobs)
        s.save(message="v2")
        s0 = clock.snapshot()
        with timer() as t:
            inc = s.push()[0]
        rows.append(_row("push_incremental", n_objs, inc,
                         clock.snapshot() - s0, t["s"], churn=CHURN,
                         cold_bytes=cold["bytes_sent"]))
        s.close()
        return rows
    finally:
        cleanup(root)


def _drain_local(s):
    """Empty the local annex: drop every HEAD path (replica-verified), then
    sweep the orphaned chunks — the cold-restore starting state."""
    paths = sorted(
        p for p, e in s.repo.tree_of(s.head()).items()
        if e.get("t") == "annex"
    )
    for p in paths:
        s.drop(p)
    s.gc()


def _pull_campaign(n_objs: int) -> list[dict]:
    root = tempfile.mkdtemp(prefix="bench_remote_pull_")
    proj = os.path.join(root, "proj")
    os.makedirs(proj)
    clock = SimClock()
    try:
        rng = np.random.default_rng(13)
        blobs = [
            rng.integers(0, 256, OBJ_BYTES, dtype=np.uint8).tobytes()
            for _ in range(n_objs)
        ]
        s = _open(proj, clock=clock, create=True)
        _write_objs(proj, blobs)
        s.save(message="v1")
        s.add_remote(os.path.join(root, "siteA"), name="siteA", net="wan")
        s.push()
        _drain_local(s)
        s.close()

        # clean link baseline
        s = _open(proj, clock=clock)
        s0 = clock.snapshot()
        with timer() as t:
            clean = s.pull()
        rows = [_row("pull_cold", n_objs, clean, clock.snapshot() - s0,
                     t["s"], total_bytes=n_objs * OBJ_BYTES)]
        assert clean["keys_fetched"] == n_objs
        _drain_local(s)
        s.close()

        # degraded link: seeded transient errors + sub-timeout stalls on
        # every request direction, retried with seeded backoff
        model = NetworkFaultModel(
            seed=7,
            rules=[
                NetFaultRule(op="*", kind="error", p=0.05),
                NetFaultRule(op="recv", kind="stall", stall_s=0.2, p=0.05),
            ],
            max_retries=MAX_RETRIES,
        )
        s = _open(proj, clock=clock, net_faults=model)
        s0 = clock.snapshot()
        with timer() as t:
            deg = s.pull()
        # per-operation retry budget: every chunk transfer, manifest op and
        # presence batch retries at most MAX_RETRIES times
        ops = deg["chunks_fetched"] + 4 * n_objs
        rows.append(_row(
            "pull_degraded", n_objs, deg, clock.snapshot() - s0, t["s"],
            completed=deg["keys_fetched"] == n_objs,
            retry_budget=MAX_RETRIES * ops,
            clean_sim_s=rows[0]["sim_s"],
        ))
        s.close()
        return rows
    finally:
        cleanup(root)


def run(n_objs: int = N_OBJS) -> list[dict]:
    return _push_campaign(n_objs) + _pull_campaign(n_objs)


if __name__ == "__main__":
    for r in run():
        print(r)
