"""Paper Figures 9/10 (contribution C3): slurm-finish runtime vs repository
size; the parallel-FS blowup and the two ways out of it.

The paper's finding: per-job finish cost grows superlinearly once the
repository exceeds ~50 000 files ON A PARALLEL FS (>10 s/job), because the
commit path performs O(repo files) metadata ops against degraded
directories. The paper's fix is operational (--alt-dir: keep the repo on a
local FS); ours is also algorithmic (the incremental commit engine,
DESIGN.md §4: O(changed paths) ops per commit).

Cases:
  finish_pfs         GPFS, incremental engine (default)  -> ~flat
  finish_pfs_legacy  GPFS, full-rebuild engine + caches
                     disabled (seed behavior)            -> superlinear
  finish_altdir      local XFS + --alt-dir staging       -> ~flat

Each case sweeps the repository's accumulated file count by seeding a
synthetic base commit + the object-store shard entry counts the parallel-FS
model degrades with (see ``common.seed_repo_files``), then measures real
finish batches at each size.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.fsio import GPFS, LOCAL_XFS
from repro.core.spec import RunSpec

from .common import cleanup, make_env, seed_repo_files, timer, write_job_dir

SIZES = (1_000, 10_000, 50_000, 100_000, 200_000)


def run(jobs_per_size: int = 8, sizes=SIZES, n_extra: int = 4,
        legacy_jobs_per_size: int = 3, cases=None) -> list[dict]:
    rows = []
    all_cases = (
        ("finish_pfs", GPFS, False, "incremental"),
        ("finish_pfs_legacy", GPFS, False, "full"),
        ("finish_altdir", LOCAL_XFS, True, "incremental"),
    )
    for case, profile, alt, engine in all_cases:
        if cases is not None and case not in cases:
            continue
        n_jobs = legacy_jobs_per_size if engine == "full" else jobs_per_size
        for n_files in sizes:
            root, repo, cluster, sched, clock = make_env(profile)
            if engine == "full":
                repo.objects.disable_caches()  # seed-era behavior end-to-end
            alt_dir = os.path.join(root, "pfs_stage") if alt else None
            seed_repo_files(repo, n_files)
            specs = []
            for j in range(n_jobs):
                write_job_dir(repo, j, n_extra)
                specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                                     pwd=f"jobs/{j}", alt_dir=alt_dir))
            ids = sched.submit_many(specs)
            cluster.wait(timeout=600)
            sim_t, wall_t = [], []
            for job_id in ids:
                s0 = clock.snapshot()
                with timer() as t:
                    res = sched.finish(job_id=job_id, engine=engine)
                assert res and res[0].commit, res
                wall_t.append(t["s"])
                sim_t.append(clock.snapshot() - s0)
            cluster.shutdown()
            rows.append({
                "bench": "finish",
                "case": case,
                "engine": engine,
                "repo_files": n_files,
                "outputs_per_job": 4 + n_extra,
                "sim_s_per_job": float(np.mean(sim_t)),
                "wall_us_per_job": float(np.mean(wall_t) * 1e6),
            })
            cleanup(root)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
