"""Paper Figures 9/10 (contribution C3): slurm-finish runtime vs repository
size; the parallel-FS blowup and the --alt-dir fix.

The paper's finding: per-job finish cost grows superlinearly once the
repository exceeds ~50 000 files ON A PARALLEL FS (>10 s/job), while a
repository on a local FS (jobs staged via --alt-dir) stays ~flat
(0.6-1.7 s/job). We sweep the repository's accumulated file count by
pre-loading the FS model's file counter (the quantity GPFS metadata
latency degrades with), then measure real finish batches at each size.
"""
from __future__ import annotations

import numpy as np

from repro.core.fsio import GPFS, LOCAL_XFS

from .common import cleanup, make_env, timer, write_job_dir


def run(jobs_per_size: int = 8, sizes=(1_000, 10_000, 50_000, 100_000, 200_000),
        n_extra: int = 4) -> list[dict]:
    rows = []
    for case, profile, alt in (
        ("finish_pfs", GPFS, False),
        ("finish_altdir", LOCAL_XFS, True),
    ):
        for n_files in sizes:
            root, repo, cluster, sched, clock = make_env(profile)
            alt_dir = None
            if alt:
                import os
                alt_dir = os.path.join(root, "pfs_stage")
            repo.fs.n_files = n_files  # repository already holds n_files files
            ids = []
            for j in range(jobs_per_size):
                write_job_dir(repo, j, n_extra)
                ids.append(
                    sched.schedule("slurm.sh", outputs=[f"jobs/{j}"],
                                   pwd=f"jobs/{j}", alt_dir=alt_dir)
                )
            cluster.wait(timeout=600)
            sim_t, wall_t = [], []
            for job_id in ids:
                s0 = clock.snapshot()
                with timer() as t:
                    res = sched.finish(job_id=job_id)
                assert res and res[0].commit, res
                wall_t.append(t["s"])
                sim_t.append(clock.snapshot() - s0)
            cluster.shutdown()
            rows.append({
                "bench": "finish",
                "case": case,
                "repo_files": n_files,
                "outputs_per_job": 4 + n_extra,
                "sim_s_per_job": float(np.mean(sim_t)),
                "wall_us_per_job": float(np.mean(wall_t) * 1e6),
            })
            cleanup(root)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
