"""Paper Figures 9/10 (contribution C3): slurm-finish runtime vs repository
size; the parallel-FS blowup and the three ways out of it.

The paper's finding: per-job finish cost grows superlinearly once the
repository exceeds ~50 000 files ON A PARALLEL FS (>10 s/job), because the
commit path performs O(repo files) metadata ops against degraded
directories. The paper's fix is operational (--alt-dir: keep the repo on a
local FS); ours is algorithmic twice over — the incremental commit engine
(DESIGN.md §4: O(changed paths) ops per commit) and the pack layer
(DESIGN.md §8: bound the per-op *cost* by keeping shard entry counts below
the degradation threshold).

Cases:
  finish_pfs         GPFS, incremental engine (default)  -> ~flat, residual
                     slope from ever-growing loose shards (0.48 -> 0.51)
  finish_pfs_legacy  GPFS, full-rebuild engine + caches
                     disabled (seed behavior)            -> superlinear
  finish_altdir      local XFS + --alt-dir staging       -> ~flat
  finish_packed      GPFS, incremental engine after repack()
                     (+ threshold auto-repack armed)     -> flat, slope ~0

``finish_packed`` is the long-horizon "repository aging" case: it sweeps
beyond the paper's 200k ceiling (AGING_SIZES adds 500k) and reports the
one-time amortized ``repack_sim_s`` alongside the steady-state per-job cost.
Its rows are tagged ``bench="finish_pack"`` and land in ``BENCH_pack.json``
(see benchmarks/run.py ``--check-pack``), keeping ``BENCH_finish.json``'s
tracked trajectory untouched.

Each case sweeps the repository's accumulated file count by seeding a
synthetic base commit + the object-store shard entry counts the parallel-FS
model degrades with (see ``common.seed_repo_files``), then measures real
finish batches at each size.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.fsio import GPFS, LOCAL_XFS
from repro.core.spec import RunSpec

from .common import cleanup, make_env, seed_repo_files, timer, write_job_dir

SIZES = (1_000, 10_000, 50_000, 100_000, 200_000)
AGING_SIZES = SIZES + (500_000,)  # the pack case holds flat past the paper


def run(jobs_per_size: int = 8, sizes=SIZES, n_extra: int = 4,
        legacy_jobs_per_size: int = 3, cases=None, aging_sizes=None
        ) -> list[dict]:
    if aging_sizes is None:
        # the packed case sweeps whatever was requested, plus the beyond-
        # paper aging point when running the full default sweep
        aging_sizes = AGING_SIZES if sizes == SIZES else sizes
    rows = []
    all_cases = (
        ("finish_pfs", GPFS, False, "incremental", False),
        ("finish_pfs_legacy", GPFS, False, "full", False),
        ("finish_altdir", LOCAL_XFS, True, "incremental", False),
        ("finish_packed", GPFS, False, "incremental", True),
    )
    for case, profile, alt, engine, packed in all_cases:
        if cases is not None and case not in cases:
            continue
        n_jobs = legacy_jobs_per_size if engine == "full" else jobs_per_size
        for n_files in (aging_sizes if packed else sizes):
            # packed case: threshold auto-repack armed (steady state); the
            # aging cases keep it off so their pressure stays observable
            root, repo, cluster, sched, clock = make_env(
                profile,
                auto_repack_threshold=profile.degrade_threshold if packed else None,
            )
            if engine == "full":
                repo.objects.disable_caches()  # seed-era behavior end-to-end
            alt_dir = os.path.join(root, "pfs_stage") if alt else None
            seed_repo_files(repo, n_files)
            repack_sim_s = 0.0
            if packed:
                # one amortized compaction of the accumulated footprint,
                # charged on the sim clock and reported; the measured jobs
                # then run with threshold auto-repack armed (steady state)
                r0 = clock.snapshot()
                repo.objects.repack()
                repack_sim_s = clock.snapshot() - r0
            specs = []
            for j in range(n_jobs):
                write_job_dir(repo, j, n_extra)
                specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                                     pwd=f"jobs/{j}", alt_dir=alt_dir))
            ids = sched.submit_many(specs)
            cluster.wait(timeout=600)
            sim_t, wall_t = [], []
            for job_id in ids:
                s0 = clock.snapshot()
                with timer() as t:
                    res = sched.finish(job_id=job_id, engine=engine)
                assert res and res[0].commit, res
                wall_t.append(t["s"])
                sim_t.append(clock.snapshot() - s0)
            cluster.shutdown()
            row = {
                "bench": "finish_pack" if packed else "finish",
                "case": case,
                "engine": engine,
                "repo_files": n_files,
                "outputs_per_job": 4 + n_extra,
                "sim_s_per_job": float(np.mean(sim_t)),
                "wall_us_per_job": float(np.mean(wall_t) * 1e6),
            }
            if packed:
                row["repack_sim_s"] = repack_sim_s
            rows.append(row)
            cleanup(root)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
