"""Run-cache benchmark (DESIGN.md §11): what does memoization buy?

The functional model says a re-submitted spec whose content-addressed
inputs are unchanged need not run at all. This benchmark measures exactly
that claim at campaign scale:

  sweep_cold   1000 novel specs: submit_many -> wait -> finish. Every job
               goes through sbatch and the full finish data plane; the
               finish path populates the run-cache index as a side effect.
  sweep_warm   1000 specs at 90% overlap: 900 bit-identical re-submissions
               of cold-sweep specs plus 100 novel ones. The 900 hits
               short-circuit at submit_many into memoized provenance
               commits (zero sbatch calls, zero data-plane work); only the
               100 novel specs reach Slurm and pay the cold path.

The gate (benchmarks/run.py ``--check-cache``) holds three claims:
  (a) the warm sweep costs <= 0.15x the cold sweep on the sim clock,
  (b) cached specs submit nothing to Slurm (warm slurm submissions ==
      the novel count, and every hit row closes as 'memoized' with a
      NULL slurm id), and
  (c) a memoized provenance record reconstructs to the exact original
      spec: ``spec_of(memoized commit).spec_id == original.spec_id``.

Rows are tagged ``bench="cache"`` and land in ``BENCH_cache.json``.
"""
from __future__ import annotations

import os
import tempfile

from repro.core import records as R
from repro.core.fsio import GPFS, SimClock
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.slurm import LocalSlurmCluster
from repro.core.spec import RunSpec

from .common import cleanup, timer

N_JOBS = 1000
OVERLAP = 0.9

# minimal payload: the bench measures the version-store control plane, not
# bash startup, and LocalSlurmCluster really execs every script
_SCRIPT = "#!/bin/bash\necho payload > out.txt\n"


def _make_env():
    root = tempfile.mkdtemp(prefix="bench_cache_")
    clock = SimClock()
    repo = Repository.init(
        os.path.join(root, "repo"), profile=GPFS, clock=clock,
        annex_threshold=256,
    )
    cluster = LocalSlurmCluster(
        max_workers=8, clock=clock, sbatch_cost_s=0.05, sacct_cost_s=0.02
    )
    sched = SlurmScheduler(repo, cluster)
    return root, repo, cluster, sched, clock


def _spec_for(repo, j: int) -> RunSpec:
    d = os.path.join(repo.root, "jobs", str(j))
    if not os.path.isdir(d):
        os.makedirs(d)
        with open(os.path.join(d, "slurm.sh"), "w") as f:
            f.write(_SCRIPT)
    return RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"], pwd=f"jobs/{j}")


def _sweep(repo, cluster, sched, specs) -> tuple[list[int], float, float]:
    clock = repo.fs.clock
    s0 = clock.snapshot()
    with timer() as t:
        ids = sched.submit_many(specs)
        open_rows = [
            r for jid in ids
            if (r := sched.db.get(jid)) and r["status"] == "scheduled"
        ]
        if open_rows:
            cluster.wait([r["slurm_id"] for r in open_rows], timeout=600)
            sched.finish()
    return ids, clock.snapshot() - s0, t["s"]


def run(n_jobs: int = N_JOBS, overlap: float = OVERLAP) -> list[dict]:
    root, repo, cluster, sched, clock = _make_env()
    try:
        n_overlap = int(n_jobs * overlap)

        cold_specs = [_spec_for(repo, j) for j in range(n_jobs)]
        cold_ids, cold_sim, cold_wall = _sweep(repo, cluster, sched, cold_specs)
        assert sched.db.cache_count() >= n_jobs, "cold sweep must fill the cache"

        # 90% bit-identical re-submissions + 10% novel — fresh RunSpec
        # objects, so the hit comes from content addressing, not object
        # identity
        warm_specs = [_spec_for(repo, j) for j in range(n_overlap)]
        warm_specs += [_spec_for(repo, n_jobs + j) for j in range(n_jobs - n_overlap)]
        warm_ids, warm_sim, warm_wall = _sweep(repo, cluster, sched, warm_specs)

        rows_db = [sched.db.get(j) for j in warm_ids]
        n_memo = sum(1 for r in rows_db if r["status"] == "memoized")
        n_slurm = sum(1 for r in rows_db if r["slurm_id"] is not None)
        assert all(
            r["slurm_id"] is None for r in rows_db if r["status"] == "memoized"
        ), "memoized rows must never have touched Slurm"

        # claim (c): the memoized provenance record reconstructs the exact
        # original spec — walk the head chain over the memoized commits
        spec_ok = n_memo > 0
        cold_by_id = {s.spec_id: s for s in cold_specs}
        oid, checked = repo.head_commit(), 0
        while oid and checked < n_memo:
            commit = repo.objects.get_commit(oid)
            rec = R.RunRecord.from_message(commit["message"])
            if rec is not None and rec.memoized:
                spec = R.spec_of(repo, oid)
                spec_ok &= spec.spec_id in cold_by_id
                checked += 1
            parents = commit.get("parents") or []
            oid = parents[0] if parents else None
        spec_ok &= checked == n_memo

        base = {
            "bench": "cache", "n_jobs": n_jobs, "repo_files": 0,
            "overlap": overlap,
        }
        return [
            {
                **base, "case": "sweep_cold", "n_hits": 0, "n_novel": n_jobs,
                "slurm_submissions": n_jobs, "spec_roundtrip_ok": True,
                "sim_s_total": cold_sim, "sim_s_per_job": cold_sim / n_jobs,
                "wall_s_total": cold_wall,
            },
            {
                **base, "case": "sweep_warm", "n_hits": n_memo,
                "n_novel": n_jobs - n_overlap, "slurm_submissions": n_slurm,
                "spec_roundtrip_ok": bool(spec_ok),
                "sim_s_total": warm_sim, "sim_s_per_job": warm_sim / n_jobs,
                "wall_s_total": warm_wall,
            },
        ]
    finally:
        cluster.shutdown()
        cleanup(root)


if __name__ == "__main__":
    for r in run():
        print(r)
