"""Robustness cost benchmark (DESIGN.md §10): what does crash-safety charge?

Three questions, answered on the calibrated sim clock:

  journal overhead   ``finish`` with the intent journal on vs off for the
                     same batch — the exactly-once guarantee costs one
                     fsynced header write + one JSONL append per job + one
                     unlink per batch, and must stay within 1.15x.
  recovery cost      kill the client at ``finish:after-publish`` halfway
                     through a batch, then ``recover()`` a fresh incarnation
                     over the same repository. Recovery (journal replay +
                     re-finish of the unpublished half) must cost less than
                     re-running the whole batch from scratch — and must end
                     at zero divergence.
  verify cost        one full fsck sweep of the recovered repository,
                     reported for the trajectory (no gate).

Rows are tagged ``bench="faults"`` and land in ``BENCH_faults.json``
(benchmarks/run.py ``--check-faults``).
"""
from __future__ import annotations

import os

from repro.core.faults import CrashInjected, FaultPlan
from repro.core.fsio import FS, GPFS, SimClock
from repro.core.repo import Repository
from repro.core.scheduler import SlurmScheduler
from repro.core.session import Session
from repro.core.slurm import LocalSlurmCluster
from repro.core.spec import RunSpec

from .common import cleanup, seed_repo_files, timer, write_job_dir

N_JOBS = 16
REPO_FILES = 10_000


def _make_env(faults=None):
    import tempfile

    root = tempfile.mkdtemp(prefix="bench_faults_")
    clock = SimClock()
    repo = Repository.init(
        os.path.join(root, "repo"), profile=GPFS, clock=clock,
        annex_threshold=256, faults=faults,
    )
    cluster = LocalSlurmCluster(
        max_workers=8, clock=clock, sbatch_cost_s=0.05, sacct_cost_s=0.02,
        faults=faults,
    )
    sched = SlurmScheduler(repo, cluster)
    return root, repo, cluster, sched, clock


def _submit_batch(repo, cluster, sched, n_jobs):
    specs = []
    for j in range(n_jobs):
        write_job_dir(repo, j)
        specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                             pwd=f"jobs/{j}"))
    ids = sched.submit_many(specs)
    cluster.wait(timeout=600)
    return ids


def _finish_cost(journal: bool, n_jobs: int, repo_files: int) -> dict:
    root, repo, cluster, sched, clock = _make_env()
    seed_repo_files(repo, repo_files)
    _submit_batch(repo, cluster, sched, n_jobs)
    s0 = clock.snapshot()
    with timer() as t:
        res = sched.finish(journal=journal)
    assert len(res) == n_jobs and all(r.commit for r in res), res
    sim_total = clock.snapshot() - s0
    cluster.shutdown()
    cleanup(root)
    return {
        "bench": "faults",
        "case": "finish_journal" if journal else "finish_nojournal",
        "n_jobs": n_jobs,
        "repo_files": repo_files,
        "sim_s_total": sim_total,
        "sim_s_per_job": sim_total / n_jobs,
        "wall_s_total": t["s"],
    }


def _recovery_cost(n_jobs: int, repo_files: int) -> list[dict]:
    # kill the client after publishing the (n/2)-th job of the batch
    plan = FaultPlan(seed=0, crash_at={"finish:after-publish": n_jobs // 2})
    root, repo, cluster, sched, clock = _make_env(faults=plan)
    seed_repo_files(repo, repo_files)
    job_ids = _submit_batch(repo, cluster, sched, n_jobs)
    try:
        sched.finish()
        raise AssertionError("crash point never fired")
    except CrashInjected:
        pass
    # reboot: fresh FS over the same repository, same (uncrashed) cluster,
    # same sim clock so recovery charges land on the same trajectory
    cluster.faults = None
    session = Session(
        Repository(repo.root, fs=FS(GPFS, clock)), cluster=cluster
    )
    s0 = clock.snapshot()
    with timer() as t_rec:
        report = session.recover()
    sim_recover = clock.snapshot() - s0
    s0 = clock.snapshot()
    with timer() as t_ver:
        check = session.verify()
    sim_verify = clock.snapshot() - s0
    assert check["divergence"] == 0, check["issues"]
    db = session.scheduler.db
    assert all(db.get(j)["status"] == "finished" for j in job_ids)
    cluster.shutdown()
    cleanup(root)
    return [
        {
            "bench": "faults", "case": "recover_midbatch",
            "n_jobs": n_jobs, "repo_files": repo_files,
            "recovered_jobs": report["commits_republished"]
            + report["jobs_refinished"],
            "sim_s_total": sim_recover,
            "sim_s_per_job": sim_recover / n_jobs,
            "wall_s_total": t_rec["s"],
        },
        {
            "bench": "faults", "case": "verify_full",
            "n_jobs": n_jobs, "repo_files": repo_files,
            "checked_commits": check["checked_commits"],
            "sim_s_total": sim_verify,
            "sim_s_per_job": sim_verify / n_jobs,
            "wall_s_total": t_ver["s"],
        },
    ]


def run(n_jobs: int = N_JOBS, repo_files: int = REPO_FILES) -> list[dict]:
    rows = [
        _finish_cost(False, n_jobs, repo_files),
        _finish_cost(True, n_jobs, repo_files),
    ]
    rows += _recovery_cost(n_jobs, repo_files)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
