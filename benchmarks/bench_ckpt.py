"""Checkpoint-campaign benchmark (DESIGN.md §12): what does chunking buy?

A training campaign checkpoints near-identical state every step: optimizer
moments drift, a few percent of each tensor changes, most bytes are the
bytes of the previous step. Whole-object content addressing dedups only
*identical* leaves — one flipped element re-ingests the whole tensor. The
chunk tier cuts each leaf at content-defined boundaries, so a step ingests
only the chunks the churn actually touched.

  ckpt_whole    20-step campaign, chunking off: every save hashes and
                writes each full leaf (dedup can only discard after the
                bytes moved), so per-step ingest == state size.
  ckpt_chunked  same campaign (same seed, same churn), chunk tier on:
                after the first step, only changed chunks + the manifests
                move.

Each save is a commit through ``CheckpointManager`` (streamed npy leaves,
pointer-v2 worktree, RunSpec-recorded). Afterwards every step is restored
in a fresh clone (annexed content stays behind, so every byte is fetched +
reassembled) and verified bit-identical against the in-memory state the
campaign had at that step — bf16 included. The first restore is cold (full
fetch); subsequent steps hit the clone's now-warm chunk store, so the
fetch side shows the same delta behaviour as ingest. A cold restore of the
final step is also timed serial vs. ``FETCH_WORKERS`` threads (reported as
trajectory data; on the metadata-dominated striped profile the sim clock
charges per-chunk metadata serially either way).

The gate (benchmarks/run.py ``--check-ckpt``) holds three claims:
  (a) chunked steady-state ingest <= 0.15x the unchunked per-step ingest
      at ~3% churn,
  (b) every step of the campaign restores bit-identical (incl. bf16),
  (c) a warm delta-restore (previous step already local) moves <= 0.2x
      the bytes of the cold restore.

Rows are tagged ``bench="ckpt"`` and land in ``BENCH_ckpt.json``.
"""
from __future__ import annotations

import hashlib
import os
import tempfile

import ml_dtypes
import numpy as np

from repro.core.chunks import ChunkParams
from repro.core.fsio import FS, GPFS_STRIPED, SimClock
from repro.core.repo import Repository
from repro.train.checkpoint import CheckpointManager, _flatten

from .common import cleanup, timer

N_STEPS = 20
CHURN = 0.03
FETCH_WORKERS = 8

# chunk geometry tuned to the leaf sizes below: ~16 KiB average chunks keep
# the changed-chunk footprint of a 3% contiguous churn region small relative
# to a ~1.5 MiB leaf
CHUNK_THRESHOLD = 256 << 10
CHUNK_PARAMS = ChunkParams(min_size=8 << 10, avg_bits=14, max_size=64 << 10)


def _make_state(rng) -> dict:
    """Sharded params + Adam moments: two f32 layer shards, one bf16 embed
    shard, one frozen shard, and m/v moments per layer — ~11 MiB total."""
    f32 = lambda shape: rng.standard_normal(shape, dtype=np.float32)
    params = {
        "layer0": f32((384, 1024)),
        "layer1": f32((384, 1024)),
        "embed": f32((768, 1024)).astype(ml_dtypes.bfloat16),
        "frozen": f32((256, 1024)),
    }
    opt_state = {
        "m": {"layer0": f32((384, 1024)), "layer1": f32((384, 1024))},
        "v": {"layer0": f32((384, 1024)), "layer1": f32((384, 1024))},
        "step": np.int32(0),
    }
    return {"params": params, "opt_state": opt_state}


def _churn(state: dict, rng, frac: float = CHURN) -> None:
    """Overwrite a random contiguous ~frac slice of every mutable leaf —
    the per-step drift of a training run ('frozen' never changes)."""
    for path, leaf in _flatten(state).items():
        if "frozen" in path:
            continue
        if not isinstance(leaf, np.ndarray) or leaf.ndim == 0:
            continue
        flat = leaf.reshape(-1)
        n = max(1, int(flat.size * frac))
        off = int(rng.integers(0, flat.size - n + 1))
        fresh = rng.standard_normal(n, dtype=np.float32)
        flat[off:off + n] = fresh.astype(leaf.dtype)
    state["opt_state"]["step"] = np.int32(
        int(state["opt_state"]["step"]) + 1
    )


def _digest(state: dict) -> dict:
    """Per-leaf (dtype, shape, sha256-of-bytes) — the bit-identity oracle."""
    out = {}
    for path, leaf in _flatten(state).items():
        arr = np.asarray(leaf)
        out[path] = (
            str(arr.dtype), arr.shape,
            hashlib.sha256(arr.tobytes()).hexdigest(),
        )
    return out


def _measure_restore(repo: Repository, root: str, tag: str, workers: int):
    """Cold-restore the latest checkpoint in a fresh clone on its own
    clock; returns (sim seconds, wall seconds, restored digest)."""
    clock = SimClock()
    clone = Repository.clone(
        repo, os.path.join(root, f"clone_{tag}"),
        fs=FS(GPFS_STRIPED, clock),
    )
    ckpt = CheckpointManager(clone, fetch_workers=workers)
    s0 = clock.snapshot()
    with timer() as t:
        state, _ = ckpt.restore()
    return clock.snapshot() - s0, t["s"], _digest(state)


def _verify_all_steps(repo: Repository, root: str, digests: dict):
    """Restore every step of the campaign in ONE clone (newest first, so
    the first restore is cold and the rest hit the warm local store) and
    check bit-identity against the saved digests. Returns
    (all_ok, cold_restore_bytes, delta_restore_bytes)."""
    clock = SimClock()
    clone = Repository.clone(
        repo, os.path.join(root, "clone_verify"), fs=FS(GPFS_STRIPED, clock),
    )
    ckpt = CheckpointManager(clone, fetch_workers=FETCH_WORKERS)
    by_step = {step: oid for oid, step in ckpt.checkpoints()}
    all_ok = len(by_step) == len(digests)
    cold_bytes = delta_bytes = None
    for step in sorted(by_step, reverse=True):
        b0 = clock.bytes_written
        state, _ = ckpt.restore(by_step[step])
        moved = clock.bytes_written - b0
        if cold_bytes is None:
            cold_bytes = moved
        elif delta_bytes is None:
            delta_bytes = moved
        all_ok &= _digest(state) == digests[step]
    return all_ok, cold_bytes or 0, delta_bytes or 0


def _campaign(case: str, chunked: bool, n_steps: int = N_STEPS) -> dict:
    root = tempfile.mkdtemp(prefix=f"bench_ckpt_{case}_")
    clock = SimClock()
    kwargs = (
        dict(chunk_threshold=CHUNK_THRESHOLD, chunk_params=CHUNK_PARAMS)
        if chunked else {}
    )
    repo = Repository.init(
        os.path.join(root, "repo"), profile=GPFS_STRIPED, clock=clock,
        annex_threshold=64 << 10, **kwargs,
    )
    try:
        rng = np.random.default_rng(7)
        state = _make_state(rng)
        ckpt = CheckpointManager(repo)
        state_bytes = sum(
            np.asarray(v).nbytes for v in _flatten(state).values()
        )

        digests = {}
        with timer() as t:
            ckpt.save(1, state["params"], state["opt_state"], data_step=1)
            digests[1] = _digest(state)
            full_bytes = clock.bytes_written
            full_sim = clock.snapshot()
            for step in range(2, n_steps + 1):
                _churn(state, rng)
                ckpt.save(step, state["params"], state["opt_state"],
                          data_step=step)
                digests[step] = _digest(state)
        steady_bytes = (clock.bytes_written - full_bytes) / (n_steps - 1)
        steady_sim = (clock.snapshot() - full_sim) / (n_steps - 1)

        all_ok, cold_bytes, delta_bytes = _verify_all_steps(
            repo, root, digests
        )
        ser_sim, ser_wall, d_ser = _measure_restore(repo, root, "serial", 1)
        par_sim, par_wall, d_par = _measure_restore(
            repo, root, "parallel", FETCH_WORKERS
        )
        all_ok &= d_ser == digests[n_steps] and d_par == digests[n_steps]
        return {
            "bench": "ckpt", "case": case, "repo_files": 0,
            "n_steps": n_steps, "churn": CHURN,
            "state_bytes": state_bytes,
            "full_ingest_bytes": full_bytes,
            "steady_bytes_per_step": steady_bytes,
            "full_ingest_sim_s": full_sim,
            "steady_sim_s_per_step": steady_sim,
            "cold_restore_bytes": cold_bytes,
            "delta_restore_bytes": delta_bytes,
            "restore_serial_sim_s": ser_sim,
            "restore_parallel_sim_s": par_sim,
            "restore_serial_wall_s": ser_wall,
            "restore_parallel_wall_s": par_wall,
            "fetch_workers": FETCH_WORKERS,
            "restore_bitwise_ok": all_ok,
            "wall_s_total": t["s"],
        }
    finally:
        cleanup(root)


def run(n_steps: int = N_STEPS) -> list[dict]:
    return [
        _campaign("ckpt_whole", chunked=False, n_steps=n_steps),
        _campaign("ckpt_chunked", chunked=True, n_steps=n_steps),
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
