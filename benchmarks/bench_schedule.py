"""Paper Figures 7/8 (contribution C2): schedule overhead vs pure sbatch,
plus the spec-layer batching case (per-job ``submit`` vs ``submit_many``).

Cases, exactly as in the paper's experiment setup (§6 + artifact A1):
  (1) schedule, repo on the parallel FS (GPFS profile)
  (2) schedule with --alt-dir, repo on local XFS, jobs staged to parallel FS
  (3) pure sbatch baseline
x {4, 8, 12} outputs per job (base 4 = result + bz2 + slurm log + env json).

Expected reproduction: (1)/(2) carry a roughly CONSTANT ~0.35-0.7 s/job
offset over (3)'s ~0.05 s, independent of the number of already-scheduled
jobs; more outputs => slightly slower.

The batching benchmark (``run_batched``) submits the same N jobs once
through N individual ``submit`` calls (N CLI-startup charges, N jobdb
transactions) and once through a single ``submit_many`` (one charge, one
transaction, one shared conflict pass); the gate in ``benchmarks/run.py
--check-schedule`` asserts the batched path costs < 0.5x the per-job sum
on the sim clock.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.fsio import GPFS, LOCAL_XFS
from repro.core.spec import RunSpec

from .common import cleanup, make_env, timer, write_job_dir


def run(n_jobs: int = 120, extra_outputs: tuple = (0, 4, 8)) -> list[dict]:
    rows = []
    for n_extra in extra_outputs:
        n_outputs = 4 + n_extra
        for case, profile, alt in (
            ("schedule_pfs", GPFS, False),
            ("schedule_altdir", LOCAL_XFS, True),
            ("pure_sbatch", GPFS, False),
        ):
            root, repo, cluster, sched, clock = make_env(profile)
            alt_dir = None
            if alt:
                alt_dir = os.path.join(root, "pfs_stage")
            sim_t, wall_t = [], []
            for j in range(n_jobs):
                write_job_dir(repo, j, n_extra)
                s0 = clock.snapshot()
                with timer() as t:
                    if case == "pure_sbatch":
                        cluster.sbatch("slurm.sh", workdir=f"{repo.root}/jobs/{j}")
                    else:
                        sched.submit(RunSpec(
                            script="slurm.sh",
                            outputs=[f"jobs/{j}"],
                            pwd=f"jobs/{j}",
                            alt_dir=alt_dir,
                        ))
                wall_t.append(t["s"])
                sim_t.append(clock.snapshot() - s0)
            cluster.wait(timeout=600)
            cluster.shutdown()
            rows.append({
                "bench": "schedule",
                "case": case,
                "outputs_per_job": n_outputs,
                "n_jobs": n_jobs,
                "sim_s_per_job": float(np.mean(sim_t)),
                "sim_s_p95": float(np.percentile(sim_t, 95)),
                "wall_us_per_job": float(np.mean(wall_t) * 1e6),
                # paper's key claim: offset constant in #scheduled jobs
                "sim_s_first_quartile": float(np.mean(sim_t[: n_jobs // 4])),
                "sim_s_last_quartile": float(np.mean(sim_t[-n_jobs // 4:])),
            })
            cleanup(root)
    return rows


def run_batched(n_jobs: int = 64) -> list[dict]:
    """Per-job ``submit`` vs one ``submit_many`` for the same N jobs (GPFS
    profile, paper-calibrated CLI-startup charge). Emitted into
    BENCH_schedule.json and gated by ``--check-schedule``."""
    rows = []
    for case in ("submit_per_job", "submit_many"):
        root, repo, cluster, sched, clock = make_env(GPFS)
        specs = []
        for j in range(n_jobs):
            write_job_dir(repo, j, 0)
            specs.append(RunSpec(script="slurm.sh", outputs=[f"jobs/{j}"],
                                 pwd=f"jobs/{j}"))
        s0 = clock.snapshot()
        with timer() as t:
            if case == "submit_many":
                sched.submit_many(specs)
            else:
                for spec in specs:
                    sched.submit(spec)
        sim_total = clock.snapshot() - s0
        cluster.wait(timeout=600)
        cluster.shutdown()
        rows.append({
            "bench": "schedule_batch",
            "case": case,
            "n_jobs": n_jobs,
            "sim_s_total": float(sim_total),
            "sim_s_per_job": float(sim_total / n_jobs),
            "wall_us_per_job": float(t["s"] * 1e6 / n_jobs),
        })
        cleanup(root)
    return rows


if __name__ == "__main__":
    for r in run() + run_batched():
        print(r)
