#!/usr/bin/env bash
# CI entry point: tier-1 tests, then every benchmark gate, with a per-step
# pass/fail summary and a nonzero exit if anything failed.
#
#   scripts/verify.sh            # everything
#   scripts/verify.sh tests      # tier-1 pytest only
#   scripts/verify.sh gates      # benchmark gates only
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-all}"
STEPS=()
RESULTS=()

run_step() {
    local name="$1"; shift
    echo "==> ${name}: $*" >&2
    "$@"
    local rc=$?
    STEPS+=("$name")
    RESULTS+=("$rc")
    return 0
}

if [ "$MODE" = "all" ] || [ "$MODE" = "tests" ]; then
    run_step "tier1-pytest" python -m pytest -x -q
fi

if [ "$MODE" = "all" ] || [ "$MODE" = "gates" ]; then
    for gate in finish schedule pack ingest faults cache ckpt remote dag; do
        run_step "gate-${gate}" python -m benchmarks.run "--check-${gate}"
    done
fi

echo ""
echo "== verify summary =="
printf '%-16s %s\n' "step" "result"
FAILED=0
for i in "${!STEPS[@]}"; do
    if [ "${RESULTS[$i]}" -eq 0 ]; then
        printf '%-16s PASS\n' "${STEPS[$i]}"
    else
        printf '%-16s FAIL (rc=%s)\n' "${STEPS[$i]}" "${RESULTS[$i]}"
        FAILED=1
    fi
done
exit "$FAILED"
