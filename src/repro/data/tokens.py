"""Deterministic, shardable, checkpointable token pipelines.

Determinism is what makes training jobs *machine-actionably reproducible*
(the paper's core property): a batch is a pure function of
``(seed, step, shard)`` via counter-based Philox, so the pipeline "state" is
just the integer step — trivially checkpointable, resumable, and elastic
(re-sharding on resume changes ``shard_count`` without changing the global
batch content, because shards slice a canonical global batch).

``RepoTokenDataset`` reads token shards committed as annexed ``.npy`` files
in a version-store repository — the paper's §7 scenario where "the current
subset of the data collection can be identified by a git commit hash": the
dataset is constructed *at a commit*, and its record (file list + hashes) is
what training jobs put in their reproducibility records.
"""
from __future__ import annotations

import io
import os
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def global_batch_at(self, step: int) -> np.ndarray:
        """The canonical global batch for ``step``: [global_batch, seq_len]."""
        bit = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, 0, step])
        )
        return bit.integers(
            0, self.vocab_size, size=(self.global_batch, self.seq_len), dtype=np.int32
        )

    def shard_batch_at(self, step: int, shard: int, shard_count: int) -> np.ndarray:
        g = self.global_batch_at(step)
        assert self.global_batch % shard_count == 0
        per = self.global_batch // shard_count
        return g[shard * per : (shard + 1) * per]


class RepoTokenDataset:
    """Token shards stored as annexed .npy files in a Repository, pinned to a
    commit. Iteration order is deterministic given (commit, seed)."""

    def __init__(self, repo, commit: str, prefix: str = "data/tokens",
                 seq_len: int = 256, global_batch: int = 8, seed: int = 0):
        self.repo = repo
        self.commit = repo.resolve(commit)
        self.prefix = prefix.rstrip("/")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        tree = repo.tree_of(self.commit)
        self.files = sorted(
            p for p in tree if p.startswith(self.prefix + "/") and p.endswith(".npy")
        )
        if not self.files:
            raise FileNotFoundError(f"no token shards under {prefix} at {commit[:12]}")
        self._tokens = None

    @property
    def manifest(self) -> dict:
        """What goes into the reproducibility record: the exact inputs."""
        return {"data_commit": self.commit, "files": self.files}

    def _load(self) -> np.ndarray:
        if self._tokens is None:
            parts = []
            for f in self.files:
                self.repo.annex_get(f)
                with open(os.path.join(self.repo.root, f), "rb") as fh:
                    parts.append(np.load(io.BytesIO(fh.read())).ravel())
            self._tokens = np.concatenate(parts).astype(np.int32)
        return self._tokens

    def global_batch_at(self, step: int) -> np.ndarray:
        toks = self._load()
        n_seq = len(toks) // self.seq_len
        usable = toks[: n_seq * self.seq_len].reshape(n_seq, self.seq_len)
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, 0, step])
        )
        idx = rng.integers(0, n_seq, size=self.global_batch)
        return usable[idx]

    def shard_batch_at(self, step: int, shard: int, shard_count: int) -> np.ndarray:
        g = self.global_batch_at(step)
        per = self.global_batch // shard_count
        return g[shard * per : (shard + 1) * per]
