from .tokens import RepoTokenDataset, SyntheticTokens

__all__ = ["RepoTokenDataset", "SyntheticTokens"]
