from .sharding import ShardingRules, make_rules, constrain

__all__ = ["ShardingRules", "make_rules", "constrain"]
