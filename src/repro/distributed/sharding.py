"""Sharding rules for the production meshes.

The assignment fixes the meshes: ``(16,16) -> ("data","model")`` single-pod
and ``(2,16,16) -> ("pod","data","model")`` multi-pod. Data parallelism maps
to ``("pod","data")`` when a pod axis exists; tensor parallelism to
``"model"``. Rules are *logical*: model code asks for e.g. ``rules.residual``
and gets a PartitionSpec valid for whichever mesh is active. When no mesh is
active (single-device smoke tests) ``rules`` is None and all constraints are
no-ops.

Baseline layout (hillclimbed in EXPERIMENTS.md §Perf):
  - residual stream [B, S, D]: P(dp, "model", None) — Megatron-style sequence
    parallelism so per-layer saved activations are 1/|model| (toggle:
    ``seq_shard_residual``),
  - attention/FFN weights: fused head & ff dims over "model",
  - embedding/lm_head: d_model-local, vocab over "model" (loss uses one-hot
    contraction so vocab-sharded logits never gather),
  - MoE expert weights: experts over "data" (ZeRO-3-style gather at use),
    ff dim over "model",
  - decode KV caches: batch over dp, head_dim over "model" (cache update
    stays shard-local; the score all-reduce is what §Perf attacks),
  - optimizer moments: sharded exactly like their parameters.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh = field(repr=False)
    dp: tuple[str, ...] = ()  # data-parallel axes, e.g. ("pod", "data")
    tp: str | None = None  # tensor-parallel axis name
    seq_shard_residual: bool = True
    kv_shard: str = "head_dim"  # 'head_dim' | 'seq' — KV-cache tp placement
    expert_axis: str = "data"  # 'data' (ZeRO gather) | 'model' (EP all-to-all)
    fsdp: bool = False  # ZeRO-3: second weight dim over 'data' (gather at use)

    def _dp(self):
        if not self.dp:
            return None
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    # ---- activations -------------------------------------------------
    @property
    def batch(self) -> P:  # [B, S]
        return P(self._dp())

    @property
    def residual(self) -> P:  # [B, S, D]
        seq = self.tp if self.seq_shard_residual else None
        return P(self._dp(), seq, None)

    @property
    def heads(self) -> P:  # [B, S, H, Dh]
        return P(self._dp(), None, self.tp, None)

    # ---- decode-time state --------------------------------------------
    def kv_cache(self, batch_shardable: bool) -> P:
        """[B, S_cache, KV, Dh]: batch over dp when B >= |dp|; the tp axis
        goes on head_dim (local single-token writes; decode-friendly) or on
        the sequence dim (local full-prefill writes; avoids the per-layer
        cache replication GSPMD falls back to when resharding the projection
        output into a head_dim-sharded buffer — see EXPERIMENTS.md §Perf)."""
        dp = self._dp() if batch_shardable else None
        if self.kv_shard == "seq":
            return P(dp, self.tp, None, None)
        return P(dp, None, None, self.tp)

    def ssm_state(self, batch_shardable: bool) -> P:
        """Leading channel-ish dim over tp: [B, H, Dh, Dh] / [B, Di, St]."""
        return P(self._dp() if batch_shardable else None, self.tp)

    # ---- params ----------------------------------------------------------
    def _fsdp_axis(self):
        return "data" if (self.fsdp and "data" in self.dp) else None

    @property
    def w_in(self) -> P:  # [D, fused_out] : fused dim over tp (+ D over data)
        return P(self._fsdp_axis(), self.tp)

    @property
    def w_out(self) -> P:  # [fused_in, D]
        return P(self.tp, self._fsdp_axis())

    def _data_size(self) -> int:
        return self.mesh.shape.get("data", 1) if "data" in self.dp else 1

    def w_expert_in(self, n_experts: int) -> P:  # [E, D, F]
        """expert_axis='data': experts over 'data' (ZeRO-3-style gather at
        use) when the count divides, else d_model over 'data'.
        expert_axis='model': expert parallelism — experts over the tp axis,
        tokens move via all-to-all on the (much smaller) dispatch tensors
        instead of gathering expert weights (EXPERIMENTS.md §Perf)."""
        data = "data" if "data" in self.dp else None
        if self.expert_axis == "model" and self.tp:
            tp_size = self.mesh.shape.get(self.tp, 1)
            if n_experts % tp_size == 0:
                return P(self.tp, data, None)
        if n_experts % max(1, self._data_size()) == 0:
            return P(data, None, self.tp)
        return P(None, data, self.tp)

    def w_expert_out(self, n_experts: int) -> P:  # [E, F, D]
        data = "data" if "data" in self.dp else None
        if self.expert_axis == "model" and self.tp:
            tp_size = self.mesh.shape.get(self.tp, 1)
            if n_experts % tp_size == 0:
                return P(self.tp, None, data)
        if n_experts % max(1, self._data_size()) == 0:
            return P(data, self.tp, None)
        return P(None, self.tp, data)

    @property
    def embed(self) -> P:  # [V, D] — row-gather local, D-sharded output
        return P(self._fsdp_axis(), self.tp)

    @property
    def lm_head(self) -> P:  # [D, V] — vocab-sharded logits
        return P(self._fsdp_axis(), self.tp)

    @property
    def replicated(self) -> P:
        return P()


def make_rules(mesh: Mesh | None, seq_shard_residual: bool = True,
               kv_shard: str = "head_dim", expert_axis: str = "data",
               fsdp: bool = False) -> ShardingRules | None:
    if mesh is None:
        return None
    axes = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    return ShardingRules(mesh=mesh, dp=dp, tp=tp,
                         seq_shard_residual=seq_shard_residual,
                         kv_shard=kv_shard, expert_axis=expert_axis, fsdp=fsdp)


def constrain(x, rules: ShardingRules | None, spec_name: str, *args):
    """No-op without rules; otherwise apply the named rule's constraint."""
    if rules is None:
        return x
    spec = getattr(rules, spec_name)
    if callable(spec):
        spec = spec(*args)
    return rules.constrain(x, spec)
