"""Resumable training loop: segments of steps as reproducible jobs.

``train_segment`` is the unit the scheduler submits: initialize-or-resume
from the version store, run N steps, checkpoint every K, commit. Killing the
process anywhere and calling ``train_segment`` again continues from the last
checkpoint and — because data, init, and optimizer are deterministic —
reaches bitwise-identical state (tested in tests/test_train.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.repo import Repository
from ..models import transformer as T
from ..models.params import init_params
from ..optim.adamw import AdamW
from .checkpoint import CheckpointManager
from .steps import make_train_step


@dataclass
class SegmentResult:
    start_step: int
    end_step: int
    final_loss: float
    checkpoint_commit: str | None


def train_segment(
    repo: Repository,
    cfg: ModelConfig,
    dataset,
    n_steps: int,
    ckpt_every: int = 50,
    optimizer: AdamW | None = None,
    rules=None,
    seed: int = 0,
    async_ckpt: bool = False,
) -> SegmentResult:
    optimizer = optimizer or AdamW(lr=1e-3, moment_dtype=cfg.opt_moment_dtype)
    ckpt = CheckpointManager(repo)
    step_fn = jax.jit(make_train_step(cfg, rules, optimizer), donate_argnums=(0, 1))

    state, manifest = ckpt.restore()
    if state is not None:
        params, opt_state = state["params"], state["opt_state"]
        start = int(manifest["step"])
    else:
        params = init_params(T.param_defs(cfg, rules), seed=seed)
        opt_state = optimizer.init(params)
        start = 0

    loss = float("nan")
    commit = None
    for step in range(start, n_steps):
        batch = {"tokens": jnp.asarray(dataset.shard_batch_at(step, 0, 1))}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
            saver = ckpt.save_async if async_ckpt else ckpt.save
            out = saver(
                step + 1, params, opt_state, data_step=step + 1,
                extra={"loss": loss, "config": cfg.name},
            )
            commit = out if isinstance(out, str) else commit
    ckpt.wait()
    if commit is None:
        latest = ckpt.latest()
        commit = latest[0] if latest else None
    return SegmentResult(start, n_steps, loss, commit)
