from .checkpoint import CheckpointManager
from .steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
    masked_loss,
)

__all__ = [
    "CheckpointManager",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
    "masked_loss",
]
