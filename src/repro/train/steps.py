"""Jittable training / prefill / decode step functions.

``make_train_step`` builds the canonical next-token LM objective with
vocab-padding masking, MoE auxiliary loss, grad clipping and AdamW update —
the function the dry-run lowers for ``train_4k`` cells. ``make_prefill_step``
and ``make_decode_step`` are the serving counterparts for ``prefill_32k`` /
``decode_32k`` / ``long_500k``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as T
from ..models.layers import cross_entropy
from ..optim.adamw import AdamW
from ..optim.compression import ef_compress_tree


def masked_loss(logits: jax.Array, tokens: jax.Array, real_vocab: int) -> jax.Array:
    """Shifted next-token CE; vocab-pad columns are masked out of the lse."""
    vp = logits.shape[-1]
    if vp != real_vocab:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < real_vocab, logits, -1e9)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


def _split_microbatches(batch: dict, n: int) -> dict:
    """Reshape every input on its batch axis to [n, B/n, ...] for lax.scan.
    ``positions3`` carries batch on axis 1; everything else on axis 0."""
    out = {}
    for k, v in batch.items():
        ax = 1 if k == "positions3" else 0
        b = v.shape[ax]
        assert b % n == 0, (k, b, n)
        new_shape = v.shape[:ax] + (n, b // n) + v.shape[ax + 1 :]
        out[k] = jnp.moveaxis(v.reshape(new_shape), ax, 0)
    return out


def make_train_step(
    cfg: ModelConfig,
    rules,
    optimizer: AdamW,
    compress_grads: bool = False,
):
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    n_mb = max(1, cfg.microbatches)

    def loss_fn(params, batch):
        logits, aux = T.forward_train(cfg, rules, params, batch)
        loss = masked_loss(logits, batch["tokens"], cfg.vocab_size)
        return loss + aux_w * aux, (loss, aux)

    def grads_of(params, batch):
        if n_mb == 1:
            (_, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, aux, grads

        # gradient accumulation: scan over microbatches, fp32 accumulators —
        # live activation memory is one microbatch's, at n_mb x the steps
        mbs = _split_microbatches(batch, n_mb)

        def mb_step(carry, mb):
            loss_acc, aux_acc, gacc = carry
            (_, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads
            )
            return (loss_acc + loss, aux_acc + aux, gacc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, aux_sum, gsum), _ = jax.lax.scan(
            mb_step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), zeros),
            mbs,
        )
        scale = 1.0 / n_mb
        grads = jax.tree.map(lambda g: (g * scale).astype(jnp.bfloat16), gsum)
        return loss_sum * scale, aux_sum * scale, grads

    def train_step(params, opt_state, batch):
        loss, aux, grads = grads_of(params, batch)
        if compress_grads:
            grads, new_resid = ef_compress_tree(grads, opt_state.get("ef_residual"))
        new_params, new_opt, stats = optimizer.update(grads, opt_state, params)
        if compress_grads:
            new_opt["ef_residual"] = new_resid
        metrics = {"loss": loss, "aux_loss": aux, **stats}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules, cache_len: int):
    def prefill_step(params, batch):
        return T.prefill(cfg, rules, params, batch, cache_len=cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig, rules):
    def decode_step(params, caches, token, pos):
        return T.decode_step(cfg, rules, params, caches, token, pos)

    return decode_step


def greedy_decode(cfg: ModelConfig, rules, params, batch, n_tokens: int,
                  cache_len: int):
    """Simple batched greedy generation built on prefill + decode_step
    (used by the serving example and tests; jitted per-step)."""
    prefill_fn = jax.jit(make_prefill_step(cfg, rules, cache_len))
    step_fn = jax.jit(make_decode_step(cfg, rules))
    caches, logits = prefill_fn(params, batch)
    prompt_len = batch["tokens"].shape[1]
    out = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]
    out.append(tok)
    for i in range(n_tokens - 1):
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        logits, caches = step_fn(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
