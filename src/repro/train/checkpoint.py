"""Checkpointing through the version store.

Checkpoints are first-class *versioned data*: every leaf is streamed into the
annex as a ``.npy`` artifact (content-addressed — unchanged leaves across
steps deduplicate to the same annex key for free) and the worktree records a
pointer, plus a manifest, committed with a machine-actionable record whose
originating :class:`~repro.core.spec.RunSpec` is embedded in the commit
object. This gives the paper's properties to training state: a checkpoint IS
a commit hash; lineage is the commit DAG; a clone knows every checkpoint and
fetches only the one it restores.

Delta dedup (DESIGN.md §12): leaves above the repository's chunk threshold
go through the content-defined chunking tier, so a multi-step campaign where
only a few percent of each tensor changes per step ingests only the changed
chunks — per-step bytes scale with churn, not state size. The save path is a
single streamed pass (npy header + contiguous array slices fed straight into
``AnnexStore.put_stream``); whole-leaf serializations are never staged in
memory. Restore resolves every leaf key from the manifest, finds what is
already local with one batched ``has_many``, delta-fetches only missing
chunks, and reassembles leaves on a thread pool so concurrent streams split
the striped filesystem's aggregate bandwidth (§9).

Fault tolerance: ``restore`` after a crash/preemption resumes from the
newest checkpoint commit; with deterministic data + optimizer the resumed
run is bitwise identical (tested). A crash between leaf publication and the
commit (``ckpt:leaves-written``) leaves only unreferenced annex objects —
``Session.gc()`` sweeps orphaned chunks; the commit either exists entirely
or not at all. Elastic restarts pass a different ``mesh``/``shardings`` —
leaves are re-``device_put`` under the new layout. Async mode runs
host-transfer + file IO + commit on a background thread so the train loop
only blocks for the on-device snapshot; a failure on the worker is re-raised
from ``wait()`` (or the next ``save_async``), never swallowed.
"""
from __future__ import annotations

import io
import json
import os
import threading
from multiprocessing.pool import ThreadPool

import jax
import ml_dtypes
import numpy as np

from ..core.annex import make_pointer
from ..core.records import RunRecord
from ..core.repo import Repository
from ..core.spec import RunSpec

MARKER = "[REPRO CKPT]"

_BLOCK = 1 << 20  # streaming quantum for leaf serialization


def _flatten(tree, prefix=""):
    out = {}
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = v
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def _npy_header(raw: np.ndarray) -> bytes:
    """The exact ``np.save`` prelude (magic + format-1.0 header) for
    ``raw``, so streamed leaves are bit-identical to an ``np.save`` file."""
    buf = io.BytesIO()
    np.lib.format.write_array_header_1_0(
        buf, np.lib.format.header_data_from_array_1_0(raw)
    )
    header = buf.getvalue()
    magic = np.lib.format.magic(1, 0)
    # numpy >= 2.0 emits the magic from write_array_header_1_0 itself;
    # older versions leave it to the caller
    if not header.startswith(magic):
        header = magic + header
    return header


def _npy_stream(header: bytes, raw: np.ndarray, block: int = _BLOCK):
    """Yield an npy serialization as bounded blocks: the header, then
    contiguous slices of the array's own buffer — the whole-file bytes are
    never materialized."""
    yield header
    if raw.nbytes == 0:
        return
    mv = (
        memoryview(raw).cast("B")
        if raw.ndim
        else memoryview(raw.tobytes())  # 0-d: a few bytes, copy is fine
    )
    for i in range(0, raw.nbytes, block):
        yield mv[i : i + block]


class CheckpointManager:
    def __init__(
        self,
        repo: Repository,
        subdir: str = "checkpoints",
        fetch_workers: int = 8,
    ):
        self.repo = repo
        self.subdir = subdir
        self.fetch_workers = fetch_workers
        self._thread: threading.Thread | None = None
        self._async_exc: BaseException | None = None
        # checkpoints() cache, per branch: ref tip the entries were computed
        # at, every commit oid already walked, and the (ts, oid, step) rows
        self._ckpt_cache: dict[str, dict] = {}

    # ------------------------------------------------------------- save
    def save(
        self,
        step: int,
        params,
        opt_state,
        data_step: int = 0,
        extra: dict | None = None,
        message: str = "",
    ) -> str:
        state = {"params": params, "opt_state": opt_state}
        flat = _flatten(state)
        host = {p: np.asarray(jax.device_get(v)) for p, v in flat.items()}
        return self._write(step, host, data_step, extra, message)

    def save_async(self, step, params, opt_state, data_step=0, extra=None,
                   message: str = "") -> None:
        """Snapshot on-device state, then write+commit on a worker thread.
        A failure of the previous async save is re-raised here (and from
        :meth:`wait`) — it is never silently dropped."""
        self.wait()
        flat = _flatten({"params": params, "opt_state": opt_state})
        host = {p: np.asarray(jax.device_get(v)) for p, v in flat.items()}

        def work():
            try:
                self._write(step, host, data_step, extra, message)
            except BaseException as e:  # incl. simulated crashes
                self._async_exc = e

        self._thread = threading.Thread(target=work)
        self._thread.start()

    def wait(self) -> None:
        """Block until the in-flight async save completes; re-raise its
        failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._async_exc = self._async_exc, None
        if exc is not None:
            raise exc

    def _write(self, step, host: dict, data_step, extra, message) -> str:
        reldir = f"{self.subdir}/step_{step:08d}"
        absdir = os.path.join(self.repo.root, reldir)
        fs = self.repo.fs
        manifest = {"step": step, "data_step": data_step, "leaves": {},
                    "extra": extra or {}}
        for path, arr in host.items():
            fname = path.replace("/", ".") + ".npy"
            dtype_name = str(arr.dtype)
            raw = arr
            if arr.dtype == ml_dtypes.bfloat16:  # numpy can't serialize bf16
                raw = arr.view(np.uint16)
            if not raw.flags.c_contiguous:
                # ascontiguousarray would also promote 0-d to 1-d; only
                # copy when the buffer really isn't C-order
                raw = np.ascontiguousarray(raw)
            header = _npy_header(raw)
            chunked = self.repo._should_chunk(len(header) + raw.nbytes)
            key = self.repo.annex.put_stream(
                _npy_stream(header, raw), chunked=chunked
            )
            fs.write_bytes(
                os.path.join(absdir, fname), make_pointer(key, chunked=chunked)
            )
            manifest["leaves"][path] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
                "key": key,
                "chunked": chunked,
            }
        fs.write_bytes(
            os.path.join(absdir, "manifest.json"),
            json.dumps(manifest, indent=1, sort_keys=True).encode(),
        )
        # §10 crash matrix: a crash here leaves published leaves/chunks but
        # no commit — recovery sees zero divergence, gc sweeps the orphans
        fs.crash_point("ckpt:leaves-written")
        spec = RunSpec(cmd=f"checkpoint --step {step}", outputs=(reldir,))
        record = RunRecord(
            cmd=spec.cmd,
            dsid=self.repo.dsid,
            outputs=[reldir],
            extras={"checkpoint_step": step, "data_step": data_step,
                    **(extra or {})},
        )
        msg = message or f"{MARKER} step {step}"
        if MARKER not in msg:
            msg = f"{MARKER} {msg}"
        oid = self.repo.save(
            paths=[reldir], message=record.to_message(msg),
            spec=spec.to_json(),
        )
        fs.crash_point("ckpt:after-commit")
        return oid

    # ---------------------------------------------------------- restore
    def _walk(self, head: str, seen: set, old_head: str | None):
        """Walk ancestry from ``head``, stopping at already-seen commits.
        Returns (new (ts, oid, step) rows, whether ``old_head`` was reached)
        — reaching it proves the update was append-only, so the cached rows
        are still exactly the checkpoints reachable from ``head``."""
        touched = old_head is None
        out = []
        frontier = [head]
        while frontier:
            oid = frontier.pop()
            if oid == old_head:
                touched = True
            if oid in seen:
                continue
            seen.add(oid)
            c = self.repo.objects.get_commit(oid)
            if MARKER in c["message"]:
                rec = RunRecord.from_message(c["message"])
                if rec and "checkpoint_step" in rec.extras:
                    out.append(
                        (c["timestamp"], oid, rec.extras["checkpoint_step"])
                    )
            frontier.extend(c["parents"])
        return out, touched

    def checkpoints(self) -> list[tuple[str, int]]:
        """(commit, step) for every checkpoint commit, newest first.

        Cached by ref tip: an unchanged HEAD answers from the cache, an
        advanced HEAD walks only the commits added since the last call — so
        ``latest()`` inside a long campaign is O(new commits), not a re-scan
        of the whole log per save. A rewritten history (reset/amend, where
        the new tip's ancestry never meets the cached tip) rebuilds from
        scratch."""
        head = self.repo.head_commit()
        if head is None:
            return []
        branch = self.repo.current_branch()
        cache = self._ckpt_cache.get(branch)
        if cache is not None and cache["head"] == head:
            return [(oid, s) for _, oid, s in cache["entries"]]
        if cache is None:
            cache = {"head": None, "seen": set(), "entries": []}
        new, touched = self._walk(head, cache["seen"], cache["head"])
        if not touched:
            cache = {"head": None, "seen": set(), "entries": []}
            new, _ = self._walk(head, cache["seen"], None)
        entries = sorted(cache["entries"] + new, key=lambda e: (-e[0], -e[2]))
        cache.update(head=head, entries=entries)
        self._ckpt_cache[branch] = cache
        return [(oid, s) for _, oid, s in entries]

    def latest(self) -> tuple[str, int] | None:
        cps = self.checkpoints()
        return cps[0] if cps else None

    def _tree_bytes(self, oid: str, rel: str) -> bytes:
        """Read one committed file's content straight from the object store
        / annex — no worktree checkout."""
        entry = self.repo.entry_at(oid, rel)
        if entry is None:
            raise FileNotFoundError(f"{rel} not in commit {oid}")
        if entry["t"] == "blob":
            return self.repo.objects.get_blob(entry["oid"])
        self.repo.annex_fetch_key(
            entry["key"], chunked=bool(entry.get("chunked"))
        )
        return self.repo.annex.read(entry["key"])

    def restore(self, commitish: str | None = None, shardings=None,
                fetch_workers: int | None = None):
        """Returns (state_tree, manifest). ``shardings``: optional pytree (or
        flat {path: sharding}) to device_put leaves under — this is the
        elastic-resume path (different mesh than at save time).

        Leaves are resolved to annex keys from the manifest, a batched
        ``has_many`` finds what is already local, missing keys delta-fetch
        (only chunks not shared with already-restored checkpoints move), and
        reassembly runs on ``fetch_workers`` threads so concurrent read
        streams split the aggregate bandwidth (§9)."""
        if commitish is None:
            latest = self.latest()
            if latest is None:
                return None, None
            commitish = latest[0]
        oid = self.repo.resolve(commitish)
        rec = RunRecord.from_message(
            self.repo.objects.get_commit(oid)["message"]
        )
        step = rec.extras["checkpoint_step"]
        reldir = f"{self.subdir}/step_{step:08d}"
        manifest = json.loads(self._tree_bytes(oid, f"{reldir}/manifest.json"))
        leaves = manifest["leaves"]
        # resolve each leaf to an annex key; legacy checkpoints (no "key" in
        # the manifest) fall back to the committed tree entry, where small
        # leaves may be inline blobs
        jobs: dict[str, tuple] = {}
        for path, meta in leaves.items():
            key = meta.get("key")
            chunked = bool(meta.get("chunked"))
            if key is None:
                entry = self.repo.entry_at(oid, f"{reldir}/{meta['file']}")
                if entry is None:
                    raise FileNotFoundError(
                        f"{reldir}/{meta['file']} not in commit {oid}"
                    )
                if entry["t"] == "annex":
                    key, chunked = entry["key"], bool(entry.get("chunked"))
                else:
                    jobs[path] = ("blob", entry["oid"])
                    continue
            jobs[path] = ("key", key, chunked)
        keys = [j[1] for j in jobs.values() if j[0] == "key"]
        local = self.repo.annex.has_many(keys)

        def fetch(item):
            path, job = item
            if job[0] == "blob":
                data = self.repo.objects.get_blob(job[1])
            else:
                _, key, chunked = job
                if key not in local:
                    self.repo.annex_fetch_key(key, chunked=chunked)
                data = self.repo.annex.read(key)
            return path, np.load(io.BytesIO(data))

        items = list(jobs.items())
        workers = fetch_workers if fetch_workers is not None else self.fetch_workers
        if workers > 1 and len(items) > 1:
            with ThreadPool(min(workers, len(items))) as pool:
                loaded = pool.map(fetch, items)
        else:
            loaded = [fetch(it) for it in items]
        arrays = dict(loaded)
        flat_shardings = (
            _flatten(shardings) if isinstance(shardings, dict) else None
        )
        flat = {}
        for path, meta in leaves.items():
            arr = arrays[path]
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if flat_shardings is not None and path in flat_shardings:
                flat[path] = jax.device_put(arr, flat_shardings[path])
            else:
                flat[path] = jax.numpy.asarray(arr)
        return _unflatten(flat), manifest
