"""Checkpointing through the version store.

Checkpoints are first-class *versioned data*: every leaf is written as an
annexed ``.npy`` artifact (content-addressed — unchanged leaves across steps
deduplicate to the same annex key for free), plus a manifest, committed with
a machine-actionable record. This gives the paper's properties to training
state: a checkpoint IS a commit hash; lineage is the commit DAG; a clone
knows every checkpoint and ``annex_get``s only the one it restores.

Fault tolerance: ``restore_latest`` after a crash/preemption resumes from the
newest checkpoint commit; with deterministic data + optimizer the resumed
run is bitwise identical (tested). Elastic restarts pass a different
``mesh``/``shardings`` — leaves are re-``device_put`` under the new layout.
Async mode runs host-transfer + file IO + commit on a background thread so
the train loop only blocks for the on-device snapshot.
"""
from __future__ import annotations

import io
import json
import os
import threading

import jax
import ml_dtypes
import numpy as np

from ..core.records import RunRecord
from ..core.repo import Repository

MARKER = "[REPRO CKPT]"


def _flatten(tree, prefix=""):
    out = {}
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = v
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, repo: Repository, subdir: str = "checkpoints"):
        self.repo = repo
        self.subdir = subdir
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(
        self,
        step: int,
        params,
        opt_state,
        data_step: int = 0,
        extra: dict | None = None,
        message: str = "",
    ) -> str:
        state = {"params": params, "opt_state": opt_state}
        flat = _flatten(state)
        host = {p: np.asarray(jax.device_get(v)) for p, v in flat.items()}
        return self._write(step, host, data_step, extra, message)

    def save_async(self, step, params, opt_state, data_step=0, extra=None,
                   message: str = "") -> None:
        """Snapshot on-device state, then write+commit on a worker thread."""
        self.wait()
        flat = _flatten({"params": params, "opt_state": opt_state})
        host = {p: np.asarray(jax.device_get(v)) for p, v in flat.items()}
        self._thread = threading.Thread(
            target=self._write, args=(step, host, data_step, extra, message)
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host: dict, data_step, extra, message) -> str:
        reldir = f"{self.subdir}/step_{step:08d}"
        absdir = os.path.join(self.repo.root, reldir)
        os.makedirs(absdir, exist_ok=True)
        manifest = {"step": step, "data_step": data_step, "leaves": {},
                    "extra": extra or {}}
        for path, arr in host.items():
            fname = path.replace("/", ".") + ".npy"
            dtype_name = str(arr.dtype)
            raw = arr
            if arr.dtype == ml_dtypes.bfloat16:  # numpy can't serialize bf16
                raw = arr.view(np.uint16)
            buf = io.BytesIO()
            np.save(buf, raw)
            self.repo.fs.write_bytes(os.path.join(absdir, fname), buf.getvalue())
            manifest["leaves"][path] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        self.repo.fs.write_bytes(
            os.path.join(absdir, "manifest.json"),
            json.dumps(manifest, indent=1, sort_keys=True).encode(),
        )
        record = RunRecord(
            cmd=f"checkpoint step={step}",
            dsid=self.repo.dsid,
            outputs=[reldir],
            extras={"checkpoint_step": step, "data_step": data_step,
                    **(extra or {})},
        )
        return self.repo.save(
            paths=[reldir],
            message=record.to_message(message or f"{MARKER} step {step}"),
        )

    # ---------------------------------------------------------- restore
    def checkpoints(self) -> list[tuple[str, int]]:
        """(commit, step) for every checkpoint commit, newest first."""
        out = []
        for oid, commit in self.repo.log():
            if MARKER in commit["message"]:
                rec = RunRecord.from_message(commit["message"])
                if rec and "checkpoint_step" in rec.extras:
                    out.append((oid, rec.extras["checkpoint_step"]))
        return out

    def latest(self) -> tuple[str, int] | None:
        cps = self.checkpoints()
        return cps[0] if cps else None

    def restore(self, commitish: str | None = None, shardings=None):
        """Returns (state_tree, manifest). ``shardings``: optional pytree (or
        flat {path: sharding}) to device_put leaves under — this is the
        elastic-resume path (different mesh than at save time)."""
        if commitish is None:
            latest = self.latest()
            if latest is None:
                return None, None
            commitish = latest[0]
        oid = self.repo.resolve(commitish)
        rec = RunRecord.from_message(self.repo.objects.get_commit(oid)["message"])
        step = rec.extras["checkpoint_step"]
        reldir = f"{self.subdir}/step_{step:08d}"
        self.repo.checkout(oid, paths=[reldir])
        absdir = os.path.join(self.repo.root, reldir)
        manifest = json.loads(
            self.repo.fs.read_bytes(os.path.join(absdir, "manifest.json"))
        )
        flat_shardings = (
            _flatten(shardings) if isinstance(shardings, dict) else None
        )
        flat = {}
        for path, meta in manifest["leaves"].items():
            rel = f"{reldir}/{meta['file']}"
            self.repo.annex_get(rel)
            arr = np.load(os.path.join(self.repo.root, rel))
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if flat_shardings is not None and path in flat_shardings:
                flat[path] = jax.device_put(arr, flat_shardings[path])
            else:
                flat[path] = jax.numpy.asarray(arr)
        return _unflatten(flat), manifest
