"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + DENSE RESIDUAL (the Arctic hybrid-dense trick)
[hf:Snowflake/snowflake-arctic-base; hf]. bf16 Adam moments (400B-class)."""
from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab_size=32000, d_head=128, rope_theta=1e4,
        moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True),
        opt_moment_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=512, d_head=16,
        moe=MoEConfig(n_experts=4, top_k=2, dense_residual=True, capacity_factor=8.0),
    )
