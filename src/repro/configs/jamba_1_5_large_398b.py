"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave
(attention at position 3 of each 8-layer Jamba block), MoE every 2nd layer
[arXiv:2403.19887; hf]. bf16 Adam moments (400B-class)."""
from .base import MambaConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab_size=65536, d_head=128, rope=False,
        moe=MoEConfig(n_experts=16, top_k=2, every_k_layers=2),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn_period=8, attn_offset=3,
        opt_moment_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16, rope=False,
        moe=MoEConfig(n_experts=4, top_k=2, every_k_layers=2, capacity_factor=8.0),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        attn_period=8, attn_offset=3,
    )
