"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA, tied embeddings
[hf:ibm-granite/granite-3.0-2b-base; hf]. Vocab 49155 is padded to 49408
(multiple of 256) for even sharding; loss masks the pad rows."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=49155, d_head=64, rope_theta=1e4,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=500, d_head=16, tie_embeddings=True,
    )
