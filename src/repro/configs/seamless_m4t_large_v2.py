"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal [arXiv:2308.11596; hf].
Backbone only: the speech frontend is a stub; input_specs provides
precomputed frame embeddings [B, S/4, D] for the encoder."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206, d_head=64, rope=False,
        enc_dec=True, n_enc_layers=24, enc_len_ratio=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, d_head=16, rope=False,
        enc_dec=True, n_enc_layers=2, enc_len_ratio=4,
    )
