"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the exact full-size config from the assignment table;
``get_smoke(name)`` returns the reduced same-family config used by the CPU
smoke tests (tiny widths, few experts, tiny vocab — same code paths).

Shape grid (the assignment's 4 shapes; ``runnable`` encodes the long_500k
sub-quadratic rule and is recorded as explicit skips in EXPERIMENTS.md).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from .base import LayerKind, MambaConfig, ModelConfig, MoEConfig

ARCH_IDS = [
    "internlm2_20b",
    "qwen3_0_6b",
    "phi3_mini_3_8b",
    "granite_3_2b",
    "arctic_480b",
    "mixtral_8x22b",
    "seamless_m4t_large_v2",
    "qwen2_vl_7b",
    "rwkv6_1_6b",
    "jamba_1_5_large_398b",
]


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def _module(name: str):
    return importlib.import_module(f".{name.replace('-', '_')}", __package__)


def get(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if decoding at 500k context doesn't need a full-size KV cache."""
    return (
        cfg.ssm is not None
        or cfg.attn_period > 0
        or cfg.sliding_window is not None
    )


def cell_runnable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """The assignment's skip rules for (arch x shape) cells."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, (
            "long_500k skipped: pure full-attention architecture (O(S) KV "
            "cache at 524288 ctx; assignment mandates sub-quadratic only)"
        )
    return True, ""


__all__ = [
    "ARCH_IDS", "SHAPES", "Shape", "LayerKind", "MambaConfig", "ModelConfig",
    "MoEConfig", "get", "get_smoke", "cell_runnable", "is_subquadratic",
]
