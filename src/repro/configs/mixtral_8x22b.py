"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768, d_head=128, rope_theta=1e6,
        sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16, sliding_window=8,
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0),
    )
