"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (temporal/height/width rotary sections), dynamic
resolution [arXiv:2409.12191; hf]. Backbone only: the vision frontend is a
stub; input_specs provides precomputed patch embeddings for the first S/8
positions plus 3-stream M-RoPE position ids."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, d_head=128, rope_theta=1e6,
        mrope_sections=(16, 24, 24), vision_len_ratio=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, d_head=16,
        mrope_sections=(2, 3, 3), vision_len_ratio=8,
    )
