"""Model / run configuration system.

One :class:`ModelConfig` expresses every assigned architecture family:
dense GQA transformers, MoE (incl. dense-residual Arctic style), sliding-
window attention, encoder-decoder (audio backbone), M-RoPE VLM backbone,
RWKV6 (attention-free), and Mamba/attention hybrids with interleaved MoE.

Layer heterogeneity is expressed as a repeating *block pattern*: a tuple of
layer descriptors that tiles the depth (e.g. Jamba's 8-layer block with one
attention layer and MoE on every 2nd layer). Stacking weights per pattern
position keeps `lax.scan` over repeats applicable to every family, which is
what keeps compiled HLO size O(pattern) instead of O(depth).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    every_k_layers: int = 1  # MoE on layers where (i % every_k) == every_k-1
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class LayerKind:
    mixer: str  # 'attn' | 'rwkv6' | 'mamba'
    moe: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 1e6
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE half-dim sections
    sliding_window: int | None = None
    moe: MoEConfig | None = None
    mamba: MambaConfig = field(default_factory=MambaConfig)
    # encoder-decoder (audio): encoder layers + how encoder length derives
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len_ratio: int = 4  # S_enc = seq_len // ratio
    # vlm: fraction of prefix positions fed as patch embeddings
    vision_len_ratio: int = 0  # 0 = no vision prefix; else S_vis = seq // ratio
    # mixer pattern: 'attn' everywhere by default; 'rwkv6' for ssm family;
    # hybrid uses attn_period (layer i is attention iff i % attn_period ==
    # attn_offset, else mamba)
    ssm: str | None = None  # None | 'rwkv6' | 'mamba'
    attn_period: int = 0  # 0 = homogeneous
    attn_offset: int = 3
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # runtime / optimizer knobs
    opt_moment_dtype: str = "float32"  # 'bfloat16' for 400B-class
    remat: bool = True
    use_pallas: str = "auto"  # 'auto' | 'on' | 'off'
    # performance knobs (hillclimbed in EXPERIMENTS.md §Perf)
    seq_shard_residual: bool = True  # Megatron-style sequence parallelism
    attn_q_chunk: int = 1024  # blockwise attention q-chunk (memory roofline)
    attn_unroll_chunks: bool = False  # python-loop chunks (exact HLO flop counts)
    decode_kv_shard: str = "head_dim"  # 'head_dim' | 'seq': KV-cache tp placement
    moe_expert_axis: str = "data"  # 'data' (ZeRO gather) | 'model' (EP all-to-all)
    fsdp_params: bool = False  # ZeRO-3: params+moments sharded over data AND model
    zero1_moments: bool = False  # ZeRO-1: only Adam moments sharded over data
    microbatches: int = 1  # gradient accumulation (activation-memory / batch trade)
    scan_layers: bool = True

    # -- derived -------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards evenly over any
        production mesh axis (MaxText-style)."""
        return _round_up(self.vocab_size, 256)

    @property
    def pattern(self) -> tuple[LayerKind, ...]:
        """The repeating layer-kind pattern (length divides n_layers)."""
        if self.ssm == "rwkv6":
            return (LayerKind("rwkv6"),)
        if self.attn_period > 0:  # hybrid
            period = self.attn_period
            moe_every = self.moe.every_k_layers if self.moe else 1
            span = math.lcm(period, moe_every)
            kinds = []
            for i in range(span):
                mixer = "attn" if i % period == self.attn_offset else "mamba"
                is_moe = bool(self.moe) and (i % moe_every == moe_every - 1)
                kinds.append(LayerKind(mixer, is_moe))
            return tuple(kinds)
        if self.moe is not None and self.moe.every_k_layers > 1:
            return tuple(
                LayerKind("attn", moe=(i % self.moe.every_k_layers == self.moe.every_k_layers - 1))
                for i in range(self.moe.every_k_layers)
            )
        return (LayerKind("attn", moe=self.moe is not None),)

    @property
    def n_repeats(self) -> int:
        p = len(self.pattern)
        if self.n_layers % p:
            raise ValueError(f"{self.name}: n_layers={self.n_layers} not divisible by pattern {p}")
        return self.n_layers // p

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba.expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for MODEL_FLOPS = 6·N·D roofline term) -------
    def param_counts(self) -> dict:
        """Returns {'total': N, 'active': N_active} (active = per-token)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D  # q,k,v,o
        dense_ffn = 3 * D * F  # SwiGLU w1,w3,w2
        moe_ffn = 0 if not self.moe else self.moe.n_experts * 3 * D * F
        moe_active = 0 if not self.moe else self.moe.top_k * 3 * D * F
        router = 0 if not self.moe else D * self.moe.n_experts
        # rwkv6 time-mix (5 square proj + decay lora) + channel-mix (k,v,r)
        rwkv = 5 * D * D + 2 * D * 64 + (D * F + F * D + D * D)
        # mamba: in_proj 2*Di*D, conv Di*4, x_proj Di*(dt+2*state), dt_proj, out_proj
        Di, St = self.mamba_d_inner, self.mamba.d_state
        mamba = 2 * Di * D + 4 * Di + Di * (Di // 16 + 2 * St) + Di * Di // 16 + Di * D
        total = 0
        active = 0
        for kind in self.pattern:
            if kind.mixer == "attn":
                mix = attn
            elif kind.mixer == "rwkv6":
                mix = rwkv
            else:
                mix = mamba
            if kind.mixer == "rwkv6":
                ffn_t = ffn_a = 0  # channel-mix is part of the rwkv term
            elif kind.moe:
                ffn_t = moe_ffn + router + (dense_ffn if self.moe.dense_residual else 0)
                ffn_a = moe_active + router + (dense_ffn if self.moe.dense_residual else 0)
            else:
                ffn_t = ffn_a = dense_ffn
            total += mix + ffn_t + 2 * D
            active += mix + ffn_a + 2 * D
        total *= self.n_repeats
        active *= self.n_repeats
        if self.enc_dec:
            # encoder layers: self-attn + ffn; decoder already counted; add
            # cross-attention for decoder layers
            enc = (attn + dense_ffn + 2 * D) * self.n_enc_layers
            cross = (attn + D) * self.n_layers
            total += enc + cross
            active += enc + cross
        emb = V * D * (1 if self.tie_embeddings else 2)
        return {"total": total + emb, "active": active + emb}
