"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: data-dependent decay [arXiv:2404.05892; unverified].
Head size 64 (RWKV convention) -> 32 heads."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536, d_head=64, rope=False,
        ssm="rwkv6",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, d_head=16, rope=False,
        ssm="rwkv6",
    )
