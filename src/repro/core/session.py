"""Session: the documented entry point of the library.

``repro.open(root)`` returns a :class:`Session` — one object that drives
every execution path of the paper's workflow through declarative
:class:`~repro.core.spec.RunSpec` objects:

    import repro
    from repro import RunSpec

    s = repro.open("/path/to/project", create=True)
    s.save(message="inputs")                       # version the worktree
    s.run(cmd="python analyze.py", inputs=["in.csv"], outputs=["fig.csv"])
    s.rerun("HEAD")                                # bitwise-verified replay

    job = s.submit(RunSpec(script="job.sh", outputs=["out"]))   # one job
    ids = s.submit_many([RunSpec(script=f"j{i}.sh", outputs=[f"o{i}"])
                         for i in range(64)])      # batched: 1 CLI charge,
                                                   # 1 jobdb transaction,
                                                   # 1 conflict pass
    s.wait()
    s.finish(octopus=True)
    s.reschedule(commitish=...)                    # exact-spec resubmission

The scheduler/cluster pair is constructed lazily, so a Session used only for
``run``/``rerun`` never spins up a thread pool. The legacy free-function /
keyword surfaces (``records.run``, ``SlurmScheduler.schedule``) remain as
shims over the same spec layer.
"""
from __future__ import annotations

import os

from . import records as R
from .fsio import NULL_FS, FSProfile, SimClock
from .repo import REPRO_DIR, Repository
from .scheduler import FinishResult, ScheduleError, SlurmScheduler
from .slurm import LocalSlurmCluster, SlurmCluster
from .spec import RunSpec


class Session:
    """A repository plus (lazily) a cluster + scheduler, driven by specs."""

    def __init__(
        self,
        repo: Repository,
        cluster: SlurmCluster | None = None,
        cli_startup_s: float = 0.0,
        max_workers: int = 8,
        auto_repack_threshold: int | None | str = "auto",
        ingest_workers: int = 0,
        run_cache: bool = True,
        cache_env: dict | None = None,
    ):
        self.repo = repo
        self.cli_startup_s = cli_startup_s
        self._max_workers = max_workers
        self.ingest_workers = ingest_workers
        # §11 run cache: on by default; cache_env folds an environment
        # fingerprint into every execution key
        self.run_cache = run_cache
        self.cache_env = cache_env
        self._cluster = cluster
        self._scheduler: SlurmScheduler | None = None
        self._owns_cluster = cluster is None
        if isinstance(auto_repack_threshold, str) and auto_repack_threshold != "auto":
            raise ValueError(
                f"auto_repack_threshold must be an int, None, or 'auto'; "
                f"got {auto_repack_threshold!r}"
            )
        if auto_repack_threshold == "auto":
            # default: compact once a loose shard would start paying the
            # parallel-FS degradation penalty; harmless (never derived) on
            # profiles without one. None disables explicitly, exactly like
            # SlurmScheduler's own parameter.
            p = repo.fs.profile
            auto_repack_threshold = (
                p.degrade_threshold if p.dir_degrade > 0 else None
            )
        self.auto_repack_threshold = auto_repack_threshold

    # ------------------------------------------------------------ plumbing
    @property
    def cluster(self) -> SlurmCluster:
        if self._cluster is None:
            self._cluster = LocalSlurmCluster(
                max_workers=self._max_workers, clock=self.repo.fs.clock,
                faults=getattr(self.repo.fs, "faults", None),
            )
        return self._cluster

    @property
    def scheduler(self) -> SlurmScheduler:
        if self._scheduler is None:
            self._scheduler = SlurmScheduler(
                self.repo, self.cluster, cli_startup_s=self.cli_startup_s,
                auto_repack_threshold=self.auto_repack_threshold,
                ingest_workers=self.ingest_workers,
                run_cache=self.run_cache, cache_env=self.cache_env,
            )
        return self._scheduler

    @property
    def dsid(self) -> str:
        return self.repo.dsid

    def close(self) -> None:
        """Shut down a lazily created local cluster (no-op otherwise)."""
        if self._owns_cluster and self._cluster is not None:
            shutdown = getattr(self._cluster, "shutdown", None)
            if shutdown:
                shutdown()
            self._cluster = None
            self._scheduler = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- versioning
    def save(self, paths=None, message: str = "", **kw) -> str:
        return self.repo.save(paths=paths, message=message, **kw)

    def head(self) -> str | None:
        return self.repo.head_commit()

    def gc(self, delete_loose: bool = True, prune_cache: bool = True,
           sweep_chunks: bool = True) -> dict:
        """Compact the object store: migrate loose objects into a pack and
        drop the shard entry counts that parallel-FS metadata latency
        degrades with (DESIGN.md §8). Crash-safe — the pack is published
        before any loose file is unlinked. ``prune_cache`` (default) also
        evicts §11 run-cache rows whose recorded commit or annex objects no
        longer exist, so the cache can never serve a hit it cannot
        materialize, and ``sweep_chunks`` drops chunk-tier objects (§12) no
        manifest references — what a crashed chunked ingest or a dropped
        chunked key leaves behind. Returns repack stats (+ ``cache_evicted``,
        ``chunks_swept``)."""
        stats = dict(self.repo.objects.repack(delete_loose=delete_loose) or {})
        if sweep_chunks and self.repo.annex.chunk_aware:
            stats["chunks_swept"] = self.repo.annex.sweep_orphan_chunks()
        if prune_cache:
            from .jobdb import JobDB
            from .runcache import RunCache

            db = (
                self._scheduler.db if self._scheduler is not None
                else JobDB(self.repo.repro_dir)
            )
            stats["cache_evicted"] = len(
                RunCache(self.repo, db).evict_missing()
            )
        return stats

    # ------------------------------------------------------------ execution
    @staticmethod
    def _coerce(spec: RunSpec | None, kwargs: dict) -> RunSpec:
        if spec is not None and kwargs:
            raise TypeError("pass either a RunSpec or keyword fields, not both")
        if spec is None:
            spec = RunSpec(**kwargs)
        return spec

    def run(self, spec: RunSpec | None = None, **kwargs) -> str:
        """Execute a command spec blocking and commit outputs + record
        (``datalad run``). Accepts a :class:`RunSpec` or its fields."""
        return R.run_spec(self.repo, self._coerce(spec, kwargs))

    def rerun(self, commitish: str, report_only: bool = False) -> dict:
        """Replay a recorded commit's exact spec and hash-verify the outputs
        (``datalad rerun``)."""
        return R.rerun(self.repo, commitish, report_only=report_only)

    def spec_of(self, commitish: str) -> RunSpec:
        """The originating spec of a recorded commit."""
        return R.spec_of(self.repo, commitish)

    # ----------------------------------------------------------- scheduling
    def submit(
        self, spec: RunSpec | None = None, refresh: bool = False, **kwargs
    ) -> int:
        """Submit one script spec to the batch system (``slurm-schedule``).
        ``refresh=True`` bypasses the §11 run cache (forces execution)."""
        return self.scheduler.submit(self._coerce(spec, kwargs), refresh=refresh)

    def submit_many(
        self, specs: list[RunSpec], refresh: bool = False
    ) -> list[int]:
        """Submit a batch: one CLI-startup charge, one jobdb transaction,
        one shared conflict pass for all specs. Cache-hit specs (§11)
        short-circuit into memoized records without touching Slurm;
        ``refresh=True`` bypasses the lookup."""
        return self.scheduler.submit_many(specs, refresh=refresh)

    def finish(self, **kw) -> list[FinishResult]:
        """Commit results of finished jobs (``slurm-finish``)."""
        return self.scheduler.finish(**kw)

    def run_pipeline(
        self,
        pipeline,
        refresh: bool = False,
        wait: bool = True,
        finish: bool = True,
        timeout: float = 600.0,
        **finish_kw,
    ) -> dict:
        """Submit a :class:`~repro.core.dag.Pipeline` DAG as one campaign
        (§14): topologically batched ``submit_many`` calls chained with
        ``afterok`` edges, memoized stages cut out of the DAG before
        anything reaches Slurm. With ``wait`` (default) blocks until every
        real job is terminal; with ``finish`` (default) then commits the
        results — so a mid-campaign failure can be replayed by simply
        calling ``run_pipeline`` again: completed stages come back from the
        run cache and only the failed cone re-executes.

        Returns ``{"jobs": {stage: job_id}, "results": [FinishResult]}``.
        """
        jobs = self.scheduler.submit_pipeline(pipeline, refresh=refresh)
        out: dict = {"jobs": dict(jobs), "results": []}
        if not wait:
            return out
        open_ids = [
            jid for jid in jobs.values()
            if (row := self.scheduler.db.get(jid))
            and row["status"] == "scheduled"
        ]
        if open_ids:
            self.wait(open_ids, timeout=timeout)
        if finish:
            out["results"] = self.scheduler.finish(**finish_kw)
        return out

    def reschedule(self, commitish: str | None = None, **kw) -> list[int]:
        """Resubmit from stored specs (``slurm-reschedule``)."""
        return self.scheduler.reschedule(commitish=commitish, **kw)

    def wait(self, job_ids: list[int] | None = None, timeout: float = 300.0) -> None:
        """Block until the given (default: all) slurm jobs are terminal."""
        slurm_ids = None
        if job_ids is not None:
            jobs = {j: self.scheduler.db.get(j) for j in job_ids}
            unknown = [j for j, row in jobs.items() if row is None]
            if unknown:
                raise ScheduleError(f"unknown job(s): {unknown}")
            # terminal rows have nothing to wait on — in particular §11
            # cache hits close as 'memoized' with no slurm id at all
            open_rows = [
                row for row in jobs.values() if row["status"] == "scheduled"
            ]
            # a NULL slurm id on an OPEN row (crash between add_jobs and
            # set_slurm_ids) would block forever — fail fast like finish
            # reports "UNKNOWN"
            unsubmitted = [
                row["job_id"] for row in open_rows if row["slurm_id"] is None
            ]
            if unsubmitted:
                raise ScheduleError(
                    f"job(s) {unsubmitted} have no slurm id (submission never "
                    "completed); close them via finish(close_failed_jobs=True)"
                )
            if not open_rows:
                return
            slurm_ids = [row["slurm_id"] for row in open_rows]
        self.cluster.wait(slurm_ids, timeout=timeout)

    def status(self) -> list[dict]:
        """Open jobs with their live Slurm state (``--list-open-jobs``)."""
        return [
            {**job, "slurm_state": state}
            for job, state in self.scheduler.list_open_jobs()
        ]

    # ---------------------------------------------------------- remote tier
    def _db(self):
        """The jobdb without forcing a cluster into existence (the remote
        tier is data-plane only)."""
        from .jobdb import JobDB

        return (
            self._scheduler.db if self._scheduler is not None
            else JobDB(self.repo.repro_dir)
        )

    def add_remote(self, store_root: str, name: str | None = None,
                   net=None):
        """Register a simulated remote site (DESIGN.md §13): an annex store
        reached over a network link ('lan', 'wan', a
        :class:`~repro.core.remote.NetProfile`, or its dict form). The site
        list persists in the repo config; returns the
        :class:`~repro.core.remote.RemoteStore`."""
        return self.repo.add_remote(store_root, name=name, net=net)

    def push(self, remote: str | None = None, keys: list[str] | None = None,
             journal: bool = True) -> list[dict]:
        """Chunk-level resumable push of ``keys`` (default: every annex key
        HEAD references) to ``remote`` (a name; default: every available
        configured remote). Presence is pre-checked per remote in one
        batched round trip, only missing chunks move, intent is journaled
        so a killed push resumes via :meth:`recover`, and verified
        transfers are recorded in the location index. Returns one report
        dict per remote pushed."""
        from .remote import push_keys

        stores = (
            [self.repo.remote_by_name(remote)] if remote is not None
            else [s for s in self.repo.remote_stores if s.available]
        )
        if not stores:
            raise ValueError("no (available) remotes configured")
        db = self._db()
        return [
            push_keys(self.repo, s, keys, journal=journal, db=db)
            for s in stores
        ]

    def pull(self, paths: list[str] | None = None,
             keys: list[str] | None = None, journal: bool = True) -> dict:
        """Chunk-level resumable pull into the local annex, with replica
        failover — a dead remote is marked unavailable and the next one
        serves. ``paths`` name worktree files (their HEAD annex keys are
        pulled); ``keys`` pass keys directly; neither = every annex key
        HEAD references. Locally present keys never move."""
        from .remote import pull_keys

        if paths is not None:
            keys = list(keys or []) + [
                self.repo.annex_key_at(p) for p in paths
            ]
        return pull_keys(self.repo, keys, journal=journal, db=self._db())

    def fetch(self, missing_only: bool = True, journal: bool = True) -> dict:
        """Ensure the local annex holds every key HEAD references, pulling
        the missing ones from the configured replicas (cold-restore path).
        ``missing_only`` is the contract (present keys are never
        re-fetched); it exists as a parameter for API symmetry."""
        del missing_only  # pull always skips locally present keys
        return self.pull(journal=journal)

    def drop(self, path: str, force: bool = False) -> None:
        """Drop the local copy of an annexed file, leaving a pointer.
        Refused unless ``numcopies`` *fresh-verified* replicas exist
        elsewhere (never trusts cached presence); ``force=True``
        overrides (DESIGN.md §13)."""
        self.repo.annex_drop(path, force=force)

    def whereis(self, paths: list[str] | None = None,
                fresh: bool = False) -> dict[str, dict]:
        """Per-key locations: ``{key: {"stores": [...], "recorded": [...]}}``
        for ``paths`` (default: every annex key HEAD references).
        ``stores`` are live probes across local + remotes (``fresh=True``
        bypasses the known-key sets); ``recorded`` is the jobdb location
        index — the cheap hint tier verify() cross-checks."""
        from .remote import head_annex_keys

        if paths is not None:
            keys = [self.repo.annex_key_at(p) for p in paths]
        else:
            keys = head_annex_keys(self.repo)
        recorded = self._db().locations_of(keys)
        stores = [self.repo.annex, *self.repo._remotes]
        live: dict[str, set[str]] = {}
        for s in stores:
            from .remote import RemoteStore

            if isinstance(s, RemoteStore) and not s.available:
                continue
            live[s.name] = s.has_many(keys, fresh=fresh)
        return {
            k: {
                "stores": [n for n in live if k in live[n]],
                "recorded": recorded.get(k, []),
            }
            for k in keys
        }

    # ------------------------------------------------------------- recovery
    def recover(self, close_unsubmitted: bool = True,
                max_tmp_age_s: float | None = 3600.0) -> dict:
        """Crash recovery (DESIGN.md §10): break stale locks, sweep
        dead-owner annex tmps, replay intent journals (exactly-once finish
        and submit), close orphan rows, release orphan protection.
        Idempotent; returns a report dict."""
        from . import recovery as _recovery

        return _recovery.recover(
            self, close_unsubmitted=close_unsubmitted,
            max_tmp_age_s=max_tmp_age_s,
        )

    def verify(self, repair: bool = False) -> dict:
        """fsck: cross-check jobdb ↔ refs ↔ object store ↔ annex and report
        divergence; ``repair=True`` fixes what is safe (DESIGN.md §10)."""
        from . import recovery as _recovery

        return _recovery.verify(self, repair=repair)


def open(
    root: str,
    create: bool = False,
    profile: FSProfile = NULL_FS,
    clock: SimClock | None = None,
    cluster: SlurmCluster | None = None,
    cli_startup_s: float = 0.0,
    max_workers: int = 8,
    auto_repack_threshold: int | None | str = "auto",
    ingest_workers: int = 0,
    run_cache: bool = True,
    cache_env: dict | None = None,
    faults=None,
    net_faults=None,
    **init_kwargs,
) -> Session:
    """Open (or with ``create=True``, initialize) a repository at ``root``
    and return a :class:`Session` over it — the documented entry point.
    ``faults`` attaches a :class:`~repro.core.faults.FaultPlan` to the
    session's FS and (lazily created) cluster — the fault-injection harness
    of DESIGN.md §10. ``net_faults`` attaches a
    :class:`~repro.core.remote.NetworkFaultModel` to every configured
    remote — the §13 unreliable-network model. ``run_cache`` toggles §11
    execution memoization (``submit*(..., refresh=True)`` bypasses it per
    call); ``cache_env`` folds an environment fingerprint into every
    execution key."""
    if os.path.isdir(os.path.join(root, REPRO_DIR)):
        if init_kwargs:
            raise TypeError(
                f"{sorted(init_kwargs)} only apply when initializing; "
                f"{root} is already a repository (its stored config wins)"
            )
        from .fsio import FS

        repo = Repository(
            root, fs=FS(profile, clock, faults=faults), net_faults=net_faults
        )
    elif create:
        repo = Repository.init(
            root, profile=profile, clock=clock, faults=faults,
            net_faults=net_faults, **init_kwargs
        )
    else:
        raise FileNotFoundError(
            f"not a repro repository: {root} (pass create=True to initialize)"
        )
    return Session(
        repo, cluster=cluster, cli_startup_s=cli_startup_s,
        max_workers=max_workers, auto_repack_threshold=auto_repack_threshold,
        ingest_workers=ingest_workers, run_cache=run_cache,
        cache_env=cache_env,
    )
