"""Session: the documented entry point of the library.

``repro.open(root)`` returns a :class:`Session` — one object that drives
every execution path of the paper's workflow through declarative
:class:`~repro.core.spec.RunSpec` objects:

    import repro
    from repro import RunSpec

    s = repro.open("/path/to/project", create=True)
    s.save(message="inputs")                       # version the worktree
    s.run(cmd="python analyze.py", inputs=["in.csv"], outputs=["fig.csv"])
    s.rerun("HEAD")                                # bitwise-verified replay

    job = s.submit(RunSpec(script="job.sh", outputs=["out"]))   # one job
    ids = s.submit_many([RunSpec(script=f"j{i}.sh", outputs=[f"o{i}"])
                         for i in range(64)])      # batched: 1 CLI charge,
                                                   # 1 jobdb transaction,
                                                   # 1 conflict pass
    s.wait()
    s.finish(octopus=True)
    s.reschedule(commitish=...)                    # exact-spec resubmission

The scheduler/cluster pair is constructed lazily, so a Session used only for
``run``/``rerun`` never spins up a thread pool. The legacy free-function /
keyword surfaces (``records.run``, ``SlurmScheduler.schedule``) remain as
shims over the same spec layer.
"""
from __future__ import annotations

import os

from . import records as R
from .fsio import NULL_FS, FSProfile, SimClock
from .repo import REPRO_DIR, Repository
from .scheduler import FinishResult, ScheduleError, SlurmScheduler
from .slurm import LocalSlurmCluster, SlurmCluster
from .spec import RunSpec


class Session:
    """A repository plus (lazily) a cluster + scheduler, driven by specs."""

    def __init__(
        self,
        repo: Repository,
        cluster: SlurmCluster | None = None,
        cli_startup_s: float = 0.0,
        max_workers: int = 8,
        auto_repack_threshold: int | None | str = "auto",
        ingest_workers: int = 0,
        run_cache: bool = True,
        cache_env: dict | None = None,
    ):
        self.repo = repo
        self.cli_startup_s = cli_startup_s
        self._max_workers = max_workers
        self.ingest_workers = ingest_workers
        # §11 run cache: on by default; cache_env folds an environment
        # fingerprint into every execution key
        self.run_cache = run_cache
        self.cache_env = cache_env
        self._cluster = cluster
        self._scheduler: SlurmScheduler | None = None
        self._owns_cluster = cluster is None
        if isinstance(auto_repack_threshold, str) and auto_repack_threshold != "auto":
            raise ValueError(
                f"auto_repack_threshold must be an int, None, or 'auto'; "
                f"got {auto_repack_threshold!r}"
            )
        if auto_repack_threshold == "auto":
            # default: compact once a loose shard would start paying the
            # parallel-FS degradation penalty; harmless (never derived) on
            # profiles without one. None disables explicitly, exactly like
            # SlurmScheduler's own parameter.
            p = repo.fs.profile
            auto_repack_threshold = (
                p.degrade_threshold if p.dir_degrade > 0 else None
            )
        self.auto_repack_threshold = auto_repack_threshold

    # ------------------------------------------------------------ plumbing
    @property
    def cluster(self) -> SlurmCluster:
        if self._cluster is None:
            self._cluster = LocalSlurmCluster(
                max_workers=self._max_workers, clock=self.repo.fs.clock,
                faults=getattr(self.repo.fs, "faults", None),
            )
        return self._cluster

    @property
    def scheduler(self) -> SlurmScheduler:
        if self._scheduler is None:
            self._scheduler = SlurmScheduler(
                self.repo, self.cluster, cli_startup_s=self.cli_startup_s,
                auto_repack_threshold=self.auto_repack_threshold,
                ingest_workers=self.ingest_workers,
                run_cache=self.run_cache, cache_env=self.cache_env,
            )
        return self._scheduler

    @property
    def dsid(self) -> str:
        return self.repo.dsid

    def close(self) -> None:
        """Shut down a lazily created local cluster (no-op otherwise)."""
        if self._owns_cluster and self._cluster is not None:
            shutdown = getattr(self._cluster, "shutdown", None)
            if shutdown:
                shutdown()
            self._cluster = None
            self._scheduler = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- versioning
    def save(self, paths=None, message: str = "", **kw) -> str:
        return self.repo.save(paths=paths, message=message, **kw)

    def head(self) -> str | None:
        return self.repo.head_commit()

    def gc(self, delete_loose: bool = True, prune_cache: bool = True,
           sweep_chunks: bool = True) -> dict:
        """Compact the object store: migrate loose objects into a pack and
        drop the shard entry counts that parallel-FS metadata latency
        degrades with (DESIGN.md §8). Crash-safe — the pack is published
        before any loose file is unlinked. ``prune_cache`` (default) also
        evicts §11 run-cache rows whose recorded commit or annex objects no
        longer exist, so the cache can never serve a hit it cannot
        materialize, and ``sweep_chunks`` drops chunk-tier objects (§12) no
        manifest references — what a crashed chunked ingest or a dropped
        chunked key leaves behind. Returns repack stats (+ ``cache_evicted``,
        ``chunks_swept``)."""
        stats = dict(self.repo.objects.repack(delete_loose=delete_loose) or {})
        if sweep_chunks and self.repo.annex.chunk_aware:
            stats["chunks_swept"] = self.repo.annex.sweep_orphan_chunks()
        if prune_cache:
            from .jobdb import JobDB
            from .runcache import RunCache

            db = (
                self._scheduler.db if self._scheduler is not None
                else JobDB(self.repo.repro_dir)
            )
            stats["cache_evicted"] = len(
                RunCache(self.repo, db).evict_missing()
            )
        return stats

    # ------------------------------------------------------------ execution
    @staticmethod
    def _coerce(spec: RunSpec | None, kwargs: dict) -> RunSpec:
        if spec is not None and kwargs:
            raise TypeError("pass either a RunSpec or keyword fields, not both")
        if spec is None:
            spec = RunSpec(**kwargs)
        return spec

    def run(self, spec: RunSpec | None = None, **kwargs) -> str:
        """Execute a command spec blocking and commit outputs + record
        (``datalad run``). Accepts a :class:`RunSpec` or its fields."""
        return R.run_spec(self.repo, self._coerce(spec, kwargs))

    def rerun(self, commitish: str, report_only: bool = False) -> dict:
        """Replay a recorded commit's exact spec and hash-verify the outputs
        (``datalad rerun``)."""
        return R.rerun(self.repo, commitish, report_only=report_only)

    def spec_of(self, commitish: str) -> RunSpec:
        """The originating spec of a recorded commit."""
        return R.spec_of(self.repo, commitish)

    # ----------------------------------------------------------- scheduling
    def submit(
        self, spec: RunSpec | None = None, refresh: bool = False, **kwargs
    ) -> int:
        """Submit one script spec to the batch system (``slurm-schedule``).
        ``refresh=True`` bypasses the §11 run cache (forces execution)."""
        return self.scheduler.submit(self._coerce(spec, kwargs), refresh=refresh)

    def submit_many(
        self, specs: list[RunSpec], refresh: bool = False
    ) -> list[int]:
        """Submit a batch: one CLI-startup charge, one jobdb transaction,
        one shared conflict pass for all specs. Cache-hit specs (§11)
        short-circuit into memoized records without touching Slurm;
        ``refresh=True`` bypasses the lookup."""
        return self.scheduler.submit_many(specs, refresh=refresh)

    def finish(self, **kw) -> list[FinishResult]:
        """Commit results of finished jobs (``slurm-finish``)."""
        return self.scheduler.finish(**kw)

    def reschedule(self, commitish: str | None = None, **kw) -> list[int]:
        """Resubmit from stored specs (``slurm-reschedule``)."""
        return self.scheduler.reschedule(commitish=commitish, **kw)

    def wait(self, job_ids: list[int] | None = None, timeout: float = 300.0) -> None:
        """Block until the given (default: all) slurm jobs are terminal."""
        slurm_ids = None
        if job_ids is not None:
            jobs = {j: self.scheduler.db.get(j) for j in job_ids}
            unknown = [j for j, row in jobs.items() if row is None]
            if unknown:
                raise ScheduleError(f"unknown job(s): {unknown}")
            # terminal rows have nothing to wait on — in particular §11
            # cache hits close as 'memoized' with no slurm id at all
            open_rows = [
                row for row in jobs.values() if row["status"] == "scheduled"
            ]
            # a NULL slurm id on an OPEN row (crash between add_jobs and
            # set_slurm_ids) would block forever — fail fast like finish
            # reports "UNKNOWN"
            unsubmitted = [
                row["job_id"] for row in open_rows if row["slurm_id"] is None
            ]
            if unsubmitted:
                raise ScheduleError(
                    f"job(s) {unsubmitted} have no slurm id (submission never "
                    "completed); close them via finish(close_failed_jobs=True)"
                )
            if not open_rows:
                return
            slurm_ids = [row["slurm_id"] for row in open_rows]
        self.cluster.wait(slurm_ids, timeout=timeout)

    def status(self) -> list[dict]:
        """Open jobs with their live Slurm state (``--list-open-jobs``)."""
        return [
            {**job, "slurm_state": state}
            for job, state in self.scheduler.list_open_jobs()
        ]

    # ------------------------------------------------------------- recovery
    def recover(self, close_unsubmitted: bool = True,
                max_tmp_age_s: float | None = 3600.0) -> dict:
        """Crash recovery (DESIGN.md §10): break stale locks, sweep
        dead-owner annex tmps, replay intent journals (exactly-once finish
        and submit), close orphan rows, release orphan protection.
        Idempotent; returns a report dict."""
        from . import recovery as _recovery

        return _recovery.recover(
            self, close_unsubmitted=close_unsubmitted,
            max_tmp_age_s=max_tmp_age_s,
        )

    def verify(self, repair: bool = False) -> dict:
        """fsck: cross-check jobdb ↔ refs ↔ object store ↔ annex and report
        divergence; ``repair=True`` fixes what is safe (DESIGN.md §10)."""
        from . import recovery as _recovery

        return _recovery.verify(self, repair=repair)


def open(
    root: str,
    create: bool = False,
    profile: FSProfile = NULL_FS,
    clock: SimClock | None = None,
    cluster: SlurmCluster | None = None,
    cli_startup_s: float = 0.0,
    max_workers: int = 8,
    auto_repack_threshold: int | None | str = "auto",
    ingest_workers: int = 0,
    run_cache: bool = True,
    cache_env: dict | None = None,
    faults=None,
    **init_kwargs,
) -> Session:
    """Open (or with ``create=True``, initialize) a repository at ``root``
    and return a :class:`Session` over it — the documented entry point.
    ``faults`` attaches a :class:`~repro.core.faults.FaultPlan` to the
    session's FS and (lazily created) cluster — the fault-injection harness
    of DESIGN.md §10. ``run_cache`` toggles §11 execution memoization
    (``submit*(..., refresh=True)`` bypasses it per call); ``cache_env``
    folds an environment fingerprint into every execution key."""
    if os.path.isdir(os.path.join(root, REPRO_DIR)):
        if init_kwargs:
            raise TypeError(
                f"{sorted(init_kwargs)} only apply when initializing; "
                f"{root} is already a repository (its stored config wins)"
            )
        from .fsio import FS

        repo = Repository(root, fs=FS(profile, clock, faults=faults))
    elif create:
        repo = Repository.init(
            root, profile=profile, clock=clock, faults=faults, **init_kwargs
        )
    else:
        raise FileNotFoundError(
            f"not a repro repository: {root} (pass create=True to initialize)"
        )
    return Session(
        repo, cluster=cluster, cli_startup_s=cli_startup_s,
        max_workers=max_workers, auto_repack_threshold=auto_repack_threshold,
        ingest_workers=ingest_workers, run_cache=run_cache,
        cache_env=cache_env,
    )
