"""Declarative, content-addressed job specifications (the spec layer).

A :class:`RunSpec` is the machine-actionable description of one unit of
(re-)executable work — the single source of truth that every execution path
consumes: ``Session.run``/``records.run`` (blocking execution, paper §3),
``SlurmScheduler.submit``/``submit_many`` (scheduled execution, paper §5),
``rerun`` and ``reschedule`` (re-execution from provenance). The paper's
promise is *machine-actionable* reproducibility; embedding the spec verbatim
in every provenance record (commit ``spec`` field + the RUNCMD JSON block)
means replay deserializes the exact original object instead of reassembling
keyword arguments from free text.

Three properties make that work:

1. **Frozen.** A spec is immutable after construction; derived specs are
   made with :meth:`RunSpec.replace`.
2. **Validated at construction.** The §5.2 mandatory-output rule, the §5.4
   wildcard-output rejection, output normalization, and the intra-job
   nesting check all run in ``__post_init__`` — call sites cannot forget
   them and cannot disagree about them. (Input *existence* is resolved
   against a repository root at execution time via :meth:`missing_inputs`;
   wildcard inputs are legal and expand like ``datalad run`` globs.)
3. **Content-addressed.** :meth:`canonical_bytes` is a canonical JSON form
   (sorted keys, sorted env, no whitespace), and :attr:`spec_id` is its
   sha256 — stable across field ordering, env-dict permutations, and
   list/tuple spelling, so the same spec has the same id everywhere. The
   ``message`` label is part of the spec (and so of the id); compare with
   ``spec.replace(message=...)`` when the label should not matter.

``cmd`` and ``script`` are mutually exclusive: a *command spec* (``cmd``)
is shell-executed blocking (``run``/``rerun``); a *script spec*
(``script`` [+ ``script_args``]) is submitted to the batch system
(``submit``/``reschedule``).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from dataclasses import dataclass
from functools import cached_property

from .conflicts import (
    WildcardOutputError,
    check_intra_job,
    has_wildcard,
    normalize,
)
from .hashing import sha256_bytes

SPEC_VERSION = 1


class SpecError(ValueError):
    """A job specification is structurally invalid or cannot be executed."""


@dataclass(frozen=True)
class RunSpec:
    """One immutable, validated, content-addressed job specification."""

    cmd: str | None = None
    script: str | None = None
    script_args: str = ""
    inputs: tuple[str, ...] = ()
    outputs: tuple[str, ...] = ()
    pwd: str = "."
    alt_dir: str | None = None
    array_n: int = 1
    time_limit_s: float | None = None
    message: str = ""
    env: tuple[tuple[str, str], ...] = ()

    # ---------------------------------------------------------- validation
    def __post_init__(self) -> None:
        set_ = object.__setattr__
        if isinstance(self.inputs, str) or isinstance(self.outputs, str):
            raise SpecError(
                "inputs/outputs must be sequences of paths, not a bare string"
            )
        set_(self, "inputs", tuple(self.inputs))
        env = self.env
        if isinstance(env, dict):
            env = env.items()
        env = tuple(sorted((str(k), str(v)) for k, v in env))
        set_(self, "env", env)
        if len(dict(env)) != len(env):
            raise SpecError("duplicate keys in env")

        if (self.cmd is None) == (self.script is None):
            raise SpecError(
                "exactly one of cmd (blocking command spec) or script "
                "(batch script spec) must be set"
            )
        if self.script is not None and not self.outputs:
            raise SpecError("output specification is mandatory (paper §5.2)")
        if self.cmd is not None and self.array_n != 1:
            raise SpecError("array jobs require a script spec")
        for o in self.outputs:
            if has_wildcard(o):
                raise WildcardOutputError(o)
        normed = tuple(normalize(o) for o in self.outputs)
        check_intra_job(list(normed))
        set_(self, "outputs", normed)
        if not isinstance(self.array_n, int) or self.array_n < 1:
            raise SpecError(f"array_n must be a positive int: {self.array_n!r}")
        if self.time_limit_s is not None:
            if not self.time_limit_s > 0:
                raise SpecError(f"time_limit_s must be positive: {self.time_limit_s!r}")
            # canonical form: ints and floats must serialize identically
            set_(self, "time_limit_s", float(self.time_limit_s))
        norm_pwd = os.path.normpath(self.pwd) if self.pwd else ""
        if (
            not self.pwd
            or os.path.isabs(self.pwd)
            or norm_pwd == ".."
            or norm_pwd.startswith(".." + os.sep)
        ):
            raise SpecError(f"pwd escapes the repository: {self.pwd!r}")

    # --------------------------------------------------------- derivations
    @property
    def kind(self) -> str:
        return "cmd" if self.cmd is not None else "script"

    @property
    def record_cmd(self) -> str:
        """The command line recorded in provenance: the spec's own command
        for command specs, the submission line for script specs."""
        if self.cmd is not None:
            return self.cmd
        return f"sbatch {self.script}" + (f" {self.script_args}" if self.script_args else "")

    def title(self) -> str:
        return self.message or self.record_cmd

    def replace(self, **changes) -> "RunSpec":
        """A new validated spec with ``changes`` applied (the only way to
        'mutate' a spec)."""
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------- serialization
    def to_json(self) -> dict:
        """Plain-JSON form (lists, dict env) — embeddable in records,
        commits, and job-database rows."""
        return {
            "spec_version": SPEC_VERSION,
            "cmd": self.cmd,
            "script": self.script,
            "script_args": self.script_args,
            "inputs": list(self.inputs),
            "outputs": list(self.outputs),
            "pwd": self.pwd,
            "alt_dir": self.alt_dir,
            "array_n": self.array_n,
            "time_limit_s": self.time_limit_s,
            "message": self.message,
            "env": dict(self.env),
        }

    def canonical_bytes(self) -> bytes:
        """Canonical serialization: sorted keys, no whitespace. Two specs
        describing the same work produce identical bytes."""
        return json.dumps(self.to_json(), sort_keys=True, separators=(",", ":")).encode()

    @cached_property
    def spec_id(self) -> str:
        """Content address: sha256 of the canonical bytes."""
        return sha256_bytes(self.canonical_bytes())

    def execution_key(
        self,
        input_entries: list[tuple[str, dict]],
        env_fingerprint: str = "",
    ) -> str:
        """Content address of one *execution* of this spec: sha256 over the
        spec id, the resolved input tree (sorted ``(path, tree-entry)``
        pairs — oids/annex keys, so same paths with different content key
        differently), and an environment fingerprint. Two submissions with
        equal execution keys are guaranteed to produce the same outputs
        under the functional model, which is what licenses the §11 run
        cache to answer the second one without touching Slurm.

        The ``message`` label is part of ``spec_id`` and hence of the key —
        deliberately: a reschedule/straggler resubmit rewrites the message
        and must MISS so it really re-executes. Script *content* is keyed
        only if the script is declared as an input.
        """
        payload = {
            "spec_id": self.spec_id,
            "inputs": [
                [p, e] for p, e in sorted(input_entries, key=lambda pe: pe[0])
            ],
            "env": env_fingerprint,
        }
        return sha256_bytes(
            json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()
        )

    @classmethod
    def from_json(cls, d: dict) -> "RunSpec":
        """Reconstruct (and re-validate) a spec from its JSON form."""
        version = d.get("spec_version", SPEC_VERSION)
        if version > SPEC_VERSION:
            raise SpecError(f"spec_version {version} is newer than supported ({SPEC_VERSION})")
        return cls(
            cmd=d.get("cmd"),
            script=d.get("script"),
            script_args=d.get("script_args", ""),
            inputs=tuple(d.get("inputs", ())),
            outputs=tuple(d.get("outputs", ())),
            pwd=d.get("pwd", "."),
            alt_dir=d.get("alt_dir"),
            array_n=int(d.get("array_n", 1)),
            time_limit_s=d.get("time_limit_s"),
            message=d.get("message", ""),
            env=tuple((k, v) for k, v in d.get("env", {}).items()),
        )

    @classmethod
    def from_canonical(cls, data: bytes | str) -> "RunSpec":
        if isinstance(data, str):
            data = data.encode()
        return cls.from_json(json.loads(data))

    # ---------------------------------------------------- input resolution
    @staticmethod
    def _provided_set(provided) -> set[str]:
        return {normalize(p) for p in provided} if provided else set()

    @staticmethod
    def _is_provided(name: str, prov: set[str]) -> bool:
        """Is ``name`` one of (or nested under) the provided paths?"""
        if not prov:
            return False
        n = normalize(name)
        return n in prov or any(n.startswith(p + "/") for p in prov)

    def missing_inputs(self, root: str, provided=()) -> list[str]:
        """Non-wildcard inputs that do not exist under ``root``. Wildcard
        inputs are never 'missing' — an empty glob is legal, like
        ``datalad run``. ``provided`` lists paths produced by an upstream
        pipeline stage: they don't exist *yet* but will by the time an
        ``afterok`` dependency releases this job, so they are not missing."""
        prov = self._provided_set(provided)
        return [
            i for i in self.inputs
            if not has_wildcard(i)
            and not os.path.exists(os.path.join(root, i))
            and not self._is_provided(i, prov)
        ]

    def expand_inputs(self, root: str, provided=()) -> list[str]:
        """Resolve inputs against ``root``: wildcard patterns glob-expand to
        the (sorted) matching paths, literal paths pass through verbatim.
        Raises FileNotFoundError for a missing literal input — unless it is
        in ``provided`` (an upstream stage will create it before the job
        starts), in which case it is skipped: there is nothing to stage yet."""
        prov = self._provided_set(provided)
        out: list[str] = []
        for i in self.inputs:
            if has_wildcard(i):
                matches = sorted(
                    glob.glob(os.path.join(root, i), recursive=True)
                )
                out.extend(os.path.relpath(m, root) for m in matches)
            elif os.path.exists(os.path.join(root, i)):
                out.append(i)
            elif self._is_provided(i, prov):
                continue
            else:
                raise FileNotFoundError(f"input does not exist: {i}")
        return out
