"""Content-addressed object store: blobs, trees, commits.

The design mirrors git's object model (the paper's foundation): an object is
``<kind> <len>\\0<payload>`` hashed with SHA-256, stored zlib-compressed under
``objects/<2-hex>/<62-hex>``. Trees and commits are canonical JSON so they can
be introspected without a porcelain layer.

Tree entries (one dict per name):
    {"t": "blob", "oid": ...}                   # regular versioned file
    {"t": "tree", "oid": ...}                   # subdirectory
    {"t": "annex", "key": "SHA256-s...--..."}   # annexed large/binary file

Commits:
    {"tree": oid, "parents": [oid...], "author": str,
     "timestamp": float, "message": str}

Octopus merges are just commits with len(parents) > 2, exactly like git.

Caching (DESIGN.md §4): content-addressed objects are immutable, so the store
keeps (a) a *known-oid set* — once an oid has been written or observed on
disk, later ``put``/``has`` calls for it are answered in memory with no
``exists`` probe, and (b) LRU caches of tree/commit *payload bytes*, so
walking the same (sub)tree twice never re-reads, decompresses, or charges
filesystem ops. Hits are re-parsed from the cached bytes, so every caller
gets a private dict it may mutate freely (the pre-cache contract).
``disable_caches()`` restores the seed-era always-probe behavior for
benchmarking the pre-incremental implementation.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from collections import OrderedDict

from .fsio import FS
from .hashing import sha256_bytes

KINDS = ("blob", "tree", "commit")

DEFAULT_TREE_CACHE = 8192
DEFAULT_COMMIT_CACHE = 8192
KNOWN_OID_CAP = 1 << 20  # bound the probe-skip set for long-lived processes


def canonical_json(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


class ObjectStore:
    def __init__(
        self,
        root: str,
        fs: FS,
        tree_cache_size: int = DEFAULT_TREE_CACHE,
        commit_cache_size: int = DEFAULT_COMMIT_CACHE,
    ):
        self.root = root
        self.fs = fs
        self._lock = threading.Lock()
        self._caches_enabled = True
        self._known: set[str] = set()
        # oid -> canonical payload bytes; parsed per hit so returned dicts
        # are never shared (callers may mutate them, as before caching)
        self._tree_cache: OrderedDict[str, bytes] = OrderedDict()
        self._commit_cache: OrderedDict[str, bytes] = OrderedDict()
        self._tree_cache_size = tree_cache_size
        self._commit_cache_size = commit_cache_size

    def disable_caches(self) -> None:
        """Revert to uncached (seed-era) behavior: every ``put`` probes the
        filesystem, every ``get_tree``/``get_commit`` re-reads and re-parses.
        Used by benchmarks to measure the pre-incremental implementation."""
        with self._lock:
            self._caches_enabled = False
            self._known.clear()
            self._tree_cache.clear()
            self._commit_cache.clear()

    def _path(self, oid: str) -> str:
        return os.path.join(self.root, oid[:2], oid[2:])

    # -- cache plumbing --------------------------------------------------
    def _mark_known(self, oid: str) -> None:
        if self._caches_enabled:
            with self._lock:
                if len(self._known) >= KNOWN_OID_CAP:
                    # reset rather than evict: the set only skips probes, so
                    # dropping it costs one exists per oid, never correctness
                    self._known.clear()
                self._known.add(oid)

    def _cache_get(self, cache: OrderedDict, oid: str) -> bytes | None:
        if not self._caches_enabled:
            return None
        with self._lock:
            payload = cache.get(oid)
            if payload is not None:
                cache.move_to_end(oid)
            return payload

    def _cache_put(self, cache: OrderedDict, size: int, oid: str, payload: bytes) -> None:
        if not self._caches_enabled:
            return
        with self._lock:
            cache[oid] = payload
            cache.move_to_end(oid)
            while len(cache) > size:
                cache.popitem(last=False)

    # -- core ------------------------------------------------------------
    def put(self, kind: str, payload: bytes) -> str:
        assert kind in KINDS, kind
        framed = kind.encode() + b" " + str(len(payload)).encode() + b"\0" + payload
        oid = sha256_bytes(framed)
        if self._caches_enabled:
            with self._lock:
                if oid in self._known:
                    return oid
        path = self._path(oid)
        if not self.fs.exists(path):
            self.fs.write_bytes(path, zlib.compress(framed, 1))
        self._mark_known(oid)
        return oid

    def get(self, oid: str) -> tuple[str, bytes]:
        framed = zlib.decompress(self.fs.read_bytes(self._path(oid)))
        header, _, payload = framed.partition(b"\0")
        kind, _, length = header.decode().partition(" ")
        if int(length) != len(payload):
            raise IOError(f"corrupt object {oid}")
        self._mark_known(oid)
        return kind, payload

    def has(self, oid: str) -> bool:
        if self._caches_enabled:
            with self._lock:
                if oid in self._known:
                    return True
        if self.fs.exists(self._path(oid)):
            self._mark_known(oid)
            return True
        return False

    # -- typed helpers ---------------------------------------------------
    def put_blob(self, data: bytes) -> str:
        return self.put("blob", data)

    def put_tree(self, entries: dict) -> str:
        payload = canonical_json(entries)
        oid = self.put("tree", payload)
        self._cache_put(self._tree_cache, self._tree_cache_size, oid, payload)
        return oid

    def put_commit(self, commit: dict) -> str:
        payload = canonical_json(commit)
        oid = self.put("commit", payload)
        self._cache_put(self._commit_cache, self._commit_cache_size, oid, payload)
        return oid

    def get_blob(self, oid: str) -> bytes:
        kind, payload = self.get(oid)
        if kind != "blob":
            raise TypeError(f"{oid} is a {kind}, not a blob")
        return payload

    def get_tree(self, oid: str) -> dict:
        cached = self._cache_get(self._tree_cache, oid)
        if cached is not None:
            return json.loads(cached)
        kind, payload = self.get(oid)
        if kind != "tree":
            raise TypeError(f"{oid} is a {kind}, not a tree")
        self._cache_put(self._tree_cache, self._tree_cache_size, oid, payload)
        return json.loads(payload)

    def get_commit(self, oid: str) -> dict:
        cached = self._cache_get(self._commit_cache, oid)
        if cached is not None:
            return json.loads(cached)
        kind, payload = self.get(oid)
        if kind != "commit":
            raise TypeError(f"{oid} is a {kind}, not a commit")
        self._cache_put(self._commit_cache, self._commit_cache_size, oid, payload)
        return json.loads(payload)
