"""Content-addressed object store: blobs, trees, commits.

The design mirrors git's object model (the paper's foundation): an object is
``<kind> <len>\\0<payload>`` hashed with SHA-256, stored zlib-compressed under
``objects/<2-hex>/<62-hex>``. Trees and commits are canonical JSON so they can
be introspected without a porcelain layer.

Tree entries (one dict per name):
    {"t": "blob", "oid": ...}                   # regular versioned file
    {"t": "tree", "oid": ...}                   # subdirectory
    {"t": "annex", "key": "SHA256-s...--..."}   # annexed large/binary file

Commits:
    {"tree": oid, "parents": [oid...], "author": str,
     "timestamp": float, "message": str}

Octopus merges are just commits with len(parents) > 2, exactly like git.
"""
from __future__ import annotations

import json
import os
import zlib

from .fsio import FS
from .hashing import sha256_bytes

KINDS = ("blob", "tree", "commit")


def canonical_json(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


class ObjectStore:
    def __init__(self, root: str, fs: FS):
        self.root = root
        self.fs = fs

    def _path(self, oid: str) -> str:
        return os.path.join(self.root, oid[:2], oid[2:])

    def put(self, kind: str, payload: bytes) -> str:
        assert kind in KINDS, kind
        framed = kind.encode() + b" " + str(len(payload)).encode() + b"\0" + payload
        oid = sha256_bytes(framed)
        path = self._path(oid)
        if not self.fs.exists(path):
            self.fs.write_bytes(path, zlib.compress(framed, 1))
        return oid

    def get(self, oid: str) -> tuple[str, bytes]:
        framed = zlib.decompress(self.fs.read_bytes(self._path(oid)))
        header, _, payload = framed.partition(b"\0")
        kind, _, length = header.decode().partition(" ")
        if int(length) != len(payload):
            raise IOError(f"corrupt object {oid}")
        return kind, payload

    def has(self, oid: str) -> bool:
        return self.fs.exists(self._path(oid))

    # -- typed helpers ---------------------------------------------------
    def put_blob(self, data: bytes) -> str:
        return self.put("blob", data)

    def put_tree(self, entries: dict) -> str:
        return self.put("tree", canonical_json(entries))

    def put_commit(self, commit: dict) -> str:
        return self.put("commit", canonical_json(commit))

    def get_blob(self, oid: str) -> bytes:
        kind, payload = self.get(oid)
        if kind != "blob":
            raise TypeError(f"{oid} is a {kind}, not a blob")
        return payload

    def get_tree(self, oid: str) -> dict:
        kind, payload = self.get(oid)
        if kind != "tree":
            raise TypeError(f"{oid} is a {kind}, not a tree")
        return json.loads(payload)

    def get_commit(self, oid: str) -> dict:
        kind, payload = self.get(oid)
        if kind != "commit":
            raise TypeError(f"{oid} is a {kind}, not a commit")
        return json.loads(payload)
