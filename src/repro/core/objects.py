"""Content-addressed object store: blobs, trees, commits.

The design mirrors git's object model (the paper's foundation): an object is
``<kind> <len>\\0<payload>`` hashed with SHA-256, stored zlib-compressed under
``objects/<2-hex>/<62-hex>``. Trees and commits are canonical JSON so they can
be introspected without a porcelain layer.

Tree entries (one dict per name):
    {"t": "blob", "oid": ...}                   # regular versioned file
    {"t": "tree", "oid": ...}                   # subdirectory
    {"t": "annex", "key": "SHA256-s...--..."}   # annexed large/binary file

Commits:
    {"tree": oid, "parents": [oid...], "author": str,
     "timestamp": float, "message": str}

Octopus merges are just commits with len(parents) > 2, exactly like git.

Caching (DESIGN.md §4): content-addressed objects are immutable, so the store
keeps (a) a *known-oid set* — once an oid has been written or observed on
disk, later ``put``/``has`` calls for it are answered in memory with no
``exists`` probe, and (b) LRU caches of tree/commit *payload bytes*, so
walking the same (sub)tree twice never re-reads, decompresses, or charges
filesystem ops. Hits are re-parsed from the cached bytes, so every caller
gets a private dict it may mutate freely (the pre-cache contract).
``disable_caches()`` restores the seed-era always-probe behavior for
benchmarking the pre-incremental implementation.

Storage has two tiers (DESIGN.md §8): *loose* files under
``objects/<2-hex>/<62-hex>`` (where every ``put`` lands) and *packs* under
``objects/pack/`` (where ``repack()`` consolidates them so shard directory
entry counts — the parallel-FS degradation driver — stay bounded). The read
path consults the in-memory pack index first; packs are storage, not a
cache, so ``disable_caches()`` does not bypass them.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from collections import OrderedDict

from .fsio import FS
from .hashing import sha256_bytes
from .packs import PACK_DIR, PackManager
from .recovery import FileLock

KINDS = ("blob", "tree", "commit")

DEFAULT_TREE_CACHE = 8192
DEFAULT_COMMIT_CACHE = 8192
DEFAULT_BLOB_CACHE_BYTES = 32 << 20  # bound by payload bytes, not entry count
KNOWN_OID_CAP = 1 << 20  # bound the probe-skip set for long-lived processes


def canonical_json(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


class ObjectStore:
    def __init__(
        self,
        root: str,
        fs: FS,
        tree_cache_size: int = DEFAULT_TREE_CACHE,
        commit_cache_size: int = DEFAULT_COMMIT_CACHE,
        blob_cache_bytes: int = DEFAULT_BLOB_CACHE_BYTES,
    ):
        self.root = root
        self.fs = fs
        self.packs = PackManager(os.path.join(root, PACK_DIR))
        self._lock = threading.Lock()
        self._repack_lock = threading.Lock()  # one compaction at a time
        # cross-process/crash-boundary counterpart of _repack_lock: a §10
        # FileLock beside the store; a crash mid-repack leaves it behind and
        # the next acquire detects the dead owner and breaks it
        self._repack_lock_path = os.path.join(
            os.path.dirname(root), "locks", "repack.lock"
        )
        self._caches_enabled = True
        self._known: set[str] = set()
        # oid -> canonical payload bytes; parsed per hit so returned dicts
        # are never shared (callers may mutate them, as before caching)
        self._tree_cache: OrderedDict[str, bytes] = OrderedDict()
        self._commit_cache: OrderedDict[str, bytes] = OrderedDict()
        self._tree_cache_size = tree_cache_size
        self._commit_cache_size = commit_cache_size
        # oid -> blob payload; bytes are immutable so hits are shared safely
        self._blob_cache: OrderedDict[str, bytes] = OrderedDict()
        self._blob_cache_bytes = blob_cache_bytes
        self._blob_cache_used = 0

    def disable_caches(self) -> None:
        """Revert to uncached (seed-era) behavior: every ``put`` probes the
        filesystem, every ``get_tree``/``get_commit``/``get_blob`` re-reads
        and re-parses. Used by benchmarks to measure the pre-incremental
        implementation. Packs stay active — they are storage, not a cache."""
        with self._lock:
            self._caches_enabled = False
            self._known.clear()
            self._tree_cache.clear()
            self._commit_cache.clear()
            self._blob_cache.clear()
            self._blob_cache_used = 0

    def _path(self, oid: str) -> str:
        return os.path.join(self.root, oid[:2], oid[2:])

    # -- cache plumbing --------------------------------------------------
    def _mark_known(self, oid: str) -> None:
        if self._caches_enabled:
            with self._lock:
                if len(self._known) >= KNOWN_OID_CAP:
                    # reset rather than evict: the set only skips probes, so
                    # dropping it costs one exists per oid, never correctness
                    self._known.clear()
                self._known.add(oid)

    def _cache_get(self, cache: OrderedDict, oid: str) -> bytes | None:
        if not self._caches_enabled:
            return None
        with self._lock:
            payload = cache.get(oid)
            if payload is not None:
                cache.move_to_end(oid)
            return payload

    def _cache_put(self, cache: OrderedDict, size: int, oid: str, payload: bytes) -> None:
        if not self._caches_enabled:
            return
        with self._lock:
            cache[oid] = payload
            cache.move_to_end(oid)
            while len(cache) > size:
                cache.popitem(last=False)

    # -- core ------------------------------------------------------------
    @staticmethod
    def oid_for(kind: str, payload: bytes) -> str:
        """The oid ``put(kind, payload)`` would assign, without writing —
        read-only comparisons (rerun's bitwise verification) use this."""
        assert kind in KINDS, kind
        framed = kind.encode() + b" " + str(len(payload)).encode() + b"\0" + payload
        return sha256_bytes(framed)

    def put(self, kind: str, payload: bytes) -> str:
        assert kind in KINDS, kind
        framed = kind.encode() + b" " + str(len(payload)).encode() + b"\0" + payload
        oid = sha256_bytes(framed)
        if self._caches_enabled:
            with self._lock:
                if oid in self._known:
                    return oid
        if self.packs.has(oid, self.fs):
            # already packed: writing a loose duplicate would re-grow the
            # shard pressure repack just removed
            self._mark_known(oid)
            return oid
        path = self._path(oid)
        if not self.fs.exists(path):
            self.fs.write_bytes(path, zlib.compress(framed, 1))
        self._mark_known(oid)
        return oid

    def _parse_frame(self, compressed: bytes, oid: str) -> tuple[str, bytes]:
        framed = zlib.decompress(compressed)
        header, _, payload = framed.partition(b"\0")
        kind, _, length = header.decode().partition(" ")
        if int(length) != len(payload):
            raise IOError(f"corrupt object {oid}")
        return kind, payload

    def _read_compressed(self, oid: str) -> bytes:
        """One object's compressed frame from either tier — the in-memory
        pack index answers first (a loose duplicate from a crashed repack is
        dead weight for the next repack to sweep). A reader racing another
        process's repack — loose file unlinked, or an indexed pack
        consolidated away — force-reloads the index and retries both tiers
        before reporting the object missing."""
        try:
            if self.packs.has(oid, self.fs):
                return self.packs.read(oid, self.fs)
            return self.fs.read_bytes(self._path(oid))
        except FileNotFoundError:
            self.packs.load(self.fs, force=True)
            try:
                return self.packs.read(oid, self.fs)
            except KeyError:
                pass
            try:
                return self.fs.read_bytes(self._path(oid))
            except FileNotFoundError:
                raise FileNotFoundError(
                    f"object {oid} is neither loose nor packed"
                ) from None

    def get(self, oid: str) -> tuple[str, bytes]:
        kind, payload = self._parse_frame(self._read_compressed(oid), oid)
        self._mark_known(oid)
        return kind, payload

    def has(self, oid: str) -> bool:
        """Note: a miss is answered from the current pack index + loose
        probe without a forced index reload (that would charge a rescan on
        every legitimate miss), so another process's concurrent repack can
        make ``has`` transiently report False. The paths where that matters
        self-heal: ``get`` retries through a reloaded index, and
        ``find_prefix`` (hence ``resolve``) reloads before concluding
        nothing matches; a stale-miss ``put`` merely re-writes a loose
        duplicate the next repack sweeps."""
        if self._caches_enabled:
            with self._lock:
                if oid in self._known:
                    return True
        if self.packs.has(oid, self.fs) or self.fs.exists(self._path(oid)):
            self._mark_known(oid)
            return True
        return False

    def find_prefix(self, prefix: str) -> list[str]:
        """Every stored oid starting with ``prefix`` — packed (in-memory
        index) and loose (one charged shard listdir). Needs at least the
        2-hex shard to be determined. An empty result retries once behind a
        did-the-pack-dir-change check (one charged stat), so resolution
        survives another process's repack moving the object out of the
        loose tier without a full rescan on every genuinely-absent probe."""
        if len(prefix) < 2:
            raise ValueError(f"oid prefix too short: {prefix!r}")
        matches = self._find_prefix_once(prefix)
        if not matches and self.packs.maybe_reload(self.fs):
            matches = self._find_prefix_once(prefix)
        return matches

    def _find_prefix_once(self, prefix: str) -> list[str]:
        matches = set(self.packs.oids_with_prefix(prefix, self.fs))
        shard = os.path.join(self.root, prefix[:2])
        if self.fs.isdir(shard):
            for f in self.fs.listdir(shard):
                if (prefix[:2] + f).startswith(prefix):
                    matches.add(prefix[:2] + f)
        return sorted(matches)

    # -- typed helpers ---------------------------------------------------
    def _blob_cache_put(self, oid: str, data: bytes) -> None:
        if not self._caches_enabled or len(data) > self._blob_cache_bytes:
            return
        with self._lock:
            old = self._blob_cache.pop(oid, None)
            if old is not None:
                self._blob_cache_used -= len(old)
            self._blob_cache[oid] = data
            self._blob_cache_used += len(data)
            while self._blob_cache_used > self._blob_cache_bytes:
                _, evicted = self._blob_cache.popitem(last=False)
                self._blob_cache_used -= len(evicted)

    def put_blob(self, data: bytes) -> str:
        # prime the read path symmetrically with put_tree/put_commit: a
        # checkout right after a save must not re-read what it just wrote
        oid = self.put("blob", data)
        self._blob_cache_put(oid, data)
        return oid

    def put_tree(self, entries: dict) -> str:
        payload = canonical_json(entries)
        oid = self.put("tree", payload)
        self._cache_put(self._tree_cache, self._tree_cache_size, oid, payload)
        return oid

    def put_commit(self, commit: dict) -> str:
        payload = canonical_json(commit)
        oid = self.put("commit", payload)
        self._cache_put(self._commit_cache, self._commit_cache_size, oid, payload)
        return oid

    def put_commits_packed(self, commits: list[dict]) -> list[str]:
        """Write a batch of commit objects as ONE pack instead of N loose
        files (§11 memoized publish): N loose commits cost N exists-probes
        + N small writes against a degradable shard; one pack costs one
        data write + one index publish regardless of N, and adds zero
        loose-shard pressure. Objects already stored are skipped. Returns
        the oids in input order."""
        oids: list[str] = []
        frames: list[tuple[str, bytes]] = []
        seen: set[str] = set()
        for commit in commits:
            payload = canonical_json(commit)
            framed = b"commit " + str(len(payload)).encode() + b"\0" + payload
            oid = sha256_bytes(framed)
            oids.append(oid)
            if oid in seen:
                continue
            seen.add(oid)
            # presence check stays UNCHARGED (in-memory known-set + pack
            # index only, no loose-shard probe): fresh commit oids are
            # timestamp-unique so a probe is a guaranteed-miss metadata op
            # per commit — the very cost this batch exists to avoid. The
            # rare loose duplicate this can re-pack is harmless: the index
            # tolerates it and the next repack sweeps the loose copy.
            with self._lock:
                known = self._caches_enabled and oid in self._known
            if not known and not self.packs.has(oid, self.fs):
                frames.append((oid, zlib.compress(framed, 1)))
        if frames:
            self.packs.add_pack(iter(frames), self.fs)
            for oid, _ in frames:
                self._mark_known(oid)
        return oids

    def get_blob(self, oid: str) -> bytes:
        if self._caches_enabled:
            with self._lock:
                cached = self._blob_cache.get(oid)
                if cached is not None:
                    self._blob_cache.move_to_end(oid)
                    return cached  # bytes are immutable: sharing is safe
        kind, payload = self.get(oid)
        if kind != "blob":
            raise TypeError(f"{oid} is a {kind}, not a blob")
        self._blob_cache_put(oid, payload)
        return payload

    def get_tree(self, oid: str) -> dict:
        cached = self._cache_get(self._tree_cache, oid)
        if cached is not None:
            return json.loads(cached)
        kind, payload = self.get(oid)
        if kind != "tree":
            raise TypeError(f"{oid} is a {kind}, not a tree")
        self._cache_put(self._tree_cache, self._tree_cache_size, oid, payload)
        return json.loads(payload)

    def get_commit(self, oid: str) -> dict:
        cached = self._cache_get(self._commit_cache, oid)
        if cached is not None:
            return json.loads(cached)
        kind, payload = self.get(oid)
        if kind != "commit":
            raise TypeError(f"{oid} is a {kind}, not a commit")
        self._cache_put(self._commit_cache, self._commit_cache_size, oid, payload)
        return json.loads(payload)

    # -- compaction (DESIGN.md §8) ---------------------------------------
    def _shard_dirs(self) -> list[str]:
        """All 256 possible shard paths — including shards that exist only
        as modeled entry counts (benchmark-seeded footprints)."""
        return [os.path.join(self.root, f"{i:02x}") for i in range(256)]

    def loose_pressure(self) -> int:
        """Max modeled entry count over the 256 loose shards (free
        bookkeeping reads, O(shards) regardless of how many directories
        the FS has ever tracked — drives the auto-repack trigger)."""
        return max(self.fs.dir_entry_count(d) for d in self._shard_dirs())

    def repack(self, delete_loose: bool = True,
               max_packs: int | None = 48) -> dict:
        """Migrate every loose object into one new pack and unlink the loose
        files, dropping shard entry counts back below the parallel-FS
        ``degrade_threshold``.

        Crash-safe ordering: the pack data and its index are written and
        published (atomic rename) BEFORE any loose file is unlinked, so a
        crash at any point leaves duplicates, never missing objects
        (``delete_loose=False`` stops after publishing — the post-crash
        state, used by equivalence tests). Also reconciles benchmark-seeded
        phantom shard entries (charged as if really unlinked; see
        ``FS.purge_phantom_entries``).

        Once ``objects/pack/`` holds ``max_packs`` packs, they are folded
        into the new pack and their files removed (index before data, after
        the new pack is live) — so the pack directory's own entry count is
        bounded at ~``2 x max_packs + 2`` forever and never re-crosses the
        degradation threshold the packs exist to avoid (``max_packs=None``
        disables consolidation). One compaction runs at a time
        (``_repack_lock`` for threads, a crash-safe :class:`FileLock` for
        processes — a stale lock from a crashed compactor is detected and
        broken, so a crash never disables compaction permanently); readers
        racing the unlink storm retry through the pack index (see ``get``).
        Returns stats."""
        with self._repack_lock:
            with FileLock(self.fs, self._repack_lock_path):
                return self._repack_locked(delete_loose, max_packs)

    def _repack_locked(self, delete_loose: bool, max_packs: int | None) -> dict:
        fs = self.fs
        # crash leftovers (unindexed data, stray tmps) count against the
        # pack dir's entry bound but serve nothing: sweep them first
        swept = self.packs.sweep_garbage(fs)
        to_pack: list[tuple[str, str]] = []  # (oid, loose path)
        loose_paths: list[str] = []
        real_shards = (
            set(fs.listdir(self.root)) if os.path.isdir(self.root) else set()
        )
        for shard in self._shard_dirs():
            if os.path.basename(shard) not in real_shards:
                continue
            for name in fs.listdir(shard):
                oid = os.path.basename(shard) + name
                path = os.path.join(shard, name)
                if not self.packs.has(oid, fs):  # else: prior-crash duplicate
                    to_pack.append((oid, path))
                loose_paths.append(path)
        consolidated: list[str] = []
        if max_packs is not None:
            ids = self.packs.pack_ids(fs)
            if len(ids) >= max_packs:
                # geometric-ish fold: rewrite only the smaller half each
                # cycle (plus whatever more the count bound needs), so
                # lifetime pack I/O stays ~O(N log N) — big, old packs are
                # not re-copied on every 48th repack
                n_fold = max(len(ids) + 1 - max_packs, (len(ids) + 1) // 2)

                def size_of(pid: str) -> int:
                    try:
                        return self.packs.pack_data_size(pid, fs)
                    except OSError:
                        return 0  # raced a foreign drop: fold the ghost away
                consolidated = sorted(ids, key=size_of)[:n_fold]

        def frames():
            # lazily: one loose file / one old pack resident at a time
            for oid, path in to_pack:
                yield oid, fs.read_bytes(path)
            for pid in consolidated:
                yield from self.packs.read_pack_objects(pid, fs)

        fs.crash_point("repack:planned")
        pack_id = None
        if to_pack or consolidated:
            pack_id = self.packs.add_pack(frames(), fs)
        # the pack (and index) is published: from here on every object is
        # served from it, and losing the loose/old-pack copies can no
        # longer lose data
        fs.crash_point("repack:pack-published")
        unlinked = phantoms = 0
        if delete_loose:
            for path in loose_paths:
                fs.unlink(path)
                unlinked += 1
                if unlinked == 1:
                    # §10: the pack is live, the loose copies half-gone
                    fs.crash_point("repack:mid-unlink")
            for shard in self._shard_dirs():
                phantoms += fs.purge_phantom_entries(shard)
            for pid in consolidated:
                if pid != pack_id:  # identical content re-packed in place
                    self.packs.drop_pack_files(pid, fs)
        return {
            "pack_id": pack_id,
            "objects_packed": len(to_pack),
            "packs_consolidated": len(consolidated),
            "garbage_swept": swept,
            "loose_unlinked": unlinked,
            "phantom_entries_purged": phantoms,
            "packed_total": self.packs.n_packed(fs),
        }
