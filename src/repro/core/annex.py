"""Annex layer: large-file content kept outside the object store.

Mirrors git-annex as the paper uses it (§2.3): a versioned *pointer* travels
with the tree while the content lives in one or more key/value stores that can
hold different subsets of keys. After ``clone`` the annexed files are *known*
but not *present* — ``annex_get`` fetches them from any store that has them,
``annex_drop`` removes the local copy while refusing to destroy the last one
(numcopies protection, unless forced).

Pointer files are what a checkout writes when content is absent:
    #%REPRO-ANNEX%# SHA256-s<size>--<hex>\n

Data plane (DESIGN.md §9)
-------------------------
``ingest_file`` is the bytes-heavy write path: it hashes the source *while*
writing the annex object through ``FS`` — one charged read pass + one charged
write pass instead of the hash-then-copy two-read protocol — into a unique
tmp name that is atomically renamed onto the key path once the hash (hence
the key) is known. The rename is idempotent on collision, so two finishers
ingesting identical content concurrently both succeed and exactly one object
remains (the TOCTOU fix ``put_bytes``/``put_file`` share via ``_commit``).

Every store keeps a *known-key set* mirroring the object store's known-oid
set: once a key has been written or observed present, later ``put``/``has``
calls are answered in memory with no ``exists`` probe against a possibly
degraded shard directory, and re-ingest of duplicate content short-circuits
before moving bytes. ``drop`` discards from the set; a *foreign* process
dropping content this store has observed can make non-``fresh`` probes
stale, which is why numcopies-critical checks pass ``fresh=True``.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
import uuid

from .faults import is_crash, owner_is_dead
from .fsio import FS
from .hashing import make_annex_key, parse_annex_key, verify_annex_key

POINTER_PREFIX = b"#%REPRO-ANNEX%#"
_POINTER_MAX = 256
KNOWN_KEY_CAP = 1 << 20  # bound the probe-skip set for long-lived processes


def make_pointer(key: str) -> bytes:
    parse_annex_key(key)  # validate
    return POINTER_PREFIX + b" " + key.encode() + b"\n"


def parse_pointer(data: bytes) -> str | None:
    """Return the annex key if ``data`` is a pointer file, else None."""
    if len(data) > _POINTER_MAX or not data.startswith(POINTER_PREFIX):
        return None
    try:
        return data[len(POINTER_PREFIX):].strip().decode()
    except UnicodeDecodeError:
        return None


class AnnexStore:
    """One key/value store (local annex dir, 'S3 bucket', second-tier FS...).

    All stores share this implementation but may live on filesystems with
    different :class:`~repro.core.fsio.FSProfile` costs — that is exactly the
    paper's second-tier-storage scenario (§2.6).
    """

    def __init__(self, root: str, fs: FS, name: str = "local",
                 sweep_on_open: bool = True):
        self.root = root
        self.fs = fs
        self.name = name
        self._known_lock = threading.Lock()
        self._known: set[str] = set()
        if sweep_on_open and os.path.isdir(root):
            # self-heal: an interrupted ingest leaves tmp-* files forever;
            # opening the store reclaims the ones whose writer is provably
            # dead (pid/incarnation-token guard, age fallback) — DESIGN §10
            self.sweep_stale_tmps()

    def _path(self, key: str) -> str:
        _, hx = parse_annex_key(key)
        return os.path.join(self.root, hx[:3], key)

    # -- known-key set --------------------------------------------------
    def _mark_known(self, key: str) -> None:
        with self._known_lock:
            if len(self._known) >= KNOWN_KEY_CAP:
                # reset rather than evict: the set only skips probes, so
                # dropping it costs one exists per key, never correctness
                self._known.clear()
            self._known.add(key)

    def _is_known(self, key: str) -> bool:
        with self._known_lock:
            return key in self._known

    def has(self, key: str, fresh: bool = False) -> bool:
        """Presence probe. ``fresh=True`` bypasses the known-key set and
        asks the filesystem — required wherever a stale positive would be
        dangerous (numcopies checks before a drop)."""
        if not fresh and self._is_known(key):
            return True
        if self.fs.exists(self._path(key)):
            self._mark_known(key)
            return True
        return False

    def has_many(self, keys, fresh: bool = False) -> set[str]:
        """Presence of a batch of keys by per-key probes (known-key set
        first), NOT a ``keys()`` directory sweep — probing a handful of
        keys must not charge a listdir of every shard."""
        present = set()
        for key in keys:
            if self.has(key, fresh=fresh):
                present.add(key)
        return present

    # -- writes ---------------------------------------------------------
    def _tmp_path(self) -> str:
        # owner-stamped (pid + FS incarnation token): the crash sweep can
        # prove the writer is dead instead of guessing by age alone
        token = getattr(self.fs, "token", None) or "0"
        return os.path.join(
            self.root, f"tmp-{os.getpid()}-{token}-{uuid.uuid4().hex[:12]}"
        )

    @staticmethod
    def _tmp_owner(name: str) -> tuple[int | None, str | None]:
        """(pid, token) from a tmp name; (None, None) for legacy
        ``tmp-<hex>`` names (age-guard only)."""
        parts = name.split("-")
        if len(parts) >= 4 and parts[1].isdigit():
            return int(parts[1]), parts[2]
        return None, None

    def _stale_tmps(self, max_age_s: float | None) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in self.fs.listdir(self.root):
            if not name.startswith("tmp-"):
                continue
            path = os.path.join(self.root, name)
            pid, token = self._tmp_owner(name)
            stale = pid is not None and owner_is_dead(pid, token)
            if not stale and max_age_s is not None:
                try:
                    stale = (time.time() - os.stat(path).st_mtime) > max_age_s
                except OSError:
                    continue  # swept by a racing opener
            if not stale and max_age_s is None and pid is None:
                stale = True  # forced sweep: legacy names have no owner proof
            if stale:
                out.append(path)
        return out

    def count_stale_tmps(self, max_age_s: float | None = 3600.0) -> int:
        """Report-only probe for verify(); charges the same listdir."""
        return len(self._stale_tmps(max_age_s))

    def sweep_stale_tmps(self, max_age_s: float | None = 3600.0) -> int:
        """Unlink leaked ingest tmp files whose writer is provably dead
        (dead pid / dead incarnation token) or whose mtime exceeds
        ``max_age_s`` (``None`` = no age sweeping: owner-proof only, except
        unprovable legacy names which a forced ``None`` sweep does take).
        Every unlink is charged through the FS cost model. Returns the
        count swept."""
        swept = 0
        for path in self._stale_tmps(max_age_s):
            try:
                self.fs.unlink(path)
                swept += 1
            except OSError:
                pass  # a racing sweeper got it first
        return swept

    def _commit(self, tmp: str, key: str) -> None:
        """Atomically publish a fully written tmp file as ``key``.
        ``os.replace`` semantics make the collision case (another finisher
        published the same content first) idempotent: last writer wins with
        byte-identical data, no window where the key path is partial."""
        self.fs.rename(tmp, self._path(key))
        self._mark_known(key)

    def put_bytes(self, key: str, data: bytes) -> None:
        if not verify_annex_key(key, data):
            raise ValueError(f"content does not match key {key}")
        if self.has(key):
            return
        tmp = self._tmp_path()
        try:
            self.fs.write_bytes(tmp, data)
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise  # a dead process runs no cleanup: the tmp leaks
            self.fs.unlink(tmp)
            raise

    def _hash_while_write(self, src: str, chunk_size: int) -> tuple[str, str, int]:
        """The single-pass pump shared by ``put_file``/``ingest_file``:
        stream ``src`` through a sha256 into a fresh tmp file — one charged
        read + one charged write, both held open as §9 stream sessions so
        concurrent ingests contend honestly. Returns (tmp path, hex digest,
        size); the tmp is unlinked on any failure."""
        h = hashlib.sha256()
        tmp = self._tmp_path()
        try:
            with self.fs.open_read(src, chunk_size) as chunks:

                def hashing():
                    for c in chunks:
                        h.update(c)
                        yield c

                size = self.fs.write_chunks(tmp, hashing())
        except BaseException as e:
            if is_crash(e):
                raise  # a dead process runs no cleanup: the tmp leaks
            self.fs.unlink(tmp)
            raise
        return tmp, h.hexdigest(), size

    def put_file(self, key: str, src: str) -> None:
        """Copy a file in as ``key``, hashing while copying (single pass)
        and verifying the content actually matches the key before the tmp
        is published — a corrupted source never lands on the key path."""
        if self.has(key):
            return
        tmp, hx, size = self._hash_while_write(src, 1 << 20)
        try:
            if make_annex_key(hx, size) != key:
                raise IOError(f"content of {src} does not match key {key}")
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise

    def ingest_file(self, src: str, chunk_size: int = 1 << 20) -> str:
        """Single-pass ingest: hash ``src`` while writing the annex object.
        The object is written to a tmp name (the key isn't known until the
        hash is) and renamed onto the key path; duplicate content (key
        already known or present) discards the tmp instead, leaving exactly
        one object. Returns the key."""
        tmp, hx, size = self._hash_while_write(src, chunk_size)
        key = make_annex_key(hx, size)
        try:
            if self.has(key):
                # dedup short-circuit: identical content already ingested
                self.fs.unlink(tmp)
                return key
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise
        return key

    # -- reads / deletion ----------------------------------------------
    def read(self, key: str) -> bytes:
        data = self.fs.read_bytes(self._path(key))
        if not verify_annex_key(key, data):
            raise IOError(f"annex corruption for {key} in store {self.name}")
        self._mark_known(key)
        return data

    def copy_to(self, key: str, dst: str) -> None:
        self.fs.copy_file(self._path(key), dst)

    def drop(self, key: str) -> None:
        with self._known_lock:
            self._known.discard(key)
        self.fs.unlink(self._path(key))

    def keys(self) -> list[str]:
        # full enumeration goes through FS like every other store op, so
        # annex listing is charged under the same parallel-FS cost model
        # (one listdir per shard, degraded with the shard's entry count).
        # Callers that only need presence of specific keys must use
        # ``has_many`` instead — it probes per key and never sweeps.
        out = []
        if not self.fs.isdir(self.root):
            return out
        for shard in self.fs.listdir(self.root):
            d = os.path.join(self.root, shard)
            if self.fs.isdir(d):
                out.extend(self.fs.listdir(d))
        return out
