"""Annex layer: large-file content kept outside the object store.

Mirrors git-annex as the paper uses it (§2.3): a versioned *pointer* travels
with the tree while the content lives in one or more key/value stores that can
hold different subsets of keys. After ``clone`` the annexed files are *known*
but not *present* — ``annex_get`` fetches them from any store that has them,
``annex_drop`` removes the local copy while refusing to destroy the last one
(numcopies protection, unless forced).

Pointer files are what a checkout writes when content is absent:
    #%REPRO-ANNEX%# SHA256-s<size>--<hex>\n

Data plane (DESIGN.md §9)
-------------------------
``ingest_file`` is the bytes-heavy write path: it hashes the source *while*
writing the annex object through ``FS`` — one charged read pass + one charged
write pass instead of the hash-then-copy two-read protocol — into a unique
tmp name that is atomically renamed onto the key path once the hash (hence
the key) is known. The rename is idempotent on collision, so two finishers
ingesting identical content concurrently both succeed and exactly one object
remains (the TOCTOU fix ``put_bytes``/``put_file`` share via ``_commit``).

Every store keeps a *known-key set* mirroring the object store's known-oid
set: once a key has been written or observed present, later ``put``/``has``
calls are answered in memory with no ``exists`` probe against a possibly
degraded shard directory, and re-ingest of duplicate content short-circuits
before moving bytes. ``drop`` discards from the set; a *foreign* process
dropping content this store has observed can make non-``fresh`` probes
stale, which is why numcopies-critical checks pass ``fresh=True``.

Chunk tier (DESIGN.md §12)
--------------------------
A *chunked* object is stored as a **manifest** — a small annex object on
the whole-content key path, recognizable by an in-band magic header — that
lists the content-defined chunk keys (``SHA256C-…``, cut by
:mod:`~repro.core.chunks`) whose concatenation is the content. Chunks are
ordinary content-addressed objects in the same shard layout, shared by
every manifest that references them: re-ingesting a checkpoint where 3% of
the bytes moved writes ~3% of the chunks plus one new manifest. ``read``/
``copy_to`` reassemble transparently; crash ordering is chunks first,
manifest last, so a killed ingest leaves only unreferenced chunks for
``sweep_orphan_chunks`` (wired into ``Session.gc()``). A manifest can be
told apart from a plain object without reading it: the stored byte size
differs from the size embedded in the key.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import uuid

from .chunks import ChunkParams, Cutter
from .faults import is_crash, owner_is_dead
from .fsio import FS
from .hashing import (
    chunk_key_for_bytes,
    is_chunk_key,
    make_annex_key,
    make_chunk_key,
    parse_annex_key,
    verify_annex_key,
)

POINTER_PREFIX = b"#%REPRO-ANNEX%#"
_POINTER_MAX = 256
KNOWN_KEY_CAP = 1 << 20  # bound the probe-skip set for long-lived processes

CHUNK_MAGIC = b"#%REPRO-CHUNKS%#"
_CHUNK_FLUSH = 8 << 20  # pending chunk bytes buffered per has_many+write flush


def make_pointer(key: str, chunked: bool = False) -> bytes:
    """Pointer v1: ``#%REPRO-ANNEX%# <key>\\n``. Pointer v2 appends a
    ``chunked`` flag token so a checkout that later materializes the file
    knows to reassemble. v1 parsers that take the first token keep working."""
    parse_annex_key(key)  # validate
    flag = b" chunked" if chunked else b""
    return POINTER_PREFIX + b" " + key.encode() + flag + b"\n"


def parse_pointer(data: bytes) -> str | None:
    """Return the annex key if ``data`` is a pointer file (v1 or v2),
    else None."""
    parsed = parse_pointer_full(data)
    return None if parsed is None else parsed[0]


def parse_pointer_full(data: bytes) -> tuple[str, bool] | None:
    """Return ``(key, chunked)`` if ``data`` is a pointer file, else None."""
    if len(data) > _POINTER_MAX or not data.startswith(POINTER_PREFIX):
        return None
    try:
        fields = data[len(POINTER_PREFIX):].split()
        if not fields:
            return None
        return fields[0].decode(), b"chunked" in fields[1:]
    except UnicodeDecodeError:
        return None


def encode_chunk_manifest(key: str, chunk_keys: list[str],
                          params: ChunkParams | None) -> bytes:
    """Manifest bytes stored *at the whole-content key path*. The embedded
    ``key`` must match the path's key — that is what lets ``read`` treat
    magic-prefixed real content as ordinary bytes (a file that is a valid
    manifest *for its own key* would have to contain its own sha256, a
    fixed point nobody can construct)."""
    body = {
        "v": 1,
        "key": key,
        "chunks": list(chunk_keys),
        "cutter": params.to_json() if params is not None else None,
    }
    return (
        CHUNK_MAGIC + b"\n"
        + json.dumps(body, sort_keys=True, separators=(",", ":")).encode()
    )


def parse_chunk_manifest(data: bytes, key: str | None = None) -> dict | None:
    """Decode manifest bytes; None if ``data`` is not a manifest, or claims
    a different key than ``key`` (then it is ordinary content)."""
    if not data.startswith(CHUNK_MAGIC + b"\n"):
        return None
    try:
        body = json.loads(data[len(CHUNK_MAGIC) + 1:])
    except (UnicodeDecodeError, json.JSONDecodeError):
        return None
    if not isinstance(body, dict) or "key" not in body or "chunks" not in body:
        return None
    if key is not None and body["key"] != key:
        return None
    return body


class AnnexStore:
    """One key/value store (local annex dir, 'S3 bucket', second-tier FS...).

    All stores share this implementation but may live on filesystems with
    different :class:`~repro.core.fsio.FSProfile` costs — that is exactly the
    paper's second-tier-storage scenario (§2.6).
    """

    def __init__(self, root: str, fs: FS, name: str = "local",
                 sweep_on_open: bool = True,
                 chunk_params: ChunkParams | None = None,
                 chunk_threshold: int | None = None):
        self.root = root
        self.fs = fs
        self.name = name
        # chunk tier configuration: params govern the cutter, threshold
        # routes in-memory puts (``put_bytes``) at/above it through the
        # chunked path. ``chunk_aware`` additionally arms the manifest
        # probe in ``copy_to`` — repos that never enabled chunking keep
        # their exact legacy meta-op accounting.
        self.chunk_params = chunk_params
        self.chunk_threshold = chunk_threshold
        self.chunk_aware = chunk_params is not None
        self._known_lock = threading.Lock()
        self._known: set[str] = set()
        if sweep_on_open and os.path.isdir(root):
            # self-heal: an interrupted ingest leaves tmp-* files forever;
            # opening the store reclaims the ones whose writer is provably
            # dead (pid/incarnation-token guard, age fallback) — DESIGN §10
            self.sweep_stale_tmps()

    def _path(self, key: str) -> str:
        _, hx = parse_annex_key(key)
        return os.path.join(self.root, hx[:3], key)

    # -- known-key set --------------------------------------------------
    def _mark_known(self, key: str) -> None:
        with self._known_lock:
            if len(self._known) >= KNOWN_KEY_CAP:
                # reset rather than evict: the set only skips probes, so
                # dropping it costs one exists per key, never correctness
                self._known.clear()
            self._known.add(key)

    def _is_known(self, key: str) -> bool:
        with self._known_lock:
            return key in self._known

    def has(self, key: str, fresh: bool = False) -> bool:
        """Presence probe. ``fresh=True`` bypasses the known-key set and
        asks the filesystem — required wherever a stale positive would be
        dangerous (numcopies checks before a drop)."""
        if not fresh and self._is_known(key):
            return True
        if self.fs.exists(self._path(key)):
            self._mark_known(key)
            return True
        return False

    def has_many(self, keys, fresh: bool = False) -> set[str]:
        """Presence of a batch of keys by per-key probes (known-key set
        first), NOT a ``keys()`` directory sweep — probing a handful of
        keys must not charge a listdir of every shard."""
        present = set()
        for key in keys:
            if self.has(key, fresh=fresh):
                present.add(key)
        return present

    # -- writes ---------------------------------------------------------
    def _tmp_path(self) -> str:
        # owner-stamped (pid + FS incarnation token): the crash sweep can
        # prove the writer is dead instead of guessing by age alone
        token = getattr(self.fs, "token", None) or "0"
        return os.path.join(
            self.root, f"tmp-{os.getpid()}-{token}-{uuid.uuid4().hex[:12]}"
        )

    @staticmethod
    def _tmp_owner(name: str) -> tuple[int | None, str | None]:
        """(pid, token) from a tmp name; (None, None) for legacy
        ``tmp-<hex>`` names (age-guard only)."""
        parts = name.split("-")
        if len(parts) >= 4 and parts[1].isdigit():
            return int(parts[1]), parts[2]
        return None, None

    def _stale_tmps(self, max_age_s: float | None) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in self.fs.listdir(self.root):
            if not name.startswith("tmp-"):
                continue
            path = os.path.join(self.root, name)
            pid, token = self._tmp_owner(name)
            stale = pid is not None and owner_is_dead(pid, token)
            if not stale and max_age_s is not None:
                try:
                    stale = (time.time() - os.stat(path).st_mtime) > max_age_s
                except OSError:
                    continue  # swept by a racing opener
            if not stale and max_age_s is None and pid is None:
                stale = True  # forced sweep: legacy names have no owner proof
            if stale:
                out.append(path)
        return out

    def count_stale_tmps(self, max_age_s: float | None = 3600.0) -> int:
        """Report-only probe for verify(); charges the same listdir."""
        return len(self._stale_tmps(max_age_s))

    def sweep_stale_tmps(self, max_age_s: float | None = 3600.0) -> int:
        """Unlink leaked ingest tmp files whose writer is provably dead
        (dead pid / dead incarnation token) or whose mtime exceeds
        ``max_age_s`` (``None`` = no age sweeping: owner-proof only, except
        unprovable legacy names which a forced ``None`` sweep does take).
        Every unlink is charged through the FS cost model. Returns the
        count swept."""
        swept = 0
        for path in self._stale_tmps(max_age_s):
            try:
                self.fs.unlink(path)
                swept += 1
            except OSError:
                pass  # a racing sweeper got it first
        return swept

    def _commit(self, tmp: str, key: str) -> None:
        """Atomically publish a fully written tmp file as ``key``.
        ``os.replace`` semantics make the collision case (another finisher
        published the same content first) idempotent: last writer wins with
        byte-identical data, no window where the key path is partial."""
        self.fs.rename(tmp, self._path(key))
        self._mark_known(key)

    def _publish_raw(self, key: str, data: bytes) -> None:
        """tmp-write + atomic rename of pre-verified bytes onto ``key``.
        Shared by ``put_bytes``, chunk publication, and manifest
        publication (manifest bytes do not hash to their key — the chunk
        contents do, which the read path verifies end to end)."""
        tmp = self._tmp_path()
        try:
            self.fs.write_bytes(tmp, data)
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise  # a dead process runs no cleanup: the tmp leaks
            self.fs.unlink(tmp)
            raise

    def put_bytes(self, key: str, data: bytes) -> None:
        if not verify_annex_key(key, data):
            raise ValueError(f"content does not match key {key}")
        if self.has(key):
            return
        if (
            self.chunk_threshold is not None
            and self.chunk_params is not None
            and not is_chunk_key(key)
            and len(data) >= self.chunk_threshold
        ):
            # in-memory publication of a large payload (e.g. run-cache
            # materialization): store it chunked instead of double-buffering
            # a whole second object — shared chunks are skipped, and the
            # content round-trips through the same manifest read path
            stored = self._ingest_chunked(memoryview(data)[i:i + (1 << 20)]
                                          for i in range(0, len(data), 1 << 20))
            if stored != key:  # pragma: no cover - verify above makes this unreachable
                raise IOError(f"chunked put produced {stored}, expected {key}")
            return
        self._publish_raw(key, data)

    def _hash_while_write(self, src: str, chunk_size: int) -> tuple[str, str, int]:
        """The single-pass pump shared by ``put_file``/``ingest_file``:
        stream ``src`` through a sha256 into a fresh tmp file — one charged
        read + one charged write, both held open as §9 stream sessions so
        concurrent ingests contend honestly. Returns (tmp path, hex digest,
        size); the tmp is unlinked on any failure."""
        h = hashlib.sha256()
        tmp = self._tmp_path()
        try:
            with self.fs.open_read(src, chunk_size) as chunks:

                def hashing():
                    for c in chunks:
                        h.update(c)
                        yield c

                size = self.fs.write_chunks(tmp, hashing())
        except BaseException as e:
            if is_crash(e):
                raise  # a dead process runs no cleanup: the tmp leaks
            self.fs.unlink(tmp)
            raise
        return tmp, h.hexdigest(), size

    def put_file(self, key: str, src: str) -> None:
        """Copy a file in as ``key``, hashing while copying (single pass)
        and verifying the content actually matches the key before the tmp
        is published — a corrupted source never lands on the key path."""
        if self.has(key):
            return
        tmp, hx, size = self._hash_while_write(src, 1 << 20)
        rebuilt = (
            make_chunk_key(hx, size) if is_chunk_key(key)
            else make_annex_key(hx, size)
        )
        try:
            if rebuilt != key:
                raise IOError(f"content of {src} does not match key {key}")
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise

    def ingest_file(self, src: str, chunk_size: int = 1 << 20,
                    chunked: bool = False) -> str:
        """Single-pass ingest: hash ``src`` while writing the annex object.
        The object is written to a tmp name (the key isn't known until the
        hash is) and renamed onto the key path; duplicate content (key
        already known or present) discards the tmp instead, leaving exactly
        one object. Returns the key.

        ``chunked=True`` routes through the chunk tier: the same single
        charged read pass feeds the content-defined cutter, chunk hashes,
        and the whole-content hash; ``has_many``-batched presence checks
        skip chunks the store already holds, so only the delta's bytes are
        written before the manifest is published on the key path."""
        if chunked:
            with self.fs.open_read(src, chunk_size) as chunks:
                return self._ingest_chunked(chunks)
        tmp, hx, size = self._hash_while_write(src, chunk_size)
        key = make_annex_key(hx, size)
        try:
            if self.has(key):
                # dedup short-circuit: identical content already ingested
                self.fs.unlink(tmp)
                return key
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise
        return key

    def put_stream(self, blocks, chunked: bool = False) -> str:
        """Ingest from an in-memory iterator of byte blocks — the write
        path for content that never existed as a file (checkpoint leaves
        streaming off the device). Only the write side charges the FS cost
        model; the source is process memory. Returns the key."""
        if chunked:
            return self._ingest_chunked(blocks)
        h = hashlib.sha256()
        tmp = self._tmp_path()

        def hashing():
            for b in blocks:
                h.update(b)
                yield b

        try:
            size = self.fs.write_chunks(tmp, hashing())
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise
        key = make_annex_key(h.hexdigest(), size)
        try:
            if self.has(key):
                self.fs.unlink(tmp)
                return key
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise
        return key

    def _ingest_chunked(self, blocks) -> str:
        """Chunk-tier ingest pump: cut + hash + write in one pass.

        Chunks are accumulated into bounded batches; each batch does one
        ``has_many`` presence pass (known-key set answers steady-state
        probes in memory) and writes only the misses, each tmp+rename
        published so a concurrent identical ingest stays idempotent.
        The manifest lands last — a crash anywhere before that leaves
        only unreferenced chunks (``sweep_orphan_chunks``) and no partial
        object on the key path."""
        if self.chunk_params is None:
            raise ValueError(f"store {self.name} has no chunk params configured")
        cutter = Cutter(self.chunk_params)
        full = hashlib.sha256()
        total = 0
        chunk_keys: list[str] = []
        pending: list[tuple[str, bytes]] = []
        pending_bytes = 0
        published = 0

        def flush():
            nonlocal pending, pending_bytes, published
            if not pending:
                return
            present = self.has_many([k for k, _ in pending])
            for ck, data in pending:
                if ck in present:
                    continue
                self._publish_raw(ck, data)
                present.add(ck)  # batch-internal dedup of identical chunks
                published += 1
                if published == 1:
                    self.fs.crash_point("chunk:mid-publish")
            pending = []
            pending_bytes = 0

        def take(chunk: bytes):
            nonlocal pending_bytes
            ck = chunk_key_for_bytes(chunk)
            chunk_keys.append(ck)
            pending.append((ck, chunk))
            pending_bytes += len(chunk)
            if pending_bytes >= _CHUNK_FLUSH:
                flush()

        for block in blocks:
            if not block:
                continue
            full.update(block)
            total += len(block)
            for chunk in cutter.feed(block):
                take(chunk)
        for chunk in cutter.finish():
            take(chunk)
        flush()
        key = make_annex_key(full.hexdigest(), total)
        self.fs.crash_point("chunk:before-manifest")
        if not self.has(key):
            self._publish_raw(
                key, encode_chunk_manifest(key, chunk_keys, self.chunk_params)
            )
        return key

    # -- inter-store transfer -------------------------------------------
    def receive_file(self, key: str, src_fs: FS, src_path: str) -> bool:
        """Accept one object from another store's file (the push unit).
        Same-filesystem base case: ``put_file`` charges both the read and
        the write on this store's FS, preserving the legacy accounting of
        co-located remotes. Network stores override with a gated, per-
        direction-charged implementation (``src_fs`` carries the client
        side's read costs there). Returns False when already present —
        no bytes move."""
        del src_fs  # same-FS base case: put_file reads on self.fs
        if self.has(key):
            return False
        self.put_file(key, src_path)
        return True

    def fetch_into(self, key: str, dst: "AnnexStore") -> bool:
        """Move one object from this store into ``dst`` (the fetch unit).
        Base case charges the copy on ``dst``'s FS like the legacy fetch
        path always did; network stores override to charge the download on
        the link instead. Returns False when ``dst`` already holds it."""
        if dst.has(key):
            return False
        dst.put_file(key, self._path(key))
        return True

    # -- reads / deletion ----------------------------------------------
    def read(self, key: str) -> bytes:
        data = self.fs.read_bytes(self._path(key))
        mf = parse_chunk_manifest(data, key)
        if mf is not None:
            parts = []
            for ck in mf["chunks"]:
                cd = self.fs.read_bytes(self._path(ck))
                if not verify_annex_key(ck, cd):
                    raise IOError(
                        f"chunk corruption for {ck} (of {key}) in store {self.name}"
                    )
                parts.append(cd)
            data = b"".join(parts)
        if not verify_annex_key(key, data):
            raise IOError(f"annex corruption for {key} in store {self.name}")
        self._mark_known(key)
        return data

    def manifest_of(self, key: str) -> list[str] | None:
        """Chunk keys of ``key`` if it is stored chunked here, else None.
        Probes by size first — a manifest is the one object whose stored
        byte count differs from the size its key embeds — so plain objects
        cost a single stat, never a read."""
        if is_chunk_key(key):
            return None
        content_size, _ = parse_annex_key(key)
        path = self._path(key)
        if self.fs.stat_size(path) == content_size:
            return None
        mf = parse_chunk_manifest(self.fs.read_bytes(path), key)
        if mf is None:
            raise IOError(
                f"annex corruption for {key} in store {self.name}: stored size "
                f"differs from key size but content is not a chunk manifest"
            )
        return list(mf["chunks"])

    def put_manifest(self, key: str, chunk_keys: list[str]) -> None:
        """Publish a manifest for ``key`` referencing chunks this store
        already holds — the replication path (push/fetch move chunks
        individually, then bind them with a locally encoded manifest)."""
        if self.has(key):
            return
        self._publish_raw(key, encode_chunk_manifest(key, chunk_keys, self.chunk_params))

    def copy_to(self, key: str, dst: str) -> None:
        """Materialize ``key`` at ``dst`` — streamed reassembly for chunked
        objects, plain charged copy otherwise. The manifest probe is armed
        only on chunk-aware stores so repositories that never enabled
        chunking keep their exact legacy meta-op accounting."""
        chunks = self.manifest_of(key) if self.chunk_aware else None
        if chunks is None:
            self.fs.copy_file(self._path(key), dst)
            return

        def gen():
            for ck in chunks:
                cd = self.fs.read_bytes(self._path(ck))
                if not verify_annex_key(ck, cd):
                    raise IOError(
                        f"chunk corruption for {ck} (of {key}) in store {self.name}"
                    )
                yield cd

        self.fs.write_chunks(dst, gen())

    def drop(self, key: str) -> None:
        with self._known_lock:
            self._known.discard(key)
        self.fs.unlink(self._path(key))

    def sweep_orphan_chunks(self) -> int:
        """Drop chunk-tier objects no manifest in this store references.

        Orphans are what a crashed chunked ingest leaves behind (chunks
        publish before the manifest) and what ``drop`` of a chunked key
        strands (the manifest goes; shared chunks cannot). This is a full
        charged enumeration + one stat per whole-content key, so it lives
        with the other offline maintenance in ``Session.gc()`` — never on
        the ingest path. Concurrent chunked ingests would race it exactly
        like ``repack``; run it quiesced. Returns the count swept."""
        names = self.keys()
        chunk_keys = {k for k in names if is_chunk_key(k)}
        if not chunk_keys:
            return 0
        referenced: set[str] = set()
        for k in names:
            if is_chunk_key(k):
                continue
            try:
                chunks = self.manifest_of(k)
            except (OSError, ValueError):
                continue  # corrupt or foreign entry: verify()'s problem
            if chunks:
                referenced.update(chunks)
        swept = 0
        for ck in chunk_keys - referenced:
            try:
                self.drop(ck)
                swept += 1
            except OSError:
                pass  # a racing sweeper got it first
        return swept

    def keys(self) -> list[str]:
        # full enumeration goes through FS like every other store op, so
        # annex listing is charged under the same parallel-FS cost model
        # (one listdir per shard, degraded with the shard's entry count).
        # Callers that only need presence of specific keys must use
        # ``has_many`` instead — it probes per key and never sweeps.
        out = []
        if not self.fs.isdir(self.root):
            return out
        for shard in self.fs.listdir(self.root):
            d = os.path.join(self.root, shard)
            if self.fs.isdir(d):
                out.extend(self.fs.listdir(d))
        return out
