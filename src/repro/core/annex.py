"""Annex layer: large-file content kept outside the object store.

Mirrors git-annex as the paper uses it (§2.3): a versioned *pointer* travels
with the tree while the content lives in one or more key/value stores that can
hold different subsets of keys. After ``clone`` the annexed files are *known*
but not *present* — ``annex_get`` fetches them from any store that has them,
``annex_drop`` removes the local copy while refusing to destroy the last one
(numcopies protection, unless forced).

Pointer files are what a checkout writes when content is absent:
    #%REPRO-ANNEX%# SHA256-s<size>--<hex>\n
"""
from __future__ import annotations

import os

from .fsio import FS
from .hashing import parse_annex_key, verify_annex_key

POINTER_PREFIX = b"#%REPRO-ANNEX%#"
_POINTER_MAX = 256


def make_pointer(key: str) -> bytes:
    parse_annex_key(key)  # validate
    return POINTER_PREFIX + b" " + key.encode() + b"\n"


def parse_pointer(data: bytes) -> str | None:
    """Return the annex key if ``data`` is a pointer file, else None."""
    if len(data) > _POINTER_MAX or not data.startswith(POINTER_PREFIX):
        return None
    try:
        return data[len(POINTER_PREFIX):].strip().decode()
    except UnicodeDecodeError:
        return None


class AnnexStore:
    """One key/value store (local annex dir, 'S3 bucket', second-tier FS...).

    All stores share this implementation but may live on filesystems with
    different :class:`~repro.core.fsio.FSProfile` costs — that is exactly the
    paper's second-tier-storage scenario (§2.6).
    """

    def __init__(self, root: str, fs: FS, name: str = "local"):
        self.root = root
        self.fs = fs
        self.name = name

    def _path(self, key: str) -> str:
        _, hx = parse_annex_key(key)
        return os.path.join(self.root, hx[:3], key)

    def has(self, key: str) -> bool:
        return self.fs.exists(self._path(key))

    def put_bytes(self, key: str, data: bytes) -> None:
        if not verify_annex_key(key, data):
            raise ValueError(f"content does not match key {key}")
        path = self._path(key)
        if not self.fs.exists(path):
            self.fs.write_bytes(path, data)

    def put_file(self, key: str, src: str) -> None:
        path = self._path(key)
        if not self.fs.exists(path):
            self.fs.copy_file(src, path)

    def read(self, key: str) -> bytes:
        data = self.fs.read_bytes(self._path(key))
        if not verify_annex_key(key, data):
            raise IOError(f"annex corruption for {key} in store {self.name}")
        return data

    def copy_to(self, key: str, dst: str) -> None:
        self.fs.copy_file(self._path(key), dst)

    def drop(self, key: str) -> None:
        self.fs.unlink(self._path(key))

    def keys(self) -> list[str]:
        # enumeration goes through FS like every other store op, so annex
        # listing is charged under the same parallel-FS cost model (one
        # listdir per shard, degraded with the shard's entry count)
        out = []
        if not self.fs.isdir(self.root):
            return out
        for shard in self.fs.listdir(self.root):
            d = os.path.join(self.root, shard)
            if self.fs.isdir(d):
                out.extend(self.fs.listdir(d))
        return out
