"""Remote annex tier (DESIGN.md §13): simulated sites over an unreliable net.

The paper's reproducibility story stops at one filesystem; real campaigns
replicate outputs across sites and archives over links that drop requests,
stall, disconnect mid-stream, and occasionally take a whole site down. This
module makes the remote a first-class, *simulated* store so the transfer
protocol can be property-tested the same way the crash machinery is (§10):
every byte and every round trip is charged on the shared
:class:`~repro.core.fsio.SimClock`, and every network failure is a seeded,
replayable event.

Three layers:

:class:`NetProfile`
    Latency + per-direction bandwidth of one site link, mapped onto an
    :class:`~repro.core.fsio.FSProfile` (``meta_op_s`` = request round trip,
    ``write_bw`` = upload, ``read_bw`` = download, per-stream caps honored
    by §9's stream pools) so the remote's backing store charges network
    costs with the exact machinery the local filesystems use.

:class:`NetworkFaultModel`
    Seeded declarative schedule of network faults per remote: transient
    request errors, stalls charged against the profile's per-transfer
    timeout, mid-stream disconnects (which strand the remote-side tmp —
    the dead link cannot clean it), and whole-remote outages that mark the
    site unavailable. Bounded retry/backoff lives in :func:`net_retry`,
    mirroring ``FS._fault``'s transient loop: each attempt's backoff is a
    seeded exponential charge on the clock.

:class:`RemoteStore`
    An :class:`~repro.core.annex.AnnexStore` whose FS is the network link —
    so it inherits the owner-stamped tmp discipline (a crashed push leaves
    ``tmp-pid-token-*`` litter that the sweep-on-open reclaims), idempotent
    tmp+rename publication, and the manifest/chunk layout. On top it adds
    batched one-round-trip presence queries (``has_many``), gated
    per-direction transfers with payload accounting, and an availability
    flag that pull failover consults.

Transfers move *chunks*, not objects: :func:`push_keys` / :func:`pull_keys`
do a batched presence pre-pass per remote, journal their intent (PR 6
discipline, ``remote:*`` crash points) so a killed client resumes with only
the missing chunks re-sent, and a dead remote fails pull over to the next
replica instead of erroring.
"""
from __future__ import annotations

import hashlib
import os
import random
import threading
from dataclasses import dataclass

from .annex import AnnexStore, encode_chunk_manifest
from .faults import InjectedNetworkError, RemoteUnavailable, is_crash
from .fsio import FS, FSProfile, SimClock
from .hashing import is_chunk_key, make_annex_key, make_chunk_key

# -- network profiles --------------------------------------------------------


@dataclass(frozen=True)
class NetProfile:
    """One site link: request latency + per-direction aggregate bandwidth
    (bytes/second), optional per-stream caps for §9's stream pools, a
    per-transfer stall timeout, and the server-side cost of one key probe
    inside a batched presence query."""

    name: str
    latency_s: float
    up_bw: float  # client -> remote (push), AGGREGATE across streams
    down_bw: float  # remote -> client (pull), AGGREGATE across streams
    up_stream_bw: float | None = None
    down_stream_bw: float | None = None
    timeout_s: float = 30.0
    probe_s: float = 1e-5

    def to_fs_profile(self) -> FSProfile:
        """The link as an FSProfile: every meta op is a round trip, reads
        are downloads, writes are uploads. Directory-entry degradation is
        a parallel-FS artifact, not a network one — disabled."""
        return FSProfile(
            name=f"net-{self.name}",
            meta_op_s=self.latency_s,
            read_bw=self.down_bw,
            write_bw=self.up_bw,
            degrade_threshold=0,
            dir_degrade=0.0,
            read_stream_bw=self.down_stream_bw,
            write_stream_bw=self.up_stream_bw,
        )

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "latency_s": self.latency_s,
            "up_bw": self.up_bw,
            "down_bw": self.down_bw,
            "up_stream_bw": self.up_stream_bw,
            "down_stream_bw": self.down_stream_bw,
            "timeout_s": self.timeout_s,
            "probe_s": self.probe_s,
        }

    @classmethod
    def from_json(cls, d: dict) -> "NetProfile":
        return cls(**d)


# Same machine-room: 10 GbE, sub-ms round trips.
LAN = NetProfile(name="lan", latency_s=2e-4, up_bw=1.25e9, down_bw=1.25e9)
# Cross-site archive link: ~30 ms RTT, 1 Gb/s up / 2 Gb/s down aggregate,
# one TCP stream drives a quarter of it (parallel streams pay off).
WAN = NetProfile(
    name="wan",
    latency_s=0.03,
    up_bw=1.25e8,
    down_bw=2.5e8,
    up_stream_bw=1.25e8 / 4,
    down_stream_bw=2.5e8 / 4,
    timeout_s=60.0,
)

_PRESETS = {"lan": LAN, "wan": WAN}


def coerce_net(net) -> NetProfile:
    """Accept a preset name, a config dict, a NetProfile, or None (LAN)."""
    if net is None:
        return LAN
    if isinstance(net, NetProfile):
        return net
    if isinstance(net, str):
        try:
            return _PRESETS[net]
        except KeyError:
            raise ValueError(f"unknown net profile {net!r}") from None
    if isinstance(net, dict):
        return NetProfile.from_json(net)
    raise TypeError(f"cannot build a NetProfile from {type(net).__name__}")


# -- network fault model -----------------------------------------------------


@dataclass
class NetFaultRule:
    """One declarative network fault. ``op`` is the request direction the
    rule watches: ``send`` (push-side mutation), ``recv`` (download),
    ``query`` (presence/metadata), or ``*``. ``remote`` filters by site
    name (None = any). ``kind``:

    error        transient request failure (retried with seeded backoff),
    stall        the request hangs ``stall_s`` — charged up to the
                 profile's ``timeout_s``; at/over the timeout the transfer
                 times out (transient),
    disconnect   the link dies mid-stream: fires per transferred block,
                 stranding the remote-side tmp of an in-flight upload,
    outage       the whole site goes down — every later request raises
                 :class:`~repro.core.faults.RemoteUnavailable` until
                 revived.

    Triggering mirrors :class:`~repro.core.faults.FaultRule`: ``nth`` /
    ``every`` / seeded ``p`` / always, capped by ``times``."""

    op: str
    remote: str | None = None
    kind: str = "error"
    nth: int | None = None
    every: int | None = None
    p: float | None = None
    times: int | None = None
    stall_s: float = 0.0
    calls: int = 0
    fires: int = 0


class NetworkFaultModel:
    """Seeded, declarative network fault schedule shared by every
    :class:`RemoteStore` of a session. Thread-safe like
    :class:`~repro.core.faults.FaultPlan` — counters and the rng mutate
    under one lock. Also owns the retry policy: ``max_retries`` transient
    attempts per transfer, each preceded by a seeded-jitter exponential
    backoff charge (:meth:`backoff_s`) — same seed, same total charge."""

    def __init__(
        self,
        seed: int = 0,
        rules: list[NetFaultRule] | tuple = (),
        max_retries: int = 4,
        backoff_base_s: float = 0.05,
    ):
        self.rng = random.Random(seed)
        self.rules = list(rules)
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self._dead: set[str] = set()
        self._lock = threading.Lock()

    # -- availability ---------------------------------------------------
    def is_available(self, remote: str) -> bool:
        with self._lock:
            return remote not in self._dead

    def mark_dead(self, remote: str) -> None:
        with self._lock:
            self._dead.add(remote)

    def revive(self, remote: str) -> None:
        with self._lock:
            self._dead.discard(remote)

    # -- retry policy ---------------------------------------------------
    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff with seeded jitter in [1x, 2x) — bounded,
        deterministic per seed (the determinism test's contract)."""
        with self._lock:
            jitter = 1.0 + self.rng.random()
        return self.backoff_base_s * (2 ** attempt) * jitter

    # -- firing ---------------------------------------------------------
    def _fire(self, rule: NetFaultRule) -> bool:
        with self._lock:
            rule.calls += 1
            if rule.times is not None and rule.fires >= rule.times:
                return False
            if rule.nth is not None:
                fire = rule.calls == rule.nth
            elif rule.every is not None:
                fire = rule.calls % rule.every == 0
            elif rule.p is not None:
                fire = self.rng.random() < rule.p
            else:
                fire = True
            if fire:
                rule.fires += 1
            return fire

    def _match(self, rule: NetFaultRule, op: str, remote: str) -> bool:
        if rule.op not in ("*", op):
            return False
        return rule.remote is None or rule.remote == remote

    def on_request(self, op: str, remote: str, clock: SimClock,
                   timeout_s: float) -> None:
        """Gate one remote request (before its round trip is charged)."""
        if not self.is_available(remote):
            raise RemoteUnavailable(remote)
        for rule in self.rules:
            if rule.kind == "disconnect" or not self._match(rule, op, remote):
                continue
            if not self._fire(rule):
                continue
            if rule.kind == "outage":
                self.mark_dead(remote)
                raise RemoteUnavailable(remote)
            if rule.kind == "stall":
                # the client genuinely waits — but never past its timeout
                clock.charge(min(rule.stall_s, timeout_s))
                if rule.stall_s >= timeout_s:
                    raise InjectedNetworkError(op, remote, reason="timeout")
                continue
            raise InjectedNetworkError(op, remote, reason="error")

    def on_stream(self, op: str, remote: str) -> None:
        """Mid-stream gate, consulted per transferred block: disconnects
        only — the request-level faults already fired before byte one."""
        for rule in self.rules:
            if rule.kind != "disconnect" or not self._match(rule, op, remote):
                continue
            if self._fire(rule):
                raise InjectedNetworkError(op, remote, reason="disconnect")


# -- the remote store --------------------------------------------------------


class RemoteStore(AnnexStore):
    """A simulated remote site: an annex store reached over a network link.

    The backing store is real (correctness is tested on real bytes); the
    *costs* are the link's — the store's FS carries the NetProfile, so meta
    ops charge round trips and transfers charge per-direction bandwidth
    through §9's stream pools. The same FS's incarnation token stamps the
    remote-side tmp files, so a crashed client's half-uploaded objects are
    provably dead and swept on the next open (``sweep_on_open=True``, the
    PR 6 discipline) — a crashed push never leaks partial objects."""

    def __init__(
        self,
        root: str,
        clock: SimClock | None = None,
        name: str = "site",
        net: "NetProfile | dict | str | None" = None,
        chunk_params=None,
        chunk_threshold: int | None = None,
        fault_model: NetworkFaultModel | None = None,
        faults=None,
        sweep_on_open: bool = True,
    ):
        self.net = coerce_net(net)
        self.fault_model = fault_model
        self._marked_dead = False
        # payload accounting (client perspective; single transfer loop)
        self.bytes_sent = 0
        self.bytes_received = 0
        self.transfers = 0
        self.retries = 0
        fs = FS(self.net.to_fs_profile(), clock, faults=faults)
        super().__init__(
            root, fs, name=name, sweep_on_open=sweep_on_open,
            chunk_params=chunk_params, chunk_threshold=chunk_threshold,
        )

    # -- availability ---------------------------------------------------
    @property
    def available(self) -> bool:
        if self._marked_dead:
            return False
        return self.fault_model is None or self.fault_model.is_available(self.name)

    def mark_unavailable(self) -> None:
        self._marked_dead = True

    def mark_available(self) -> None:
        self._marked_dead = False
        if self.fault_model is not None:
            self.fault_model.revive(self.name)

    # -- fault gates ----------------------------------------------------
    def _gate(self, op: str) -> None:
        if self._marked_dead:
            raise RemoteUnavailable(self.name, "marked unavailable")
        if self.fs.faults is not None:
            # a dead client issues no requests: crash poisoning applies to
            # the network exactly as it does to the filesystem
            self.fs.faults._check_crashed()
        if self.fault_model is not None:
            self.fault_model.on_request(
                op, self.name, self.fs.clock, self.net.timeout_s
            )

    def _gate_stream(self, op: str) -> None:
        if self.fault_model is not None:
            self.fault_model.on_stream(op, self.name)

    # -- batched presence ------------------------------------------------
    def has_many(self, keys, fresh: bool = False) -> set[str]:
        """ONE round trip for the whole batch — the server answers N key
        probes at its local per-key cost — instead of one RTT per key.
        This is the presence primitive every numcopies-critical caller and
        every transfer pre-pass routes through; ``fresh=True`` bypasses the
        known-key set and asks the site."""
        keys = list(keys)
        present: set[str] = set()
        misses: list[str] = []
        for key in keys:
            if not fresh and self._is_known(key):
                present.add(key)
            else:
                misses.append(key)
        if not misses:
            return present
        self._gate("query")
        self.fs.clock.charge_meta(
            len(misses), self.net.latency_s + self.net.probe_s * len(misses)
        )
        for key in misses:
            # server-side stat: the per-key cost is in the batch charge
            # above, not one client round trip each
            if os.path.exists(self._path(key)):
                present.add(key)
                self._mark_known(key)
        return present

    # -- gated single-object ops ----------------------------------------
    def has(self, key: str, fresh: bool = False) -> bool:
        if not fresh and self._is_known(key):
            return True
        self._gate("query")
        return super().has(key, fresh=fresh)

    def read(self, key: str) -> bytes:
        self._gate("recv")
        return super().read(key)

    def copy_to(self, key: str, dst: str) -> None:
        self._gate("recv")
        super().copy_to(key, dst)

    def manifest_of(self, key: str) -> list[str] | None:
        self._gate("query")
        return super().manifest_of(key)

    def put_manifest(self, key: str, chunk_keys: list[str]) -> None:
        self._gate("send")
        if self.has(key):
            return
        self._publish_raw(
            key, encode_chunk_manifest(key, chunk_keys, self.chunk_params)
        )

    def drop(self, key: str) -> None:
        self._gate("send")
        super().drop(key)

    # -- transfers -------------------------------------------------------
    def receive_file(self, key: str, src_fs: FS, src_path: str) -> bool:
        """Upload one object into this remote: a streamed charged read from
        the client's store plus a charged upload through the link, verified
        against the key before the remote-side tmp is published. A
        mid-stream disconnect strands the remote tmp (a dead link runs no
        remote cleanup) — the owner-stamped sweep on the next open reclaims
        it. Returns False when the remote already holds the key."""
        self._gate("send")
        if self.has(key):
            return False
        h = hashlib.sha256()
        tmp = self._tmp_path()
        try:
            with src_fs.open_read(src_path, 1 << 20) as chunks:

                def pump():
                    for c in chunks:
                        self._gate_stream("send")
                        h.update(c)
                        self.bytes_sent += len(c)
                        yield c

                size = self.fs.write_chunks(tmp, pump())
        except BaseException as e:
            if is_crash(e) or getattr(e, "reason", None) == "disconnect":
                raise  # dead client or dead link: the remote tmp leaks
            self.fs.unlink(tmp)
            raise
        rebuilt = (
            make_chunk_key(h.hexdigest(), size) if is_chunk_key(key)
            else make_annex_key(h.hexdigest(), size)
        )
        try:
            if rebuilt != key:
                raise IOError(f"content of {src_path} does not match key {key}")
            self._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            self.fs.unlink(tmp)
            raise
        self.transfers += 1
        return True

    def fetch_into(self, key: str, dst: AnnexStore) -> bool:
        """Download one object from this remote into ``dst``: a charged
        download through the link plus a charged local write, verified
        before ``dst``'s tmp is published. Client-side cleanup survives a
        dead link — only a client crash leaks the local tmp."""
        self._gate("recv")
        if dst.has(key):
            return False
        h = hashlib.sha256()
        tmp = dst._tmp_path()
        try:
            with self.fs.open_read(self._path(key), 1 << 20) as chunks:

                def pump():
                    for c in chunks:
                        self._gate_stream("recv")
                        h.update(c)
                        self.bytes_received += len(c)
                        yield c

                size = dst.fs.write_chunks(tmp, pump())
            rebuilt = (
                make_chunk_key(h.hexdigest(), size) if is_chunk_key(key)
                else make_annex_key(h.hexdigest(), size)
            )
            if rebuilt != key:
                raise IOError(
                    f"remote {self.name} returned corrupt content for {key}"
                )
            dst._commit(tmp, key)
        except BaseException as e:
            if is_crash(e):
                raise
            dst.fs.unlink(tmp)
            raise
        self.transfers += 1
        return True


# -- bounded seeded retry ----------------------------------------------------


def net_retry(store: AnnexStore, fn, what: str, report: dict | None = None):
    """Bounded retry/backoff around one remote operation.

    Transient network faults (request errors, timeouts, mid-stream
    disconnects) are retried up to the fault model's ``max_retries``; each
    attempt waits a seeded exponential backoff charged on the SimClock —
    the client genuinely waits, and the charge is deterministic per seed.
    ``RemoteUnavailable`` (and exhausted retries) propagate: the caller
    decides between failover (pull) and surfacing the error (push). Works
    on plain same-filesystem stores too — no fault model, no retries."""
    model = getattr(store, "fault_model", None)
    attempt = 0
    while True:
        try:
            return fn()
        except InjectedNetworkError as e:
            max_r = model.max_retries if model is not None else 0
            if not e.transient or attempt >= max_r:
                raise
            store.fs.clock.charge(model.backoff_s(attempt))
            attempt += 1
            if isinstance(store, RemoteStore):
                store.retries += 1
            if report is not None:
                report["retries"] = report.get("retries", 0) + 1


def _store_has(store: AnnexStore, keys, fresh: bool = True,
               report: dict | None = None) -> set[str]:
    return net_retry(
        store, lambda: store.has_many(keys, fresh=fresh),
        f"presence on {store.name}", report,
    )


# -- chunk-level transfer orchestration --------------------------------------


def head_annex_keys(repo) -> list[str]:
    """Every annex key referenced by the current HEAD tree — the 'local
    truth' set push/fetch default to."""
    head = repo.head_commit()
    if head is None:
        return []
    return sorted(
        {
            e["key"]
            for e in repo.tree_of(head).values()
            if e.get("t") == "annex"
        }
    )


def push_keys(repo, store: AnnexStore, keys: list[str] | None = None,
              journal: bool = True, db=None) -> dict:
    """Resumable chunk-level push of ``keys`` (default: HEAD's annex keys)
    to one remote.

    Protocol: one batched fresh presence pass over the whole-content keys
    (objects the remote holds never transfer again), then per missing key a
    batched chunk presence pass and one upload per missing chunk, manifest
    bound last — the remote never exposes a manifest whose chunks it lacks.
    Intent is journaled first (kind ``push``); a killed client's journal is
    replayed by ``recover()``, whose presence pre-pass re-sends only the
    chunks absent from the remote (exactly-once, PR 6 discipline)."""
    if keys is None:
        keys = head_annex_keys(repo)
    keys = list(keys)
    report = {
        "remote": store.name, "keys": len(keys), "keys_sent": 0,
        "keys_skipped": 0, "chunks_sent": 0, "bytes_sent": 0, "retries": 0,
    }
    if isinstance(store, RemoteStore) and not store.available:
        raise RemoteUnavailable(store.name, "marked unavailable")
    if not keys:
        return report
    fs = repo.fs
    b0 = getattr(store, "bytes_sent", 0)
    jh = None
    if journal:
        from .recovery import JournalHandle

        jh = JournalHandle.begin(
            fs, repo.repro_dir, "push", {"remote": store.name, "keys": keys}
        )
        fs.crash_point("remote:push-journal-written")
    sent_any = False
    have = _store_has(store, keys, fresh=True, report=report)
    for key in keys:
        if key in have:
            report["keys_skipped"] += 1
            if jh is not None:
                jh.append({"key": key, "skipped": True})
            continue
        chunks = repo.annex.manifest_of(key) if repo.annex.chunk_aware else None
        if chunks is None:
            net_retry(
                store,
                lambda k=key: store.receive_file(
                    k, repo.annex.fs, repo.annex._path(k)
                ),
                f"push {key}", report,
            )
            report["chunks_sent"] += 1
            if not sent_any:
                sent_any = True
                fs.crash_point("remote:push-mid-object")
        else:
            present = _store_has(store, chunks, fresh=True, report=report)
            for ck in chunks:
                if ck in present:
                    continue
                net_retry(
                    store,
                    lambda k=ck: store.receive_file(
                        k, repo.annex.fs, repo.annex._path(k)
                    ),
                    f"push chunk {ck}", report,
                )
                present.add(ck)
                report["chunks_sent"] += 1
                if not sent_any:
                    sent_any = True
                    fs.crash_point("remote:push-mid-object")
            fs.crash_point("remote:push-before-manifest")
            net_retry(
                store,
                lambda k=key, c=chunks: store.put_manifest(k, c),
                f"push manifest {key}", report,
            )
        report["keys_sent"] += 1
        if jh is not None:
            jh.append({"key": key})
        fs.crash_point("remote:push-after-key")
    if jh is not None:
        jh.done()
        fs.crash_point("remote:push-done")
    report["bytes_sent"] = getattr(store, "bytes_sent", 0) - b0
    if db is not None:
        db.locations_record(store.name, keys)
    return report


def pull_keys(repo, keys: list[str] | None = None, journal: bool = True,
              db=None, stores: list[AnnexStore] | None = None) -> dict:
    """Resumable chunk-level pull of ``keys`` (default: HEAD's annex keys)
    into the local annex, with replica failover.

    Per key the first *available* replica that holds it is asked for its
    manifest; missing chunks download individually (batched local presence
    pre-pass — warm chunks never move), and the local manifest is bound
    last. A replica that goes dead mid-pull (outage, or transient retries
    exhausted) is marked unavailable and the key fails over to the next
    one; only when no replica can serve does the pull raise. Intent is
    journaled (kind ``pull``) for crash resume."""
    if keys is None:
        keys = head_annex_keys(repo)
    keys = [k for k in keys if not repo.annex.has(k)]
    report = {
        "keys": len(keys), "keys_fetched": 0, "chunks_fetched": 0,
        "bytes_received": 0, "retries": 0, "failovers": 0, "sources": {},
    }
    if not keys:
        return report
    fs = repo.fs
    candidates = list(stores) if stores is not None else list(repo._remotes)
    b0 = sum(getattr(s, "bytes_received", 0) for s in candidates)
    jh = None
    if journal:
        from .recovery import JournalHandle

        jh = JournalHandle.begin(fs, repo.repro_dir, "pull", {"keys": keys})
        fs.crash_point("remote:pull-journal-written")
    state = {"fetched_any": False}
    for key in keys:
        src = _pull_one(repo, key, candidates, report, state)
        report["keys_fetched"] += 1
        report["sources"][key] = src
        if jh is not None:
            jh.append({"key": key, "from": src})
        fs.crash_point("remote:pull-after-key")
    if jh is not None:
        jh.done()
        fs.crash_point("remote:pull-done")
    report["bytes_received"] = (
        sum(getattr(s, "bytes_received", 0) for s in candidates) - b0
    )
    if db is not None:
        by_src: dict[str, list[str]] = {}
        for key, src in report["sources"].items():
            by_src.setdefault(src, []).append(key)
        for src, ks in by_src.items():
            db.locations_record(src, ks)
    return report


def _pull_one(repo, key: str, stores: list[AnnexStore], report: dict,
              state: dict) -> str:
    """Fetch one key from the first available replica that holds it,
    failing over on remote death. Returns the serving store's name."""
    fs = repo.fs
    last_err: BaseException | None = None
    for store in stores:
        if isinstance(store, RemoteStore) and not store.available:
            continue
        try:
            if key not in _store_has(store, [key], fresh=True, report=report):
                continue  # this replica never had it: not a failure
            chunks = (
                net_retry(store, lambda: store.manifest_of(key),
                          f"manifest {key}", report)
                if store.chunk_aware else None
            )
            if chunks is None:
                net_retry(
                    store,
                    lambda: store.fetch_into(key, repo.annex),
                    f"pull {key}", report,
                )
                report["chunks_fetched"] += 1
                if not state["fetched_any"]:
                    state["fetched_any"] = True
                    fs.crash_point("remote:pull-mid-object")
            else:
                local = repo.annex.has_many(chunks)
                for ck in chunks:
                    if ck in local:
                        continue
                    net_retry(
                        store,
                        lambda k=ck: store.fetch_into(k, repo.annex),
                        f"pull chunk {ck}", report,
                    )
                    local.add(ck)
                    report["chunks_fetched"] += 1
                    if not state["fetched_any"]:
                        state["fetched_any"] = True
                        fs.crash_point("remote:pull-mid-object")
                repo.annex.put_manifest(key, chunks)
            return store.name
        except (InjectedNetworkError, RemoteUnavailable) as e:
            # graceful degradation: this replica is dead to us (outage, or
            # its transient-retry budget is spent) — fail over
            last_err = e
            if isinstance(store, RemoteStore):
                store.mark_unavailable()
            report["failovers"] += 1
            continue
    if last_err is not None:
        raise last_err
    raise FileNotFoundError(f"no available replica holds {key}")
