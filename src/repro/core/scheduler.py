"""The DataLad-Slurm protocol: schedule / finish / reschedule (paper §5).

Design goals, verbatim from §5.1:

  - many jobs scheduled & running at the same time on ONE clone of the repo,
  - track which outputs belong to which job; refuse conflicting outputs at
    schedule time (the §5.5 N/P checks, persisted in the job DB),
  - one machine-actionable reproducibility record per job in the history,
  - no version-control commands inside jobs — the job script itself is the
    subject of (re-)execution.

Plus §5.6 array jobs, §5.7 ``--alt-dir`` staging, §5.8 per-job branches and
octopus merges, and straggler detection/rescheduling (our beyond-paper
addition for 1000+-node operation).
"""
from __future__ import annotations

import os
import shutil
import statistics
import time
from dataclasses import dataclass

from . import slurm as S
from .conflicts import WildcardOutputError, has_wildcard, normalize
from .jobdb import JobDB
from .records import TITLE_SLURM, RunRecord
from .repo import Repository


class ScheduleError(ValueError):
    pass


@dataclass
class FinishResult:
    job_id: int
    slurm_id: int
    state: str
    commit: str | None
    branch: str | None = None


class SlurmScheduler:
    """``cli_startup_s`` models the per-invocation cost the paper measures
    for the DataLad CLI — Python package loading + repository state check
    (§6 steps (1)-(2), ~0.35 s) — charged on the *virtual* clock. Our port is
    an in-process library, so the real wall cost is ~20-50 µs (see
    benchmarks/run.py, the ``us_per_call`` column); the charge keeps the
    simulated figures 1:1 comparable with the paper's plots. Set to 0.0 to
    benchmark the library itself."""

    def __init__(self, repo: Repository, cluster: S.SlurmCluster,
                 cli_startup_s: float = 0.35):
        self.repo = repo
        self.cluster = cluster
        self.cli_startup_s = cli_startup_s
        self.db = JobDB(repo.repro_dir)

    def _charge_cli(self) -> None:
        if self.cli_startup_s:
            self.repo.fs.clock.charge(self.cli_startup_s)

    # ------------------------------------------------------------- schedule
    def schedule(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
        time_limit_s: float | None = None,
    ) -> int:
        """``datalad slurm-schedule``: validate, conflict-check, stage, submit.

        Returns the job DB id. Output specification is mandatory (§5.2) and
        wildcards are rejected (§5.4). Inputs are annex-fetched if needed.
        """
        self._charge_cli()
        if not outputs:
            raise ScheduleError("output specification is mandatory (paper §5.2)")
        for o in outputs:
            if has_wildcard(o):
                raise WildcardOutputError(o)
        inputs = list(inputs or [])
        for i in inputs:
            if not has_wildcard(i):  # inputs may be wildcards like datalad run
                abspath = os.path.join(self.repo.root, i)
                if not os.path.exists(abspath):
                    raise ScheduleError(f"input does not exist: {i}")
                if os.path.isfile(abspath):
                    self.repo.annex_get(i)  # step (1) of datalad run, §3

        # conflict check + protection, atomic in the job DB (§5.3/§5.5)
        job_id = self.db.add_job(
            script=script,
            outputs=outputs,
            inputs=inputs,
            script_args=script_args,
            pwd=pwd,
            alt_dir=alt_dir,
            array_n=array_n,
            message=message,
        )

        # unlock outputs that already exist so the job may overwrite them
        for o in outputs:
            self.repo.unlock(normalize(o))

        workdir = os.path.normpath(os.path.join(self.repo.root, pwd))
        if alt_dir:
            workdir = self._stage_alt_dir(alt_dir, pwd, script, inputs)

        slurm_id = self.cluster.sbatch(
            script, workdir=workdir, args=script_args, array_n=array_n,
            time_limit_s=time_limit_s,
        )
        self.db.set_slurm_id(job_id, slurm_id)
        return job_id

    def _stage_alt_dir(
        self, alt_dir: str, pwd: str, script: str, inputs: list[str]
    ) -> str:
        """§5.7: construct the real working directory under ``alt_dir`` with
        the same relative path, deep-copy script + inputs, submit from there.
        The repository itself stays on the (fast, local) file system."""
        real_workdir = os.path.normpath(os.path.join(alt_dir, pwd))
        os.makedirs(real_workdir, exist_ok=True)
        fs = self.repo.fs
        to_copy = list(inputs)
        script_rel = os.path.normpath(os.path.join(pwd, script))
        if os.path.exists(os.path.join(self.repo.root, script_rel)):
            to_copy.append(script_rel)
        for rel in to_copy:
            src = os.path.join(self.repo.root, os.path.normpath(os.path.join(".", rel)))
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, self.repo.root)
                        fs.copy_file(s, os.path.join(alt_dir, r))
            elif os.path.exists(src):
                r = os.path.relpath(src, self.repo.root)
                fs.copy_file(src, os.path.join(alt_dir, r))
        return real_workdir

    # --------------------------------------------------------------- finish
    def finish(
        self,
        job_id: int | None = None,
        slurm_job_id: int | None = None,
        close_failed_jobs: bool = False,
        commit_failed_jobs: bool = False,
        branches: bool = False,
        octopus: bool = False,
        engine: str = "incremental",
    ) -> list[FinishResult]:
        """``datalad slurm-finish``: commit results of finished jobs.

        Running jobs are ignored (they stay for a future call). Failed jobs
        require ``close_failed_jobs`` (drop + unprotect) or
        ``commit_failed_jobs`` (commit like a success); otherwise they stay in
        the DB and their outputs remain protected (§5.2).

        All committable jobs in one call share a single batched commit pass:
        the base tree is read once, each job's changes are applied
        incrementally (O(changed paths x depth) per job), and per-job commits
        are chained in memory — plus one octopus merge when requested —
        instead of N independent full-tree rebuilds. The branch ref is
        published before each job is closed in the DB, so a crash mid-batch
        never leaves a closed job with an unreachable commit.
        ``engine="full"`` routes every commit through the seed-era full
        rebuild instead (used by benchmarks to measure the legacy path).
        """
        self._charge_cli()
        jobs = self.db.open_jobs()
        if job_id is not None:
            jobs = [j for j in jobs if j["job_id"] == job_id]
        if slurm_job_id is not None:
            jobs = [j for j in jobs if j["slurm_id"] == slurm_job_id]
        results: list[FinishResult] = []
        to_commit: list[tuple[dict, str]] = []
        for job in jobs:
            state = self.cluster.sacct(job["slurm_id"])
            if state not in S.TERMINAL:
                continue  # still pending/running -> a future slurm-finish
            if state != S.COMPLETED and not (close_failed_jobs or commit_failed_jobs):
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue  # outputs stay protected (§5.2)
            if state != S.COMPLETED and close_failed_jobs:
                self.db.close_job(job["job_id"], status=f"closed-{state.lower()}")
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue
            to_commit.append((job, state))
        results += self._commit_jobs_batched(
            to_commit, use_branch=branches or octopus, octopus=octopus,
            engine=engine,
        )
        return results

    def _commit_jobs_batched(
        self,
        to_commit: list[tuple[dict, str]],
        use_branch: bool,
        octopus: bool,
        engine: str = "incremental",
    ) -> list[FinishResult]:
        """One commit per job (§5.1: one reproducibility record each), but the
        whole batch shares one base-tree read. The branch ref is written per
        commit, *before* the job is closed — crash-safety over batching; do
        not hoist it out of the loop."""
        if engine not in ("incremental", "full"):
            raise ValueError(f"unknown commit engine: {engine!r}")
        if not to_commit:
            return []
        repo = self.repo
        branch = repo.current_branch()
        base = repo.branch_head(branch)
        base_tree = repo._tree_oid_of(base)
        head_commit, head_tree = base, base_tree
        results: list[FinishResult] = []
        new_branches: list[str] = []
        for job, state in to_commit:
            message, save_paths = self._job_record(job, state)
            if engine == "full":
                # seed-era path, one full-tree rebuild per job (benchmarks)
                branch_name = None
                if use_branch:
                    branch_name = f"job/{job['slurm_id']}"
                    repo.create_branch(branch_name, at=base)
                    new_branches.append(branch_name)
                commit = repo.save(
                    paths=save_paths, message=message, branch=branch_name,
                    engine="full",
                )
            else:
                changes = repo.stage_paths(save_paths)
                branch_name = None
                if use_branch:
                    # per-job branches all root at the shared base (§5.8)
                    branch_name = f"job/{job['slurm_id']}"
                    repo.create_branch(branch_name, at=base)
                    commit, _ = repo.commit_changes(
                        changes, message=message, base_commit=base, base_tree=base_tree
                    )
                    repo.set_branch(branch_name, commit)
                    new_branches.append(branch_name)
                else:
                    commit, tree = repo.commit_changes(
                        changes, message=message,
                        base_commit=head_commit, base_tree=head_tree,
                    )
                    head_commit, head_tree = commit, tree
                    # publish before closing the job: a closed job must always
                    # have its commit reachable, even if the process dies here
                    repo.set_branch(branch, commit)
            self.db.close_job(job["job_id"], status="finished")
            results.append(
                FinishResult(job["job_id"], job["slurm_id"], state, commit, branch_name)
            )
        if octopus and new_branches:
            repo.merge_octopus(
                new_branches, message=f"octopus merge of {len(new_branches)} slurm jobs"
            )
        return results

    def _job_record(self, job: dict, state: str) -> tuple[str, list[str]]:
        """Reproducibility record message (§5.2) + the existing output paths
        to stage for one finished job."""
        slurm_id = job["slurm_id"]
        pwd = job["pwd"]
        slurm_outputs = [
            os.path.normpath(os.path.join(pwd, f))
            for f in self.cluster.slurm_output_files(slurm_id)
        ]
        if job["alt_dir"]:
            self._copy_back_alt_dir(job, slurm_outputs)
        record = RunRecord(
            cmd=f"sbatch {job['script']}"
            + (f" {job['script_args']}" if job["script_args"] else ""),
            dsid=self.repo.dsid,
            inputs=job["inputs"],
            outputs=job["outputs"] + slurm_outputs,
            exit=0 if state == S.COMPLETED else 1,
            pwd=pwd,
            slurm_job_id=slurm_id,
            slurm_outputs=[os.path.basename(f) for f in slurm_outputs],
            extras={
                "script": job["script"],
                "script_args": job["script_args"],
                "array_n": job["array_n"],
                "alt_dir": job["alt_dir"],
            },
        )
        message = record.to_message(
            f"Slurm job {slurm_id}: {state.capitalize()}", kind=TITLE_SLURM
        )
        save_paths = [
            p for p in job["outputs"] + slurm_outputs
            if os.path.exists(os.path.join(self.repo.root, p))
        ]
        return message, save_paths

    def _copy_back_alt_dir(self, job: dict, slurm_outputs: list[str]) -> None:
        """§5.7 step (4): copy output files from the alternative directory
        back into the repository."""
        fs = self.repo.fs
        for rel in job["outputs"] + slurm_outputs:
            src = os.path.join(job["alt_dir"], rel)
            dst = os.path.join(self.repo.root, rel)
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, job["alt_dir"])
                        fs.copy_file(s, os.path.join(self.repo.root, r))
            elif os.path.exists(src):
                fs.copy_file(src, dst)

    # ----------------------------------------------------------- inspection
    def list_open_jobs(self) -> list[tuple[dict, str]]:
        """``--list-open-jobs``: scheduled jobs + their current Slurm state."""
        return [(j, self.cluster.sacct(j["slurm_id"])) for j in self.db.open_jobs()]

    # ----------------------------------------------------------- reschedule
    def reschedule(
        self,
        commitish: str | None = None,
        since: str | None = None,
        alt_dir: str | None = "__same__",
    ) -> list[int]:
        """``datalad slurm-reschedule``: schedule job(s) again from their
        reproducibility records (§5.2). Uses the *current* version of the job
        script, schedules from the recorded ``pwd``, and re-applies all
        conflict checks. Defaults to the most recent slurm job; ``since``
        reschedules every slurm job after that commit."""
        records = self._find_slurm_records(commitish, since)
        if not records:
            raise ScheduleError("no slurm reproducibility records found")
        new_ids = []
        for rec in records:
            outputs = [
                o for o in rec.outputs
                if o not in (rec.slurm_outputs or [])
                and not os.path.basename(o).startswith(("log.slurm-", "slurm-job-"))
            ]
            ad = rec.extras.get("alt_dir") if alt_dir == "__same__" else alt_dir
            new_ids.append(
                self.schedule(
                    script=rec.extras.get("script", rec.cmd.removeprefix("sbatch ").split()[0]),
                    outputs=outputs,
                    inputs=rec.inputs,
                    script_args=rec.extras.get("script_args", ""),
                    pwd=rec.pwd,
                    alt_dir=ad,
                    array_n=int(rec.extras.get("array_n", 1)),
                    message=f"reschedule of slurm job {rec.slurm_job_id}",
                )
            )
        return new_ids

    def _find_slurm_records(
        self, commitish: str | None, since: str | None
    ) -> list[RunRecord]:
        if commitish is not None:
            commit = self.repo.objects.get_commit(self.repo.resolve(commitish))
            rec = RunRecord.from_message(commit["message"])
            if rec is None or rec.slurm_job_id is None:
                raise ScheduleError(f"{commitish} has no slurm reproducibility record")
            return [rec]
        stop = self.repo.resolve(since) if since else None
        found = []
        for oid, commit in self.repo.log():
            if oid == stop:
                break
            rec = RunRecord.from_message(commit["message"])
            if rec is not None and rec.slurm_job_id is not None:
                found.append(rec)
                if since is None:
                    break  # only the most recent
        return list(reversed(found))

    # ----------------------------------------------------- straggler handling
    def find_stragglers(self, factor: float = 3.0, min_samples: int = 3) -> list[dict]:
        """Beyond-paper: flag RUNNING jobs whose elapsed time exceeds
        ``factor`` x the median runtime of completed jobs."""
        runtimes = []
        open_jobs = self.db.open_jobs()
        for job in open_jobs:
            if self.cluster.sacct(job["slurm_id"]) == S.COMPLETED:
                rt = self.cluster.job_runtime(job["slurm_id"])
                if rt:
                    runtimes.append(rt)
        if len(runtimes) < min_samples:
            return []
        median = statistics.median(runtimes)
        stragglers = []
        for job in open_jobs:
            if self.cluster.sacct(job["slurm_id"]) == S.RUNNING:
                rt = self.cluster.job_runtime(job["slurm_id"]) or 0.0
                if rt > factor * median:
                    stragglers.append(job)
        return stragglers

    def reschedule_straggler(self, job_id: int) -> int:
        """Cancel a straggling job, release its outputs, and submit a fresh
        copy with the same specification."""
        job = self.db.get(job_id)
        if job is None:
            raise ScheduleError(f"unknown job {job_id}")
        self.cluster.scancel(job["slurm_id"])
        self.db.close_job(job_id, status="cancelled-straggler")
        return self.schedule(
            script=job["script"],
            outputs=job["outputs"],
            inputs=job["inputs"],
            script_args=job["script_args"],
            pwd=job["pwd"],
            alt_dir=job["alt_dir"],
            array_n=job["array_n"],
            message=f"straggler reschedule of job {job_id}",
        )
