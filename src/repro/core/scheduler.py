"""The DataLad-Slurm protocol: schedule / finish / reschedule (paper §5).

Design goals, verbatim from §5.1:

  - many jobs scheduled & running at the same time on ONE clone of the repo,
  - track which outputs belong to which job; refuse conflicting outputs at
    schedule time (the §5.5 N/P checks, persisted in the job DB),
  - one machine-actionable reproducibility record per job in the history,
  - no version-control commands inside jobs — the job script itself is the
    subject of (re-)execution.

Plus §5.6 array jobs, §5.7 ``--alt-dir`` staging, §5.8 per-job branches and
octopus merges, and straggler detection/rescheduling (our beyond-paper
addition for 1000+-node operation).

Since the spec layer, the submission surface is declarative:
:meth:`SlurmScheduler.submit` takes a validated script
:class:`~repro.core.spec.RunSpec`, and :meth:`submit_many` amortizes a whole
batch — ONE CLI-startup charge, ONE job-database transaction, and ONE shared
§5.5 conflict pass for N jobs. The stored spec rides through the job DB and
the finish-time provenance record, so ``reschedule`` and straggler
resubmission replay the *exact* original spec. The legacy keyword
``schedule(...)`` signature remains as a thin shim that builds a spec and
delegates.
"""
from __future__ import annotations

import os
import statistics
from dataclasses import dataclass

from . import slurm as S
from .jobdb import JobDB, job_spec
from .records import TITLE_SLURM, RunRecord, spec_of
from .repo import Repository
from .spec import RunSpec, SpecError

class ScheduleError(SpecError):
    """Operational scheduling error (unknown job, no records to reschedule,
    missing input, ...). Subclasses :class:`SpecError` so existing callers
    that catch the scheduler's historical error type keep working; the
    legacy ``schedule(...)`` shim also surfaces spec-construction failures
    as this type."""


@dataclass
class FinishResult:
    job_id: int
    slurm_id: int
    state: str
    commit: str | None
    branch: str | None = None


class SlurmScheduler:
    """``cli_startup_s`` models the per-invocation cost the paper measures
    for the DataLad CLI — Python package loading + repository state check
    (§6 steps (1)-(2), ~0.35 s) — charged on the *virtual* clock. Our port is
    an in-process library, so the real wall cost is ~20-50 µs (see
    benchmarks/run.py, the ``us_per_call`` column); the charge keeps the
    simulated figures 1:1 comparable with the paper's plots. Set to 0.0 to
    benchmark the library itself. ``submit_many`` charges it ONCE per batch —
    the amortization a one-CLI-call-per-job workflow cannot have."""

    def __init__(self, repo: Repository, cluster: S.SlurmCluster,
                 cli_startup_s: float = 0.35,
                 auto_repack_threshold: int | None = None):
        self.repo = repo
        self.cluster = cluster
        self.cli_startup_s = cli_startup_s
        # max loose-shard entry count tolerated before finish() compacts the
        # object store after its commit batch (DESIGN.md §8). None disables
        # auto-repack — measurement runs want the aging slope observable.
        self.auto_repack_threshold = auto_repack_threshold
        self.db = JobDB(repo.repro_dir)

    def _charge_cli(self) -> None:
        if self.cli_startup_s:
            self.repo.fs.clock.charge(self.cli_startup_s)

    # ------------------------------------------------------------- submit
    def submit(self, spec: RunSpec) -> int:
        """Validate, conflict-check, stage, and submit one script spec.
        Returns the job DB id."""
        return self.submit_many([spec])[0]

    def submit_many(self, specs: list[RunSpec]) -> list[int]:
        """Batched submission: N specs, ONE CLI-startup charge, ONE job-DB
        transaction, ONE shared §5.5 conflict pass (see ``JobDB.add_jobs``).

        Specs are protected atomically before anything is handed to Slurm.
        If ``sbatch`` (or alt-dir staging) fails mid-batch, the failed job
        and every not-yet-submitted job are closed in the DB (releasing
        their output protection) and the failed job's outputs are re-locked;
        already-submitted jobs keep their slurm ids and stay scheduled.

        Crash note: slurm ids are persisted once per batch (the one-
        transaction contract), so a *hard* crash (kill -9, power loss) mid-
        batch can leave rows with a NULL slurm id whose jobs ARE running.
        ``finish`` reports such rows as ``"UNKNOWN"`` and only
        ``close_failed_jobs=True`` closes them — before using it after a
        crash, check the queue (``squeue``/``sacct``) for orphans, since
        closing releases their output protection.
        """
        specs = list(specs)
        for spec in specs:
            if not isinstance(spec, RunSpec):
                raise ScheduleError(f"submit expects RunSpec instances, got {type(spec).__name__}")
            if spec.script is None:
                raise ScheduleError(
                    "batch submission requires a script spec (cmd specs are "
                    "for blocking run/rerun)"
                )
        self._charge_cli()  # one startup charge for the whole batch
        for spec in specs:  # cheap existence probe before any DB or fetch work
            missing = spec.missing_inputs(self.repo.root)
            if missing:
                raise ScheduleError(f"input does not exist: {missing[0]}")

        # conflict check + protection, atomic in the job DB (§5.3/§5.5):
        # one transaction, each output checked exactly once — BEFORE the
        # potentially expensive annex fetches, so a conflicting batch is
        # refused without moving any data
        job_ids = self.db.add_jobs(specs)

        submitted: list[tuple[int, int]] = []
        unlocked = False  # did the currently failing spec get its outputs unlocked?
        try:
            for idx, spec in enumerate(specs):
                unlocked = False
                inputs = self._fetch_inputs(spec)
                # unlock outputs that already exist so the job may overwrite
                unlocked = True
                for o in spec.outputs:
                    self.repo.unlock(o)
                slurm_id = self._submit_one(spec, inputs)
                submitted.append((job_ids[idx], slurm_id))
        except BaseException:
            # submission failed: persist what did get submitted, then close
            # the failed + never-submitted jobs so their rows don't linger
            # and their protected outputs are released (and re-locked, if
            # the failure happened after the unlock)
            self.db.set_slurm_ids(submitted)
            failed_idx = len(submitted)
            for idx in range(failed_idx, len(specs)):
                self.db.close_job(job_ids[idx], status="submit-failed")
            if unlocked:
                for o in specs[failed_idx].outputs:
                    self.repo.lock(o)
            raise
        self.db.set_slurm_ids(submitted)  # one transaction for the batch
        return job_ids

    def _fetch_inputs(self, spec: RunSpec) -> list[str]:
        """Resolve + annex-fetch a spec's inputs (step (1) of datalad run,
        §3). Wildcards glob-expand like ``datalad run``; a missing literal
        input raises (``submit_many`` pre-checks existence before any DB
        work, so this only fires on a race)."""
        expanded = spec.expand_inputs(self.repo.root)
        for i in expanded:
            if os.path.isfile(os.path.join(self.repo.root, i)):
                self.repo.annex_get(i)
        return expanded

    def _submit_one(self, spec: RunSpec, inputs: list[str]) -> int:
        """Stage alt-dir and sbatch (outputs already unlocked by the caller).
        Returns the slurm id."""
        workdir = os.path.normpath(os.path.join(self.repo.root, spec.pwd))
        if spec.alt_dir:
            workdir = self._stage_alt_dir(spec.alt_dir, spec.pwd, spec.script, inputs)
        return self.cluster.sbatch(
            spec.script, workdir=workdir, args=spec.script_args,
            array_n=spec.array_n, time_limit_s=spec.time_limit_s,
            env=dict(spec.env) or None,
        )

    # ----------------------------------------------------------- schedule
    def schedule(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
        time_limit_s: float | None = None,
        env: dict | None = None,
    ) -> int:
        """``datalad slurm-schedule`` — legacy keyword shim over
        :meth:`submit`. Builds a validated :class:`RunSpec` and delegates;
        output mandatoriness (§5.2) and wildcard rejection (§5.4) are
        enforced by spec construction."""
        try:
            spec = RunSpec(
                script=script,
                script_args=script_args,
                inputs=tuple(inputs or ()),
                outputs=tuple(outputs),
                pwd=pwd,
                alt_dir=alt_dir,
                array_n=array_n,
                message=message,
                time_limit_s=time_limit_s,
                env=tuple((env or {}).items()),
            )
        except ScheduleError:
            raise
        except SpecError as e:
            # the shim's historical error type for an invalid submission
            raise ScheduleError(str(e)) from e
        return self.submit(spec)

    def _stage_alt_dir(
        self, alt_dir: str, pwd: str, script: str, inputs: list[str]
    ) -> str:
        """§5.7: construct the real working directory under ``alt_dir`` with
        the same relative path, deep-copy script + inputs, submit from there.
        The repository itself stays on the (fast, local) file system."""
        real_workdir = os.path.normpath(os.path.join(alt_dir, pwd))
        os.makedirs(real_workdir, exist_ok=True)
        fs = self.repo.fs
        to_copy = list(inputs)
        script_rel = os.path.normpath(os.path.join(pwd, script))
        if os.path.exists(os.path.join(self.repo.root, script_rel)):
            to_copy.append(script_rel)
        for rel in to_copy:
            src = os.path.join(self.repo.root, os.path.normpath(os.path.join(".", rel)))
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, self.repo.root)
                        fs.copy_file(s, os.path.join(alt_dir, r))
            elif os.path.exists(src):
                r = os.path.relpath(src, self.repo.root)
                fs.copy_file(src, os.path.join(alt_dir, r))
        return real_workdir

    # --------------------------------------------------------------- finish
    def finish(
        self,
        job_id: int | None = None,
        slurm_job_id: int | None = None,
        close_failed_jobs: bool = False,
        commit_failed_jobs: bool = False,
        branches: bool = False,
        octopus: bool = False,
        engine: str = "incremental",
    ) -> list[FinishResult]:
        """``datalad slurm-finish``: commit results of finished jobs.

        Running jobs are ignored (they stay for a future call). Failed jobs
        require ``close_failed_jobs`` (drop + unprotect) or
        ``commit_failed_jobs`` (commit like a success); otherwise they stay in
        the DB and their outputs remain protected (§5.2).

        All committable jobs in one call share a single batched commit pass:
        the base tree is read once, each job's changes are applied
        incrementally (O(changed paths x depth) per job), and per-job commits
        are chained in memory — plus one octopus merge when requested —
        instead of N independent full-tree rebuilds. The branch ref is
        published before each job is closed in the DB, so a crash mid-batch
        never leaves a closed job with an unreachable commit.
        ``engine="full"`` routes every commit through the seed-era full
        rebuild instead (used by benchmarks to measure the legacy path).
        """
        self._charge_cli()
        jobs = self.db.open_jobs()
        if job_id is not None:
            jobs = [j for j in jobs if j["job_id"] == job_id]
        if slurm_job_id is not None:
            jobs = [j for j in jobs if j["slurm_id"] == slurm_job_id]
        # one batched accounting query for the whole candidate set
        states = self.cluster.sacct_many(
            [j["slurm_id"] for j in jobs if j["slurm_id"] is not None]
        )
        results: list[FinishResult] = []
        to_commit: list[tuple[dict, str]] = []
        for job in jobs:
            if job["slurm_id"] is None:
                # a crash between add_jobs and set_slurm_ids left this row
                # without a submission id; it cannot be queried or committed.
                # close_failed_jobs is the recovery path.
                if close_failed_jobs:
                    self.db.close_job(job["job_id"], status="closed-unsubmitted")
                results.append(FinishResult(job["job_id"], -1, "UNKNOWN", None))
                continue
            state = states[job["slurm_id"]]
            if state not in S.TERMINAL:
                continue  # still pending/running -> a future slurm-finish
            if state != S.COMPLETED and not (close_failed_jobs or commit_failed_jobs):
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue  # outputs stay protected (§5.2)
            if state != S.COMPLETED and close_failed_jobs:
                self.db.close_job(job["job_id"], status=f"closed-{state.lower()}")
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue
            to_commit.append((job, state))
        results += self._commit_jobs_batched(
            to_commit, use_branch=branches or octopus, octopus=octopus,
            engine=engine,
        )
        if to_commit:
            self.maybe_repack()
        return results

    def maybe_repack(self) -> dict | None:
        """Threshold-based compaction (DESIGN.md §8), amortized over finish
        batches: when any loose shard's entry count exceeds
        ``auto_repack_threshold``, migrate loose objects into a pack so new
        writes stop paying the directory-pressure degradation. Runs AFTER
        the batch's refs are published; crash-safe by repack's
        pack-before-unlink ordering. Returns repack stats, or None."""
        thr = self.auto_repack_threshold
        if thr is None or self.repo.objects.loose_pressure() <= thr:
            return None
        return self.repo.objects.repack()

    def _commit_jobs_batched(
        self,
        to_commit: list[tuple[dict, str]],
        use_branch: bool,
        octopus: bool,
        engine: str = "incremental",
    ) -> list[FinishResult]:
        """One commit per job (§5.1: one reproducibility record each), but the
        whole batch shares one base-tree read. The branch ref is written per
        commit, *before* the job is closed — crash-safety over batching; do
        not hoist it out of the loop."""
        if engine not in ("incremental", "full"):
            raise ValueError(f"unknown commit engine: {engine!r}")
        if not to_commit:
            return []
        repo = self.repo
        branch = repo.current_branch()
        base = repo.branch_head(branch)
        base_tree = repo._tree_oid_of(base)
        head_commit, head_tree = base, base_tree
        results: list[FinishResult] = []
        new_branches: list[str] = []
        for job, state in to_commit:
            message, save_paths, spec_json = self._job_record(job, state)
            if engine == "full":
                # seed-era path, one full-tree rebuild per job (benchmarks)
                branch_name = None
                if use_branch:
                    branch_name = f"job/{job['slurm_id']}"
                    repo.create_branch(branch_name, at=base)
                    new_branches.append(branch_name)
                commit = repo.save(
                    paths=save_paths, message=message, branch=branch_name,
                    engine="full", spec=spec_json,
                )
            else:
                changes = repo.stage_paths(save_paths)
                branch_name = None
                if use_branch:
                    # per-job branches all root at the shared base (§5.8)
                    branch_name = f"job/{job['slurm_id']}"
                    repo.create_branch(branch_name, at=base)
                    commit, _ = repo.commit_changes(
                        changes, message=message, base_commit=base,
                        base_tree=base_tree, spec=spec_json,
                    )
                    repo.set_branch(branch_name, commit)
                    new_branches.append(branch_name)
                else:
                    commit, tree = repo.commit_changes(
                        changes, message=message,
                        base_commit=head_commit, base_tree=head_tree,
                        spec=spec_json,
                    )
                    head_commit, head_tree = commit, tree
                    # publish before closing the job: a closed job must always
                    # have its commit reachable, even if the process dies here
                    repo.set_branch(branch, commit)
            self.db.close_job(job["job_id"], status="finished")
            results.append(
                FinishResult(job["job_id"], job["slurm_id"], state, commit, branch_name)
            )
        if octopus and new_branches:
            repo.merge_octopus(
                new_branches, message=f"octopus merge of {len(new_branches)} slurm jobs"
            )
        return results

    def _job_record(self, job: dict, state: str) -> tuple[str, list[str], dict]:
        """Reproducibility record message (§5.2), the existing output paths
        to stage, and the originating spec JSON for one finished job."""
        spec = job_spec(job)
        slurm_id = job["slurm_id"]
        slurm_outputs = [
            os.path.normpath(os.path.join(spec.pwd, f))
            for f in self.cluster.slurm_output_files(slurm_id)
        ]
        if spec.alt_dir:
            self._copy_back_alt_dir(spec, slurm_outputs)
        spec_json = spec.to_json()
        record = RunRecord(
            cmd=spec.record_cmd,
            dsid=self.repo.dsid,
            inputs=list(spec.inputs),
            outputs=list(spec.outputs) + slurm_outputs,
            exit=0 if state == S.COMPLETED else 1,
            pwd=spec.pwd,
            spec=spec_json,
            slurm_job_id=slurm_id,
            slurm_outputs=[os.path.basename(f) for f in slurm_outputs],
            extras={
                "script": spec.script,
                "script_args": spec.script_args,
                "array_n": spec.array_n,
                "alt_dir": spec.alt_dir,
            },
        )
        message = record.to_message(
            f"Slurm job {slurm_id}: {state.capitalize()}", kind=TITLE_SLURM
        )
        save_paths = [
            p for p in list(spec.outputs) + slurm_outputs
            if os.path.exists(os.path.join(self.repo.root, p))
        ]
        return message, save_paths, spec_json

    def _copy_back_alt_dir(self, spec: RunSpec, slurm_outputs: list[str]) -> None:
        """§5.7 step (4): copy output files from the alternative directory
        back into the repository."""
        fs = self.repo.fs
        for rel in list(spec.outputs) + slurm_outputs:
            src = os.path.join(spec.alt_dir, rel)
            dst = os.path.join(self.repo.root, rel)
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, spec.alt_dir)
                        fs.copy_file(s, os.path.join(self.repo.root, r))
            elif os.path.exists(src):
                fs.copy_file(src, dst)

    # ----------------------------------------------------------- inspection
    def list_open_jobs(self) -> list[tuple[dict, str]]:
        """``--list-open-jobs``: scheduled jobs + their current Slurm state,
        polled with ONE batched accounting query. A job whose slurm id was
        never persisted (crash mid-submission) reports ``"UNKNOWN"``."""
        jobs = self.db.open_jobs()
        states = self.cluster.sacct_many(
            [j["slurm_id"] for j in jobs if j["slurm_id"] is not None]
        )
        return [
            (j, states[j["slurm_id"]] if j["slurm_id"] is not None
             else "UNKNOWN")
            for j in jobs
        ]

    # ----------------------------------------------------------- reschedule
    def reschedule(
        self,
        commitish: str | None = None,
        since: str | None = None,
        alt_dir: str | None = "__same__",
    ) -> list[int]:
        """``datalad slurm-reschedule``: schedule job(s) again from their
        provenance (§5.2). Deserializes the stored :class:`RunSpec` of each
        commit (exact replay — no message reassembly; pre-spec records fall
        back to field reconstruction), re-applies all conflict checks, and
        resubmits the whole set as ONE batch. Uses the *current* version of
        the job script. Defaults to the most recent slurm job; ``since``
        reschedules every slurm job after that commit."""
        found = self._find_slurm_records(commitish, since)
        if not found:
            raise ScheduleError("no slurm reproducibility records found")
        specs = []
        for oid, rec in found:
            spec = spec_of(self.repo, oid)
            changes: dict = {"message": f"reschedule of slurm job {rec.slurm_job_id}"}
            if alt_dir != "__same__":
                changes["alt_dir"] = alt_dir
            specs.append(spec.replace(**changes))
        return self.submit_many(specs)

    def _find_slurm_records(
        self, commitish: str | None, since: str | None
    ) -> list[tuple[str, RunRecord]]:
        if commitish is not None:
            oid = self.repo.resolve(commitish)
            commit = self.repo.objects.get_commit(oid)
            rec = RunRecord.from_message(commit["message"])
            if rec is None or rec.slurm_job_id is None:
                raise ScheduleError(f"{commitish} has no slurm reproducibility record")
            return [(oid, rec)]
        stop = self.repo.resolve(since) if since else None
        found = []
        for oid, commit in self.repo.log():
            if oid == stop:
                break
            rec = RunRecord.from_message(commit["message"])
            if rec is not None and rec.slurm_job_id is not None:
                found.append((oid, rec))
                if since is None:
                    break  # only the most recent
        return list(reversed(found))

    # ----------------------------------------------------- straggler handling
    def find_stragglers(self, factor: float = 3.0, min_samples: int = 3) -> list[dict]:
        """Beyond-paper: flag RUNNING jobs whose elapsed time exceeds
        ``factor`` x the median runtime of completed jobs."""
        runtimes = []
        open_jobs = [j for j in self.db.open_jobs() if j["slurm_id"] is not None]
        # one batched poll serves both the median scan and the straggler scan
        states = self.cluster.sacct_many([j["slurm_id"] for j in open_jobs])
        for job in open_jobs:
            if states[job["slurm_id"]] == S.COMPLETED:
                rt = self.cluster.job_runtime(job["slurm_id"])
                if rt:
                    runtimes.append(rt)
        if len(runtimes) < min_samples:
            return []
        median = statistics.median(runtimes)
        stragglers = []
        for job in open_jobs:
            if states[job["slurm_id"]] == S.RUNNING:
                rt = self.cluster.job_runtime(job["slurm_id"]) or 0.0
                if rt > factor * median:
                    stragglers.append(job)
        return stragglers

    def reschedule_straggler(self, job_id: int) -> int:
        """Cancel a straggling job, release its outputs, and submit a fresh
        copy of its exact stored spec."""
        job = self.db.get(job_id)
        if job is None:
            raise ScheduleError(f"unknown job {job_id}")
        self.cluster.scancel(job["slurm_id"])
        self.db.close_job(job_id, status="cancelled-straggler")
        spec = job_spec(job).replace(
            message=f"straggler reschedule of job {job_id}"
        )
        return self.submit(spec)
