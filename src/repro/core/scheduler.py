"""The DataLad-Slurm protocol: schedule / finish / reschedule (paper §5).

Design goals, verbatim from §5.1:

  - many jobs scheduled & running at the same time on ONE clone of the repo,
  - track which outputs belong to which job; refuse conflicting outputs at
    schedule time (the §5.5 N/P checks, persisted in the job DB),
  - one machine-actionable reproducibility record per job in the history,
  - no version-control commands inside jobs — the job script itself is the
    subject of (re-)execution.

Plus §5.6 array jobs, §5.7 ``--alt-dir`` staging, §5.8 per-job branches and
octopus merges, and straggler detection/rescheduling (our beyond-paper
addition for 1000+-node operation).

Since the spec layer, the submission surface is declarative:
:meth:`SlurmScheduler.submit` takes a validated script
:class:`~repro.core.spec.RunSpec`, and :meth:`submit_many` amortizes a whole
batch — ONE CLI-startup charge, ONE job-database transaction, and ONE shared
§5.5 conflict pass for N jobs. The stored spec rides through the job DB and
the finish-time provenance record, so ``reschedule`` and straggler
resubmission replay the *exact* original spec. The legacy keyword
``schedule(...)`` signature remains as a thin shim that builds a spec and
delegates.
"""
from __future__ import annotations

import os
import statistics
import uuid
from dataclasses import dataclass

from . import slurm as S
from .dag import Pipeline
from .faults import is_crash, is_transient
from .jobdb import JobDB, job_spec
from .records import TITLE_SLURM, RunRecord, spec_of
from .recovery import JournalHandle
from .repo import REPRO_DIR, Repository
from .runcache import RunCache
from .spec import RunSpec, SpecError

class ScheduleError(SpecError):
    """Operational scheduling error (unknown job, no records to reschedule,
    missing input, ...). Subclasses :class:`SpecError` so existing callers
    that catch the scheduler's historical error type keep working; the
    legacy ``schedule(...)`` shim also surfaces spec-construction failures
    as this type."""


@dataclass
class FinishResult:
    job_id: int
    slurm_id: int
    state: str
    commit: str | None
    branch: str | None = None


class SlurmScheduler:
    """``cli_startup_s`` models the per-invocation cost the paper measures
    for the DataLad CLI — Python package loading + repository state check
    (§6 steps (1)-(2), ~0.35 s) — charged on the *virtual* clock. Our port is
    an in-process library, so the real wall cost is ~20-50 µs (see
    benchmarks/run.py, the ``us_per_call`` column); the charge keeps the
    simulated figures 1:1 comparable with the paper's plots. Set to 0.0 to
    benchmark the library itself. ``submit_many`` charges it ONCE per batch —
    the amortization a one-CLI-call-per-job workflow cannot have."""

    def __init__(self, repo: Repository, cluster: S.SlurmCluster,
                 cli_startup_s: float = 0.35,
                 auto_repack_threshold: int | None = None,
                 ingest_workers: int = 0,
                 run_cache: bool = True,
                 cache_env: dict | None = None):
        self.repo = repo
        self.cluster = cluster
        self.cli_startup_s = cli_startup_s
        # max loose-shard entry count tolerated before finish() compacts the
        # object store after its commit batch (DESIGN.md §8). None disables
        # auto-repack — measurement runs want the aging slope observable.
        self.auto_repack_threshold = auto_repack_threshold
        # finish()'s data-plane fan-out width (DESIGN.md §9): output
        # ingestion is content-addressed and commutative, so a batch's
        # files can be ingested by a worker pool while commit chaining and
        # ref publication stay strictly ordered. 0/1 = serial (default, and
        # identical charges to the serial model).
        self.ingest_workers = ingest_workers
        self.db = JobDB(repo.repro_dir)
        # §11 run cache: execution-key memoization of finished specs.
        # run_cache=False disables lookup AND population; cache_env keys
        # executions on an environment fingerprint on top of spec + inputs.
        self.cache = RunCache(repo, self.db, cache_env) if run_cache else None

    def _charge_cli(self) -> None:
        if self.cli_startup_s:
            self.repo.fs.clock.charge(self.cli_startup_s)

    def _retry_slurm(self, fn, what: str):
        """Run one Slurm CLI interaction, retrying *transient* failures
        (a flaky slurmctld / accounting DB — DESIGN §10) with exponential
        backoff charged on the virtual clock. Permanent errors and injected
        crashes propagate immediately; the retry budget is bounded so a
        genuinely dead controller still surfaces as an error."""
        plan = getattr(self.repo.fs, "faults", None)
        retries = plan.max_slurm_retries if plan is not None else 3
        base = plan.backoff_base_s if plan is not None else 0.05
        attempt = 0
        while True:
            try:
                return fn()
            except BaseException as e:
                if is_crash(e) or not is_transient(e) or attempt >= retries:
                    raise
                self.repo.fs.clock.charge(base * (2 ** attempt))
                attempt += 1

    # ------------------------------------------------------------- submit
    def submit(self, spec: RunSpec, refresh: bool = False) -> int:
        """Validate, conflict-check, stage, and submit one script spec.
        Returns the job DB id."""
        return self.submit_many([spec], refresh=refresh)[0]

    def submit_many(
        self,
        specs: list[RunSpec],
        refresh: bool = False,
        dependencies: list[list[int]] | None = None,
        provided: set[str] | None = None,
        pipeline: str | None = None,
        stages: list[str] | None = None,
    ) -> list[int]:
        """Batched submission: N specs, ONE CLI-startup charge, ONE job-DB
        transaction, ONE shared §5.5 conflict pass (see ``JobDB.add_jobs``).

        Run cache (§11): each spec's execution key (spec_id + resolved
        input tree + env fingerprint) is looked up first; hits short-circuit
        into a memoized provenance commit — their recorded output tree is
        materialized from the object store/annex and the row closes as
        ``memoized`` — while only novel specs reach sbatch. ``refresh=True``
        bypasses the lookup (every spec re-executes) but still records the
        batch's results so the cache stays warm.

        Specs are protected atomically before anything is handed to Slurm.
        If ``sbatch`` (or alt-dir staging) fails mid-batch, the failed job
        and every not-yet-submitted job are closed in the DB (releasing
        their output protection) and the failed job's outputs are re-locked;
        already-submitted jobs keep their slurm ids and stay scheduled.

        Crash note: slurm ids are persisted once per batch (the one-
        transaction contract), so a *hard* crash (kill -9, power loss) mid-
        batch can leave rows with a NULL slurm id whose jobs ARE running.
        ``finish`` reports such rows as ``"UNKNOWN"`` and only
        ``close_failed_jobs=True`` closes them — before using it after a
        crash, check the queue (``squeue``/``sacct``) for orphans, since
        closing releases their output protection.

        Pipeline plumbing (§14, used by ``submit_pipeline``):
        ``dependencies[i]`` lists parent *slurm* ids for spec i (afterok);
        ``provided`` is the set of upstream-declared outputs — inputs under
        it are not "missing" even though they don't exist yet; ``pipeline``
        and ``stages`` tag the rows for dag-journal replay. A spec with a
        live dependency never consults the run cache: its inputs are about
        to be rewritten by the parent job, so a key derived from what is on
        disk *now* would be stale. Its key is derived at finish time
        instead, once the real inputs exist.
        """
        specs = list(specs)
        deps = dependencies if dependencies is not None else [[] for _ in specs]
        provided = provided or set()
        for spec in specs:
            if not isinstance(spec, RunSpec):
                raise ScheduleError(f"submit expects RunSpec instances, got {type(spec).__name__}")
            if spec.script is None:
                raise ScheduleError(
                    "batch submission requires a script spec (cmd specs are "
                    "for blocking run/rerun)"
                )
        self._charge_cli()  # one startup charge for the whole batch
        for spec in specs:  # cheap existence probe before any DB or fetch work
            missing = spec.missing_inputs(self.repo.root, provided=provided)
            if missing:
                raise ScheduleError(f"input does not exist: {missing[0]}")

        # §11: derive execution keys up front — uncacheable specs
        # (unresolvable inputs, cache disabled) key as None and always
        # submit as novel. Specs with pending afterok parents are forced
        # uncacheable here (stale-input guard, see docstring).
        if self.cache is not None:
            exec_keys = self.cache.execution_keys(specs)
            exec_keys = [
                None if deps[i] else k for i, k in enumerate(exec_keys)
            ]
        else:
            exec_keys = [None] * len(specs)

        # conflict check + protection, atomic in the job DB (§5.3/§5.5):
        # one transaction, each output checked exactly once — BEFORE the
        # potentially expensive annex fetches, so a conflicting batch is
        # refused without moving any data
        job_ids = self.db.add_jobs(
            specs, exec_keys=exec_keys, pipeline=pipeline, stages=stages
        )
        fs = self.repo.fs
        fs.crash_point("submit:jobs-added")

        # cache-hit short-circuit (§11): memoized specs never reach Slurm —
        # their recorded result is republished as provenance right here and
        # the rows close as 'memoized'; only novel specs continue to sbatch
        hit_rows = (
            self.cache.lookup(exec_keys)
            if self.cache is not None and not refresh
            else {}
        )
        if hit_rows:
            self._publish_memoized([
                (job_ids[i], specs[i], exec_keys[i], hit_rows[exec_keys[i]])
                for i in range(len(specs))
                if exec_keys[i] in hit_rows
            ])
        novel = [i for i in range(len(specs)) if exec_keys[i] not in hit_rows]
        if not novel:
            return job_ids

        # intent journal (DESIGN §10): each slurm id is journaled the moment
        # sbatch hands it out, so a hard crash before the batched
        # set_slurm_ids transaction no longer orphans running jobs —
        # Session.recover() replays the pairs instead of guessing
        jh = JournalHandle.begin(
            fs, self.repo.repro_dir, "submit",
            {"job_ids": [job_ids[i] for i in novel]},
        )

        submitted: list[tuple[int, int]] = []
        unlocked = False  # did the currently failing spec get its outputs unlocked?
        try:
            for idx in novel:
                spec = specs[idx]
                unlocked = False
                inputs = self._fetch_inputs(spec, provided=provided)
                # unlock outputs that already exist so the job may overwrite
                unlocked = True
                for o in spec.outputs:
                    self.repo.unlock(o)
                slurm_id = self._retry_slurm(
                    lambda: self._submit_one(spec, inputs, deps[idx]), "sbatch"
                )
                jh.append({"job_id": job_ids[idx], "slurm_id": slurm_id})
                fs.crash_point("submit:after-sbatch")
                submitted.append((job_ids[idx], slurm_id))
        except BaseException as e:
            if is_crash(e):
                raise  # dead process: no cleanup; recover() replays the journal
            # submission failed: persist what did get submitted, then close
            # the failed + never-submitted jobs so their rows don't linger
            # and their protected outputs are released (and re-locked, if
            # the failure happened after the unlock)
            self.db.set_slurm_ids(submitted)
            failed = novel[len(submitted):]  # failing spec first, then the rest
            for idx in failed:
                self.db.close_job(job_ids[idx], status="submit-failed")
            if unlocked and failed:
                for o in specs[failed[0]].outputs:
                    self.repo.lock(o)
            jh.done()  # the DB now tells the whole story
            raise
        fs.crash_point("submit:before-set-ids")
        self.db.set_slurm_ids(submitted)  # one transaction for the batch
        fs.crash_point("submit:after-set-ids")
        jh.done()
        return job_ids

    # ---------------------------------------------------- pipelines (§14)
    def submit_pipeline(
        self,
        pipeline: Pipeline,
        refresh: bool = False,
        run_id: str | None = None,
    ) -> dict[str, int]:
        """Submit a whole :class:`~repro.core.dag.Pipeline` as topologically
        batched ``submit_many`` calls — one batch per level, so an L-level
        DAG costs L CLI charges however many stages it has — with
        ``afterok`` dependency edges between levels.

        Cache cutting (§11 x §14): levels are submitted in topological
        order without waiting, so a stage whose parents all short-circuited
        as ``memoized`` sees its inputs already materialized and gets its
        own cache lookup; a stage chained to a *real* job submits uncached
        with an afterok edge. Re-submitting a partially failed campaign
        therefore re-executes exactly the failed stage's downstream cone —
        everything else replays from the run cache.

        The whole submission runs under an intent journal (kind ``dag``,
        DESIGN §10): the header carries the full stage specs and edge list,
        each level appends its job ids once landed, and ``recover()``
        resubmits only the levels the crash prevented — already-landed
        levels are found by their pipeline/stage row tags and reused.

        Returns {stage name: job DB id}.
        """
        levels = pipeline.levels()
        pid = run_id or (
            f"{pipeline.pipeline_id[:12]}-{uuid.uuid4().hex[:8]}"
        )
        fs = self.repo.fs
        jh = JournalHandle.begin(
            fs, self.repo.repro_dir, "dag",
            {
                "pipeline": pid,
                "stages": {n: s.to_json() for n, s in pipeline.stages.items()},
                "edges": [list(e) for e in pipeline.edges()],
                "levels": levels,
                "refresh": bool(refresh),
            },
        )
        fs.crash_point("dag:journal-written")
        stage_jobs: dict[str, int] = {}
        try:
            for i, level in enumerate(levels):
                self._submit_level(
                    pipeline, pid, i, level, stage_jobs,
                    refresh=refresh, journal=jh,
                )
        except BaseException as e:
            if is_crash(e):
                raise  # dead process: recover() resumes from the journal
            # a soft failure (conflict, sbatch error) already closed the
            # failing level's rows inside submit_many; earlier levels stay
            # queued and valid, so the DB tells the whole story — retire
            # the journal rather than have recovery resubmit a submission
            # the caller saw fail
            jh.done()
            raise
        fs.crash_point("dag:before-done")
        jh.done()
        return stage_jobs

    def _submit_level(
        self,
        pipeline: Pipeline,
        pid: str,
        level_idx: int,
        level: list[str],
        stage_jobs: dict[str, int],
        refresh: bool = False,
        journal: JournalHandle | None = None,
    ) -> list[str]:
        """Submit one topological level (shared by ``submit_pipeline`` and
        dag-journal replay). Mutates ``stage_jobs`` with the landed ids and
        returns the stages *skipped* because their parent chain is dead
        (failed/cancelled rows upstream — only possible during replay)."""
        fs = self.repo.fs
        names: list[str] = []
        specs: list[RunSpec] = []
        deps: list[list[int]] = []
        skipped: list[str] = []
        for name in level:
            dep_ids: list[int] = []
            alive = True
            for p in pipeline.parents[name]:
                prow = self.db.get(stage_jobs[p]) if p in stage_jobs else None
                if prow is None:
                    alive = False  # parent never landed: dead cone
                    break
                if prow["status"] == "scheduled" and prow["slurm_id"] is not None:
                    dep_ids.append(prow["slurm_id"])
                elif prow["status"] in ("finished", "memoized"):
                    continue  # satisfied: outputs exist on disk
                else:
                    alive = False  # parent closed failed/cancelled
                    break
            if alive:
                names.append(name)
                specs.append(pipeline.stages[name])
                deps.append(dep_ids)
            else:
                skipped.append(name)
        if not names:
            return skipped
        provided: set[str] = set()
        for name in names:
            provided |= pipeline.upstream_outputs(name)
        ids = self.submit_many(
            specs, refresh=refresh, dependencies=deps,
            provided=provided, pipeline=pid, stages=names,
        )
        fs.crash_point("dag:level-submitted")
        stage_jobs.update(zip(names, ids))
        self.db.add_deps(
            [
                (stage_jobs[c], stage_jobs[p])
                for c in names
                for p in pipeline.parents[c]
                if p in stage_jobs
            ],
            pipeline=pid,
        )
        fs.crash_point("dag:deps-recorded")
        if journal is not None:
            journal.append({
                "level": level_idx,
                "jobs": {n: stage_jobs[n] for n in names},
                "skipped": skipped,
            })
            fs.crash_point("dag:level-journaled")
        return skipped

    def _fetch_inputs(self, spec: RunSpec, provided: set[str] | None = None) -> list[str]:
        """Resolve + annex-fetch a spec's inputs (step (1) of datalad run,
        §3). Wildcards glob-expand like ``datalad run``; a missing literal
        input raises (``submit_many`` pre-checks existence before any DB
        work, so this only fires on a race). Inputs an upstream pipeline
        stage will produce (``provided``) are skipped — there is nothing to
        fetch yet; the job reads them from the worktree once released."""
        expanded = spec.expand_inputs(self.repo.root, provided=provided or ())
        for i in expanded:
            if os.path.isfile(os.path.join(self.repo.root, i)):
                self.repo.annex_get(i)
        return expanded

    def _submit_one(
        self, spec: RunSpec, inputs: list[str],
        dependency: list[int] | None = None,
    ) -> int:
        """Stage alt-dir and sbatch (outputs already unlocked by the caller).
        Returns the slurm id."""
        workdir = os.path.normpath(os.path.join(self.repo.root, spec.pwd))
        if spec.alt_dir:
            workdir = self._stage_alt_dir(spec.alt_dir, spec.pwd, spec.script, inputs)
        return self.cluster.sbatch(
            spec.script, workdir=workdir, args=spec.script_args,
            array_n=spec.array_n, time_limit_s=spec.time_limit_s,
            env=dict(spec.env) or None,
            dependency=list(dependency) if dependency else None,
        )

    # ---------------------------------------------------- memoization (§11)
    def _publish_memoized(
        self, hits: list[tuple[int, RunSpec, str, dict]]
    ) -> None:
        """Publish memoized provenance for cache-hit specs without touching
        Slurm. ``hits`` is ``[(job_id, spec, exec_key, cache_row)]``.

        The protocol mirrors the batched finish but is tuned so a hit
        charges ~one commit write: under the ref locks, every hit's commit
        is chained in memory, then ONE batched journal append covers all of
        them, then ONE ref publication moves the branch to the last commit,
        then the rows close as ``memoized``. Exactly-once across crashes:
        before the append the commits are unreachable garbage and
        ``recover()`` republishes from the durable cache rows; after it,
        ``_replay_memoize`` tells published from committed-only by walking
        the ref chain back to the journaled base."""
        repo = self.repo
        fs = repo.fs
        with repo.ref_lock, repo.file_lock("refs"):
            branch = repo.current_branch()
            base = repo.branch_head(branch)
            base_tree = repo._tree_oid_of(base)
            jh = JournalHandle.begin(
                fs, repo.repro_dir, "memoize",
                {
                    "branch": branch,
                    "base": base,
                    "jobs": [
                        {"job_id": job_id, "exec_key": key}
                        for job_id, _, key, _ in hits
                    ],
                },
            )
            fs.crash_point("memoize:journal-written")
            head_commit, head_tree = base, base_tree
            lines: list[dict] = []
            deferred: list[dict] = []
            for job_id, spec, key, row in hits:
                changes = self._materialize_cached(row, base)
                message, spec_json = self._memoized_record(spec, row, key)
                # allow_empty: a warm worktree leaves the tree identical to
                # the base, but each hit still gets its provenance commit;
                # defer: the whole chain lands as ONE pack below, so a hit
                # charges no per-commit loose write
                commit, tree = repo.commit_changes(
                    changes, message=message, base_commit=head_commit,
                    base_tree=head_tree, allow_empty=True, spec=spec_json,
                    defer=deferred,
                )
                head_commit, head_tree = commit, tree
                lines.append({"job_id": job_id, "commit": commit})
            # durability order: commit objects first (one pack write), THEN
            # the journal lines that name them, THEN the ref that makes
            # them reachable — a crash between any two steps leaves only
            # unreferenced objects or a replayable journal, never a
            # published ref over missing commits
            repo.objects.put_commits_packed(deferred)
            jh.append_many(lines)
            fs.crash_point("memoize:before-publish")
            repo.set_branch(branch, head_commit)
            fs.crash_point("memoize:after-publish")
            for job_id, _, _, _ in hits:
                self.db.close_job(job_id, status="memoized")
            self.db.cache_bump([key for _, _, key, _ in hits])
            fs.crash_point("memoize:after-close")
        jh.done()

    def _materialize_cached(self, row: dict, base_commit: str | None) -> dict:
        """Changes dict for one memoized commit: every recorded output
        entry, with worktree materialization only where the committed entry
        or working copy differs from the record — a warm resubmit over an
        unchanged repository materializes nothing. Materialization is the
        checkout idiom: blob bytes from the object store, annex content by
        copy when locally present, else a pointer file."""
        repo = self.repo
        changes: dict[str, dict] = {}
        for rel, entry in sorted(row["output_tree"].items()):
            changes[rel] = entry
            abspath = os.path.join(repo.root, rel)
            if (
                base_commit is not None
                and os.path.exists(abspath)
                and repo.entry_at(base_commit, rel) == entry
            ):
                continue  # already live at the recorded content
            if entry.get("t") == "blob":
                repo.fs.write_bytes(abspath, repo.objects.get_blob(entry["oid"]))
            else:
                key = entry["key"]
                if repo.annex.has(key):
                    repo.annex.copy_to(key, abspath)
                else:
                    from .annex import make_pointer

                    repo.fs.write_bytes(abspath, make_pointer(key))
        return changes

    def _memoized_record(
        self, spec: RunSpec, row: dict, exec_key: str
    ) -> tuple[str, dict]:
        """Provenance message + spec JSON for a memoized run. The record
        carries no slurm id (nothing was submitted — the §10 duplicate-
        record fsck keys on slurm ids, so memoized replays can never read
        as duplicates) and points at the original run's commit via
        ``memoized_of``; the spec rides along verbatim, so ``spec_of`` /
        ``rerun`` reconstruct the exact original spec (equal spec_id)."""
        orig = row["commit_oid"]
        spec_json = spec.to_json()
        record = RunRecord(
            cmd=spec.record_cmd,
            dsid=self.repo.dsid,
            inputs=list(spec.inputs),
            outputs=sorted(row["output_tree"]),
            exit=0,
            pwd=spec.pwd,
            spec=spec_json,
            slurm_job_id=None,
            extras={
                "memoized": True,
                "memoized_of": orig,
                "exec_key": exec_key,
                "script": spec.script,
                "script_args": spec.script_args,
            },
        )
        message = record.to_message(
            f"cache hit: memoized replay of {orig[:12]}", kind=TITLE_SLURM
        )
        return message, spec_json

    # ----------------------------------------------------------- schedule
    def schedule(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
        time_limit_s: float | None = None,
        env: dict | None = None,
    ) -> int:
        """``datalad slurm-schedule`` — legacy keyword shim over
        :meth:`submit`. Builds a validated :class:`RunSpec` and delegates;
        output mandatoriness (§5.2) and wildcard rejection (§5.4) are
        enforced by spec construction."""
        try:
            spec = RunSpec(
                script=script,
                script_args=script_args,
                inputs=tuple(inputs or ()),
                outputs=tuple(outputs),
                pwd=pwd,
                alt_dir=alt_dir,
                array_n=array_n,
                message=message,
                time_limit_s=time_limit_s,
                env=tuple((env or {}).items()),
            )
        except ScheduleError:
            raise
        except SpecError as e:
            # the shim's historical error type for an invalid submission
            raise ScheduleError(str(e)) from e
        return self.submit(spec)

    def _stage_alt_dir(
        self, alt_dir: str, pwd: str, script: str, inputs: list[str]
    ) -> str:
        """§5.7: construct the real working directory under ``alt_dir`` with
        the same relative path, deep-copy script + inputs, submit from there.
        The repository itself stays on the (fast, local) file system."""
        real_workdir = os.path.normpath(os.path.join(alt_dir, pwd))
        os.makedirs(real_workdir, exist_ok=True)
        fs = self.repo.fs
        to_copy = list(inputs)
        script_rel = os.path.normpath(os.path.join(pwd, script))
        if os.path.exists(os.path.join(self.repo.root, script_rel)):
            to_copy.append(script_rel)
        for rel in to_copy:
            src = os.path.join(self.repo.root, os.path.normpath(os.path.join(".", rel)))
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, self.repo.root)
                        fs.copy_file(s, os.path.join(alt_dir, r))
            elif os.path.exists(src):
                r = os.path.relpath(src, self.repo.root)
                fs.copy_file(src, os.path.join(alt_dir, r))
        return real_workdir

    # --------------------------------------------------------------- finish
    def finish(
        self,
        job_id: int | None = None,
        slurm_job_id: int | None = None,
        close_failed_jobs: bool = False,
        commit_failed_jobs: bool = False,
        branches: bool = False,
        octopus: bool = False,
        engine: str = "incremental",
        data_plane: str = "fused",
        job_ids: list[int] | None = None,
        journal: bool = True,
        push_to: str | list[str] | None = None,
    ) -> list[FinishResult]:
        """``datalad slurm-finish``: commit results of finished jobs.

        Running jobs are ignored (they stay for a future call). Failed jobs
        require ``close_failed_jobs`` (drop + unprotect) or
        ``commit_failed_jobs`` (commit like a success); otherwise they stay in
        the DB and their outputs remain protected (§5.2).

        All committable jobs in one call share a single batched commit pass:
        the base tree is read once, each job's changes are applied
        incrementally (O(changed paths x depth) per job), and per-job commits
        are chained in memory — plus one octopus merge when requested —
        instead of N independent full-tree rebuilds. The branch ref is
        published before each job is closed in the DB, so a crash mid-batch
        never leaves a closed job with an unreachable commit.

        The *data plane* (DESIGN.md §9) runs first and commutes: every
        output file of every committable job is ingested content-addressed
        (hash-while-write, alt-dir copy-back fused into the same single
        pass) — across ``ingest_workers`` threads when configured — before
        the strictly ordered commit/publish phase, which is serialized
        against concurrent finishers on ``Repository.ref_lock``.
        ``data_plane="legacy"`` restores the seed-era two-pass protocol
        (copy back, then read-whole + write) for benchmarking.
        ``engine="full"`` routes every commit through the seed-era full
        rebuild instead (used by benchmarks to measure the legacy path).

        ``journal=True`` (default) writes an intent journal before the
        commit phase so a crash anywhere inside it is replayed exactly-once
        by ``Session.recover()`` (DESIGN §10); ``job_ids`` restricts the
        batch to specific job-DB rows (the recovery path uses this to
        re-finish precisely the jobs a crashed batch left open).

        ``push_to`` names one or more configured remotes (DESIGN.md §13):
        after the commits land, every annex key the batch introduced is
        pushed there (journaled and resumable like any push — a crash
        after the commits but mid-push leaves the commits intact and the
        push replayable).
        """
        self._charge_cli()
        jobs = self.db.open_jobs()
        if job_id is not None:
            jobs = [j for j in jobs if j["job_id"] == job_id]
        if job_ids is not None:
            wanted = set(job_ids)
            jobs = [j for j in jobs if j["job_id"] in wanted]
        if slurm_job_id is not None:
            jobs = [j for j in jobs if j["slurm_id"] == slurm_job_id]
        # one batched accounting query for the whole candidate set
        states = self._retry_slurm(
            lambda: self.cluster.sacct_many(
                [j["slurm_id"] for j in jobs if j["slurm_id"] is not None]
            ),
            "sacct",
        )
        # §14 satellite: a failed parent's afterok dependents were cancelled
        # by the cluster and will never produce anything — close their rows
        # (releasing output protection) instead of leaving them open to
        # block future conflicting submissions. The failed parent itself
        # keeps the §5.2 close/commit discipline.
        dep_closed = self._close_failed_dependents(jobs, states)
        results: list[FinishResult] = []
        to_commit: list[tuple[dict, str]] = []
        for job in jobs:
            if job["job_id"] in dep_closed:
                results.append(FinishResult(
                    job["job_id"], job["slurm_id"] or -1,
                    "CANCELLED", None,
                ))
                continue
            if job["slurm_id"] is None:
                # a crash between add_jobs and set_slurm_ids left this row
                # without a submission id; it cannot be queried or committed.
                # close_failed_jobs is the recovery path.
                if close_failed_jobs:
                    self.db.close_job(job["job_id"], status="closed-unsubmitted")
                results.append(FinishResult(job["job_id"], -1, "UNKNOWN", None))
                continue
            state = states[job["slurm_id"]]
            if state not in S.TERMINAL:
                continue  # still pending/running -> a future slurm-finish
            if state != S.COMPLETED and not (close_failed_jobs or commit_failed_jobs):
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue  # outputs stay protected (§5.2)
            if state != S.COMPLETED and close_failed_jobs:
                self.db.close_job(job["job_id"], status=f"closed-{state.lower()}")
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue
            to_commit.append((job, state))
        jh = None
        if to_commit and journal:
            jh = JournalHandle.begin(
                self.repo.fs, self.repo.repro_dir, "finish",
                {
                    "branch": self.repo.current_branch(),
                    "jobs": [
                        {"job_id": j["job_id"], "slurm_id": j["slurm_id"],
                         "state": st}
                        for j, st in to_commit
                    ],
                    "flags": {
                        "branches": branches, "octopus": octopus,
                        "engine": engine, "data_plane": data_plane,
                        "close_failed_jobs": close_failed_jobs,
                        "commit_failed_jobs": commit_failed_jobs,
                    },
                },
            )
            self.repo.fs.crash_point("finish:journal-written")
        # a non-crash failure mid-batch deliberately leaves the journal in
        # place: the jobs it covers are still open and recover() (or the
        # next finish) completes them exactly-once
        results += self._commit_jobs_batched(
            to_commit, use_branch=branches or octopus, octopus=octopus,
            engine=engine, data_plane=data_plane, journal=jh,
        )
        if jh is not None:
            jh.done()
        if to_commit:
            self.maybe_repack()
        if push_to is not None and any(r.commit for r in results):
            self._auto_push(push_to, results)
        return results

    def _close_failed_dependents(
        self, jobs: list[dict], states: dict[int, str]
    ) -> set[int]:
        """Close (transitively) every open afterok dependent of a job the
        poll saw terminal-but-not-COMPLETED, as ``cancelled-dependency``.
        Returns the closed job ids."""
        frontier = [
            j["job_id"] for j in jobs
            if j["slurm_id"] is not None
            and states.get(j["slurm_id"]) in S.TERMINAL
            and states.get(j["slurm_id"]) != S.COMPLETED
        ]
        closed: set[int] = set()
        while frontier:
            parent = frontier.pop()
            for row in self.db.dependents_of(parent):
                jid = row["job_id"]
                if jid in closed or row["status"] != "scheduled":
                    continue
                self.db.close_job(jid, status="cancelled-dependency")
                closed.add(jid)
                frontier.append(jid)
        return closed

    def _auto_push(self, push_to: str | list[str],
                   results: list[FinishResult]) -> list[dict]:
        """Push the annex keys the batch's commits introduced (diff against
        each commit's first parent — O(changed), not O(tree)) to every
        remote named in ``push_to``."""
        from .remote import push_keys

        names = [push_to] if isinstance(push_to, str) else list(push_to)
        keys: set[str] = set()
        for r in results:
            if r.commit is None:
                continue
            commit = self.repo.objects.get_commit(r.commit)
            parents = commit.get("parents", [])
            base = (
                self.repo.objects.get_commit(parents[0])["tree"]
                if parents else None
            )
            for entry in self.repo._diff_trees(base, commit["tree"]).values():
                if entry is not None and entry.get("t") == "annex":
                    keys.add(entry["key"])
        if not keys:
            return []
        return [
            push_keys(self.repo, self.repo.remote_by_name(n), sorted(keys),
                      db=self.db)
            for n in names
        ]

    def maybe_repack(self) -> dict | None:
        """Threshold-based compaction (DESIGN.md §8), amortized over finish
        batches: when any loose shard's entry count exceeds
        ``auto_repack_threshold``, migrate loose objects into a pack so new
        writes stop paying the directory-pressure degradation. Runs AFTER
        the batch's refs are published; crash-safe by repack's
        pack-before-unlink ordering. Returns repack stats, or None."""
        thr = self.auto_repack_threshold
        if thr is None or self.repo.objects.loose_pressure() <= thr:
            return None
        return self.repo.objects.repack()

    def _commit_jobs_batched(
        self,
        to_commit: list[tuple[dict, str]],
        use_branch: bool,
        octopus: bool,
        engine: str = "incremental",
        data_plane: str = "fused",
        journal: JournalHandle | None = None,
    ) -> list[FinishResult]:
        """One commit per job (§5.1: one reproducibility record each), but the
        whole batch shares one base-tree read. The branch ref is written per
        commit, *before* the job is closed — crash-safety over batching; do
        not hoist it out of the loop.

        Two phases (DESIGN.md §9): the commutative data plane first — every
        output of every job ingested content-addressed, fan-out across
        ``self.ingest_workers`` — then the ordered metadata phase (record,
        commit chaining, ref publication, job closing) under
        ``Repository.ref_lock`` so concurrent finish batches interleave at
        the byte level but publish serially. A crash between the phases
        loses nothing: ingested objects are content-addressed (a re-finish
        dedups them) and the jobs are still open."""
        if engine not in ("incremental", "full"):
            raise ValueError(f"unknown commit engine: {engine!r}")
        if data_plane not in ("fused", "legacy"):
            raise ValueError(f"unknown data plane: {data_plane!r}")
        if not to_commit:
            return []
        repo = self.repo
        prepared = []
        for job, state in to_commit:
            spec = job_spec(job)
            slurm_outputs = [
                os.path.normpath(os.path.join(spec.pwd, f))
                for f in self.cluster.slurm_output_files(job["slurm_id"])
            ]
            prepared.append((job, state, spec, slurm_outputs))
        fused = engine == "incremental" and data_plane == "fused"
        staged: list[dict] | None = None
        if fused:
            staged = self._ingest_batch(prepared)
            repo.fs.crash_point("finish:after-ingest")
        else:
            # seed-era data plane: deep-copy alt-dir outputs back into the
            # worktree now; each job re-reads + re-writes them when staged
            for _, _, spec, slurm_outputs in prepared:
                if spec.alt_dir:
                    self._copy_back_alt_dir(spec, slurm_outputs)
        results: list[FinishResult] = []
        new_branches: list[str] = []
        cache_rows: list[dict] = []  # §11: executions to memoize
        # ref_lock serializes threads; the file lock serializes processes
        # and survives (as a breakable stale lock) the holder's crash
        with repo.ref_lock, repo.file_lock("refs"):
            branch = repo.current_branch()
            base = repo.branch_head(branch)
            base_tree = repo._tree_oid_of(base)
            head_commit, head_tree = base, base_tree
            for idx, (job, state, spec, slurm_outputs) in enumerate(prepared):
                # another finisher may have committed this job between our
                # open_jobs() read and taking the lock (two unfiltered
                # finish() calls racing): commits + close run under
                # ref_lock, so a re-read here decides exactly once per job.
                # The data-plane work already done is content-addressed —
                # wasted effort at most, never a duplicate record.
                row = self.db.get(job["job_id"])
                if row is None or row["status"] != "scheduled":
                    results.append(
                        FinishResult(job["job_id"], job["slurm_id"], state, None)
                    )
                    continue
                message, save_paths, spec_json = self._job_record(
                    job, state, spec, slurm_outputs
                )
                if engine == "full":
                    # seed-era path, one full-tree rebuild per job (benchmarks)
                    branch_name = None
                    if use_branch:
                        branch_name = f"job/{job['slurm_id']}"
                        if repo.branch_head(branch_name) is None:
                            repo.create_branch(branch_name, at=base)
                        new_branches.append(branch_name)
                    commit = repo.save(
                        paths=save_paths, message=message, branch=branch_name,
                        engine="full", spec=spec_json,
                    )
                    if journal is not None:
                        # save() publishes internally; journal after the fact
                        # so replay sees head==commit and just closes the row
                        journal.append({
                            "job_id": job["job_id"], "commit": commit,
                            "job_branch": branch_name,
                        })
                else:
                    changes = (
                        staged[idx] if staged is not None
                        else repo.stage_paths(save_paths, single_pass=False)
                    )
                    branch_name = None
                    if use_branch:
                        # per-job branches all root at the shared base (§5.8);
                        # tolerate a branch a crashed pre-recovery finish
                        # already created — it is re-published below
                        branch_name = f"job/{job['slurm_id']}"
                        if repo.branch_head(branch_name) is None:
                            repo.create_branch(branch_name, at=base)
                        commit, _ = repo.commit_changes(
                            changes, message=message, base_commit=base,
                            base_tree=base_tree, spec=spec_json,
                        )
                        if journal is not None:
                            # journal BEFORE the ref moves: replay can tell
                            # published from committed-only (exactly-once)
                            journal.append({
                                "job_id": job["job_id"], "commit": commit,
                                "job_branch": branch_name,
                            })
                        repo.fs.crash_point("finish:before-publish")
                        repo.set_branch(branch_name, commit)
                        repo.fs.crash_point("finish:after-publish")
                        new_branches.append(branch_name)
                    else:
                        commit, tree = repo.commit_changes(
                            changes, message=message,
                            base_commit=head_commit, base_tree=head_tree,
                            spec=spec_json,
                        )
                        head_commit, head_tree = commit, tree
                        if journal is not None:
                            journal.append({
                                "job_id": job["job_id"], "commit": commit,
                                "job_branch": None,
                            })
                        # publish before closing the job: a closed job must
                        # always have its commit reachable, even if the
                        # process dies here
                        repo.fs.crash_point("finish:before-publish")
                        repo.set_branch(branch, commit)
                        repo.fs.crash_point("finish:after-publish")
                ekey = job.get("exec_key")
                if (
                    self.cache is not None and staged is not None
                    and state == S.COMPLETED and not ekey
                ):
                    # pipeline stages submit with no key (their inputs did
                    # not exist yet / were about to be rewritten, §14) —
                    # derive it now that the real inputs are on disk, so
                    # replays of the same campaign can memoize this stage
                    ekey = self.cache.execution_key(spec)
                if (
                    self.cache is not None and staged is not None
                    and state == S.COMPLETED and ekey
                ):
                    entries = staged[idx]
                    cache_rows.append({
                        "exec_key": ekey,
                        "spec_id": spec.spec_id,
                        "commit_oid": commit,
                        "output_tree": entries,
                        "annex_keys": sorted({
                            e["key"] for e in entries.values()
                            if e.get("t") == "annex"
                        }),
                    })
                self.db.close_job(job["job_id"], status="finished")
                repo.fs.crash_point("finish:after-close")
                results.append(
                    FinishResult(
                        job["job_id"], job["slurm_id"], state, commit, branch_name
                    )
                )
            if octopus and new_branches:
                repo.fs.crash_point("finish:before-octopus")
                merge_oid = repo.merge_octopus(
                    new_branches,
                    message=f"octopus merge of {len(new_branches)} slurm jobs",
                )
                if journal is not None:
                    journal.append({"octopus": merge_oid})
                repo.fs.crash_point("finish:after-octopus")
        if cache_rows:
            # recorded AFTER publication: a crash before this insert costs
            # a future cache miss, never a wrong hit; INSERT OR REPLACE on
            # the exec_key keeps §10 journal replay from double-inserting
            self.db.cache_put(cache_rows)
        return results

    def _ingest_batch(self, prepared) -> list[dict]:
        """Fused data plane: expand every committable job's outputs into
        per-file ingest tasks and run them — serially, or across the
        ``ingest_workers`` pool (ingest is content-addressed and
        commutative, so ordering is irrelevant and duplicate content
        collapses via the annex known-key set). Alt-dir outputs are
        absorbed straight from the staging tree (one read + one annex
        write + a rename into the worktree) instead of copy-then-restage.
        Returns one {relpath: entry} changes dict per prepared job."""
        repo = self.repo
        tasks: list[tuple[int, str, str | None]] = []  # (job idx, rel, alt src)
        seen: set[tuple[int, str]] = set()

        def add_task(idx: int, rel: str, src: str | None) -> None:
            if (idx, rel) not in seen and not repo._is_ignored(rel):
                seen.add((idx, rel))
                tasks.append((idx, rel, src))

        def expand(idx: int, rel: str, base_dir: str, external: bool) -> None:
            abs_p = os.path.join(base_dir, rel)
            if os.path.isdir(abs_p):
                for dirpath, dirnames, files in os.walk(abs_p):
                    dirnames[:] = [d for d in dirnames if d != REPRO_DIR]
                    for f in sorted(files):
                        r = os.path.relpath(os.path.join(dirpath, f), base_dir)
                        add_task(
                            idx, r,
                            os.path.join(base_dir, r) if external else None,
                        )
            else:
                add_task(idx, rel, abs_p if external else None)

        for idx, (job, state, spec, slurm_outputs) in enumerate(prepared):
            for p in list(spec.outputs) + slurm_outputs:
                rel = os.path.normpath(p)
                # alt first (a staged output shadows a same-path worktree
                # file, like the legacy copy-back overwrite), then the
                # worktree copy of the same output — a directory output may
                # hold files on both sides and the commit needs the union,
                # exactly as copy-back + stage produced
                if spec.alt_dir and os.path.exists(os.path.join(spec.alt_dir, rel)):
                    expand(idx, rel, spec.alt_dir, True)
                if os.path.exists(os.path.join(repo.root, rel)):
                    expand(idx, rel, repo.root, False)

        # readdirplus prime (§11 satellite): every task opens with one
        # charged stat_size (annex routing). Where several staged files
        # share a directory, one scan_dir enumeration primes all their
        # sizes, so N per-file stat RPCs collapse into 1 listdir-cost op.
        by_dir: dict[str, int] = {}
        for idx, rel, src in tasks:
            p = src if src is not None else os.path.join(repo.root, rel)
            d = os.path.dirname(p)
            by_dir[d] = by_dir.get(d, 0) + 1
        for d, n in by_dir.items():
            if n > 1 and os.path.isdir(d):
                repo.fs.scan_dir(d)

        def ingest_one(task: tuple[int, str, str | None]):
            idx, rel, src = task
            repo.fs.crash_point("finish:mid-ingest")
            if src is not None:
                try:
                    return idx, rel, repo.ingest_external_file(src, rel)
                except FileNotFoundError:
                    # a racing finisher of the same job absorbed this staged
                    # file already — its content now lives in the worktree,
                    # so stage it from there like any in-repo output
                    pass
            return idx, rel, repo._hash_working_file(rel)

        try:
            if self.ingest_workers > 1 and len(tasks) > 1:
                from concurrent.futures import ThreadPoolExecutor

                with ThreadPoolExecutor(max_workers=self.ingest_workers) as ex:
                    done = list(ex.map(ingest_one, tasks))
            else:
                done = [ingest_one(t) for t in tasks]
        finally:
            # job payloads are written by external processes the FS layer
            # never sees — no primed size may outlive this batch
            repo.fs.stat_cache_clear()
        staged: list[dict] = [{} for _ in prepared]
        for idx, rel, entry in done:
            staged[idx][rel] = entry
        return staged

    def _job_record(
        self, job: dict, state: str, spec: RunSpec, slurm_outputs: list[str]
    ) -> tuple[str, list[str], dict]:
        """Reproducibility record message (§5.2), the existing output paths
        to stage, and the originating spec JSON for one finished job. Pure
        bookkeeping: the data plane (copy-back/ingest) has already run."""
        slurm_id = job["slurm_id"]
        spec_json = spec.to_json()
        record = RunRecord(
            cmd=spec.record_cmd,
            dsid=self.repo.dsid,
            inputs=list(spec.inputs),
            outputs=list(spec.outputs) + slurm_outputs,
            exit=0 if state == S.COMPLETED else 1,
            pwd=spec.pwd,
            spec=spec_json,
            slurm_job_id=slurm_id,
            slurm_outputs=[os.path.basename(f) for f in slurm_outputs],
            extras={
                "script": spec.script,
                "script_args": spec.script_args,
                "array_n": spec.array_n,
                "alt_dir": spec.alt_dir,
            },
        )
        message = record.to_message(
            f"Slurm job {slurm_id}: {state.capitalize()}", kind=TITLE_SLURM
        )
        save_paths = [
            p for p in list(spec.outputs) + slurm_outputs
            if os.path.exists(os.path.join(self.repo.root, p))
        ]
        return message, save_paths, spec_json

    def _copy_back_alt_dir(self, spec: RunSpec, slurm_outputs: list[str]) -> None:
        """§5.7 step (4): copy output files from the alternative directory
        back into the repository."""
        fs = self.repo.fs
        for rel in list(spec.outputs) + slurm_outputs:
            src = os.path.join(spec.alt_dir, rel)
            dst = os.path.join(self.repo.root, rel)
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, spec.alt_dir)
                        fs.copy_file(s, os.path.join(self.repo.root, r))
            elif os.path.exists(src):
                fs.copy_file(src, dst)

    # ----------------------------------------------------------- inspection
    def list_open_jobs(self) -> list[tuple[dict, str]]:
        """``--list-open-jobs``: scheduled jobs + their current Slurm state,
        polled with ONE batched accounting query. A job whose slurm id was
        never persisted (crash mid-submission) reports ``"UNKNOWN"``."""
        jobs = self.db.open_jobs()
        states = self.cluster.sacct_many(
            [j["slurm_id"] for j in jobs if j["slurm_id"] is not None]
        )
        return [
            (j, states[j["slurm_id"]] if j["slurm_id"] is not None
             else "UNKNOWN")
            for j in jobs
        ]

    # ----------------------------------------------------------- reschedule
    def reschedule(
        self,
        commitish: str | None = None,
        since: str | None = None,
        alt_dir: str | None = "__same__",
    ) -> list[int]:
        """``datalad slurm-reschedule``: schedule job(s) again from their
        provenance (§5.2). Deserializes the stored :class:`RunSpec` of each
        commit (exact replay — no message reassembly; pre-spec records fall
        back to field reconstruction), re-applies all conflict checks, and
        resubmits the whole set as ONE batch. Uses the *current* version of
        the job script. Defaults to the most recent slurm job; ``since``
        reschedules every slurm job after that commit."""
        found = self._find_slurm_records(commitish, since)
        if not found:
            raise ScheduleError("no slurm reproducibility records found")
        specs = []
        for oid, rec in found:
            spec = spec_of(self.repo, oid)
            label = (
                f"memoized run {oid[:12]}" if rec.slurm_job_id is None
                else f"slurm job {rec.slurm_job_id}"
            )
            changes: dict = {"message": f"reschedule of {label}"}
            if alt_dir != "__same__":
                changes["alt_dir"] = alt_dir
            specs.append(spec.replace(**changes))
        return self.submit_many(specs)

    def _find_slurm_records(
        self, commitish: str | None, since: str | None
    ) -> list[tuple[str, RunRecord]]:
        # a memoized record has no slurm id (nothing was submitted) but is
        # every bit as reschedulable: it embeds the exact original spec
        def is_slurm(rec: RunRecord | None) -> bool:
            return rec is not None and (
                rec.slurm_job_id is not None or rec.memoized
            )

        if commitish is not None:
            oid = self.repo.resolve(commitish)
            commit = self.repo.objects.get_commit(oid)
            rec = RunRecord.from_message(commit["message"])
            if not is_slurm(rec):
                raise ScheduleError(f"{commitish} has no slurm reproducibility record")
            return [(oid, rec)]
        stop = self.repo.resolve(since) if since else None
        found = []
        for oid, commit in self.repo.log():
            if oid == stop:
                break
            rec = RunRecord.from_message(commit["message"])
            if is_slurm(rec):
                found.append((oid, rec))
                if since is None:
                    break  # only the most recent
        return list(reversed(found))

    # ----------------------------------------------------- straggler handling
    def find_stragglers(self, factor: float = 3.0, min_samples: int = 3) -> list[dict]:
        """Beyond-paper: flag RUNNING jobs whose elapsed time exceeds
        ``factor`` x the median runtime of completed jobs."""
        runtimes = []
        open_jobs = [j for j in self.db.open_jobs() if j["slurm_id"] is not None]
        # one batched poll serves both the median scan and the straggler scan
        states = self.cluster.sacct_many([j["slurm_id"] for j in open_jobs])
        for job in open_jobs:
            if states[job["slurm_id"]] == S.COMPLETED:
                rt = self.cluster.job_runtime(job["slurm_id"])
                if rt:
                    runtimes.append(rt)
        if len(runtimes) < min_samples:
            return []
        median = statistics.median(runtimes)
        stragglers = []
        for job in open_jobs:
            if states[job["slurm_id"]] == S.RUNNING:
                rt = self.cluster.job_runtime(job["slurm_id"]) or 0.0
                if rt > factor * median:
                    stragglers.append(job)
        return stragglers

    def reschedule_straggler(self, job_id: int) -> int | None:
        """Cancel a straggling job, release its outputs, and submit a fresh
        copy of its exact stored spec.

        Race-safe: between the straggler scan and the cancel, the job may
        have completed (and a concurrent finish may even have closed the
        row). ``scancel`` is idempotent and reports the job's terminal state
        instead of cancelling twice; a COMPLETED straggler is left open for
        a normal ``finish`` and no duplicate submission happens — returns
        None in both already-resolved cases.

        Pipeline-aware (§14): afterok dependents of the straggler are first
        detached-and-held (so the cancel cannot cascade into them), then
        rewired onto the replacement's slurm id and released — they run
        after the replacement, never after the cancelled original. The
        jobdb dependency edges move to the replacement row so failure
        handling and future rewires keep following the chain."""
        job = self.db.get(job_id)
        if job is None:
            raise ScheduleError(f"unknown job {job_id}")
        if job["status"] != "scheduled" or job["slurm_id"] is None:
            return None  # a racing finisher already resolved this job
        # detach held dependents BEFORE cancelling: a cancelled parent would
        # otherwise cascade-cancel the very jobs we mean to re-parent
        dependents = [
            r for r in self.db.dependents_of(job_id)
            if r["status"] == "scheduled" and r["slurm_id"] is not None
        ]
        detached: list[dict] = []
        for d in dependents:
            ok = self._retry_slurm(
                lambda d=d: self.cluster.scontrol_update_dependency(
                    d["slurm_id"], remove=[job["slurm_id"]], hold=True
                ),
                "scontrol",
            )
            if ok:
                detached.append(d)
        state = self._retry_slurm(
            lambda: self.cluster.scancel(job["slurm_id"]), "scancel"
        )
        if state == S.COMPLETED:
            # lost the race: the job finished before the cancel landed.
            # Leave the row open so finish() commits it exactly once; the
            # afterok edges we removed are satisfied by definition.
            for d in detached:
                self.cluster.scontrol_release(d["slurm_id"])
            return None
        self.db.close_job(job_id, status="cancelled-straggler")
        spec = job_spec(job).replace(
            message=f"straggler reschedule of job {job_id}"
        )
        try:
            new_id = self.submit(spec)
        except BaseException:
            # no replacement: the dependents' parent is gone — same
            # semantics as a failed parent, so cancel and close them
            for d in detached:
                self.cluster.scancel(d["slurm_id"])
                self.db.close_job(d["job_id"], status="cancelled-dependency")
            raise
        new_row = self.db.get(new_id)
        for d in detached:
            if new_row["status"] == "scheduled" and new_row["slurm_id"] is not None:
                self.cluster.scontrol_update_dependency(
                    d["slurm_id"], add=[new_row["slurm_id"]]
                )
            # a memoized replacement needs no edge: its outputs are already
            # materialized, so the afterok contract is satisfied
            self.cluster.scontrol_release(d["slurm_id"])
        # move only the edges of dependents the cluster actually detached:
        # a dependent scontrol_update_dependency could not rewire (already
        # started/terminal) is still chained to the old job on the cluster,
        # and its jobdb edge must keep saying so for failure handling
        if detached:
            self.db.replace_dep_parent(
                job_id, new_id, children=[d["job_id"] for d in detached]
            )
        return new_id
