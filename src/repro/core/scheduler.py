"""The DataLad-Slurm protocol: schedule / finish / reschedule (paper §5).

Design goals, verbatim from §5.1:

  - many jobs scheduled & running at the same time on ONE clone of the repo,
  - track which outputs belong to which job; refuse conflicting outputs at
    schedule time (the §5.5 N/P checks, persisted in the job DB),
  - one machine-actionable reproducibility record per job in the history,
  - no version-control commands inside jobs — the job script itself is the
    subject of (re-)execution.

Plus §5.6 array jobs, §5.7 ``--alt-dir`` staging, §5.8 per-job branches and
octopus merges, and straggler detection/rescheduling (our beyond-paper
addition for 1000+-node operation).
"""
from __future__ import annotations

import os
import shutil
import statistics
import time
from dataclasses import dataclass

from . import slurm as S
from .conflicts import WildcardOutputError, has_wildcard, normalize
from .jobdb import JobDB
from .records import TITLE_SLURM, RunRecord
from .repo import Repository


class ScheduleError(ValueError):
    pass


@dataclass
class FinishResult:
    job_id: int
    slurm_id: int
    state: str
    commit: str | None
    branch: str | None = None


class SlurmScheduler:
    """``cli_startup_s`` models the per-invocation cost the paper measures
    for the DataLad CLI — Python package loading + repository state check
    (§6 steps (1)-(2), ~0.35 s) — charged on the *virtual* clock. Our port is
    an in-process library, so the real wall cost is ~20-50 µs (see
    benchmarks/run.py, the ``us_per_call`` column); the charge keeps the
    simulated figures 1:1 comparable with the paper's plots. Set to 0.0 to
    benchmark the library itself."""

    def __init__(self, repo: Repository, cluster: S.SlurmCluster,
                 cli_startup_s: float = 0.35):
        self.repo = repo
        self.cluster = cluster
        self.cli_startup_s = cli_startup_s
        self.db = JobDB(repo.repro_dir)

    def _charge_cli(self) -> None:
        if self.cli_startup_s:
            self.repo.fs.clock.charge(self.cli_startup_s)

    # ------------------------------------------------------------- schedule
    def schedule(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
        time_limit_s: float | None = None,
    ) -> int:
        """``datalad slurm-schedule``: validate, conflict-check, stage, submit.

        Returns the job DB id. Output specification is mandatory (§5.2) and
        wildcards are rejected (§5.4). Inputs are annex-fetched if needed.
        """
        self._charge_cli()
        if not outputs:
            raise ScheduleError("output specification is mandatory (paper §5.2)")
        for o in outputs:
            if has_wildcard(o):
                raise WildcardOutputError(o)
        inputs = list(inputs or [])
        for i in inputs:
            if not has_wildcard(i):  # inputs may be wildcards like datalad run
                abspath = os.path.join(self.repo.root, i)
                if not os.path.exists(abspath):
                    raise ScheduleError(f"input does not exist: {i}")
                if os.path.isfile(abspath):
                    self.repo.annex_get(i)  # step (1) of datalad run, §3

        # conflict check + protection, atomic in the job DB (§5.3/§5.5)
        job_id = self.db.add_job(
            script=script,
            outputs=outputs,
            inputs=inputs,
            script_args=script_args,
            pwd=pwd,
            alt_dir=alt_dir,
            array_n=array_n,
            message=message,
        )

        # unlock outputs that already exist so the job may overwrite them
        for o in outputs:
            self.repo.unlock(normalize(o))

        workdir = os.path.normpath(os.path.join(self.repo.root, pwd))
        if alt_dir:
            workdir = self._stage_alt_dir(alt_dir, pwd, script, inputs)

        slurm_id = self.cluster.sbatch(
            script, workdir=workdir, args=script_args, array_n=array_n,
            time_limit_s=time_limit_s,
        )
        self.db.set_slurm_id(job_id, slurm_id)
        return job_id

    def _stage_alt_dir(
        self, alt_dir: str, pwd: str, script: str, inputs: list[str]
    ) -> str:
        """§5.7: construct the real working directory under ``alt_dir`` with
        the same relative path, deep-copy script + inputs, submit from there.
        The repository itself stays on the (fast, local) file system."""
        real_workdir = os.path.normpath(os.path.join(alt_dir, pwd))
        os.makedirs(real_workdir, exist_ok=True)
        fs = self.repo.fs
        to_copy = list(inputs)
        script_rel = os.path.normpath(os.path.join(pwd, script))
        if os.path.exists(os.path.join(self.repo.root, script_rel)):
            to_copy.append(script_rel)
        for rel in to_copy:
            src = os.path.join(self.repo.root, os.path.normpath(os.path.join(".", rel)))
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, self.repo.root)
                        fs.copy_file(s, os.path.join(alt_dir, r))
            elif os.path.exists(src):
                r = os.path.relpath(src, self.repo.root)
                fs.copy_file(src, os.path.join(alt_dir, r))
        return real_workdir

    # --------------------------------------------------------------- finish
    def finish(
        self,
        job_id: int | None = None,
        slurm_job_id: int | None = None,
        close_failed_jobs: bool = False,
        commit_failed_jobs: bool = False,
        branches: bool = False,
        octopus: bool = False,
    ) -> list[FinishResult]:
        """``datalad slurm-finish``: commit results of finished jobs.

        Running jobs are ignored (they stay for a future call). Failed jobs
        require ``close_failed_jobs`` (drop + unprotect) or
        ``commit_failed_jobs`` (commit like a success); otherwise they stay in
        the DB and their outputs remain protected (§5.2).
        """
        self._charge_cli()
        jobs = self.db.open_jobs()
        if job_id is not None:
            jobs = [j for j in jobs if j["job_id"] == job_id]
        if slurm_job_id is not None:
            jobs = [j for j in jobs if j["slurm_id"] == slurm_job_id]
        results: list[FinishResult] = []
        new_branches: list[str] = []
        for job in jobs:
            state = self.cluster.sacct(job["slurm_id"])
            if state not in S.TERMINAL:
                continue  # still pending/running -> a future slurm-finish
            if state != S.COMPLETED and not (close_failed_jobs or commit_failed_jobs):
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue  # outputs stay protected (§5.2)
            if state != S.COMPLETED and close_failed_jobs:
                self.db.close_job(job["job_id"], status=f"closed-{state.lower()}")
                results.append(FinishResult(job["job_id"], job["slurm_id"], state, None))
                continue
            commit, branch = self._commit_job(job, state, use_branch=branches or octopus)
            self.db.close_job(job["job_id"], status="finished")
            if branch:
                new_branches.append(branch)
            results.append(
                FinishResult(job["job_id"], job["slurm_id"], state, commit, branch)
            )
        if octopus and new_branches:
            self.repo.merge_octopus(
                new_branches, message=f"octopus merge of {len(new_branches)} slurm jobs"
            )
        return results

    def _commit_job(
        self, job: dict, state: str, use_branch: bool
    ) -> tuple[str, str | None]:
        slurm_id = job["slurm_id"]
        pwd = job["pwd"]
        slurm_outputs = [
            os.path.normpath(os.path.join(pwd, f))
            for f in self.cluster.slurm_output_files(slurm_id)
        ]
        if job["alt_dir"]:
            self._copy_back_alt_dir(job, slurm_outputs)
        record = RunRecord(
            cmd=f"sbatch {job['script']}"
            + (f" {job['script_args']}" if job["script_args"] else ""),
            dsid=self.repo.dsid,
            inputs=job["inputs"],
            outputs=job["outputs"] + slurm_outputs,
            exit=0 if state == S.COMPLETED else 1,
            pwd=pwd,
            slurm_job_id=slurm_id,
            slurm_outputs=[os.path.basename(f) for f in slurm_outputs],
            extras={
                "script": job["script"],
                "script_args": job["script_args"],
                "array_n": job["array_n"],
                "alt_dir": job["alt_dir"],
            },
        )
        message = record.to_message(
            f"Slurm job {slurm_id}: {state.capitalize()}", kind=TITLE_SLURM
        )
        save_paths = [
            p for p in job["outputs"] + slurm_outputs
            if os.path.exists(os.path.join(self.repo.root, p))
        ]
        branch_name = None
        if use_branch:
            branch_name = f"job/{slurm_id}"
            self.repo.create_branch(branch_name)
            commit = self.repo.save(paths=save_paths, message=message, branch=branch_name)
        else:
            commit = self.repo.save(paths=save_paths, message=message)
        return commit, branch_name

    def _copy_back_alt_dir(self, job: dict, slurm_outputs: list[str]) -> None:
        """§5.7 step (4): copy output files from the alternative directory
        back into the repository."""
        fs = self.repo.fs
        for rel in job["outputs"] + slurm_outputs:
            src = os.path.join(job["alt_dir"], rel)
            dst = os.path.join(self.repo.root, rel)
            if os.path.isdir(src):
                for dirpath, _, files in os.walk(src):
                    for f in files:
                        s = os.path.join(dirpath, f)
                        r = os.path.relpath(s, job["alt_dir"])
                        fs.copy_file(s, os.path.join(self.repo.root, r))
            elif os.path.exists(src):
                fs.copy_file(src, dst)

    # ----------------------------------------------------------- inspection
    def list_open_jobs(self) -> list[tuple[dict, str]]:
        """``--list-open-jobs``: scheduled jobs + their current Slurm state."""
        return [(j, self.cluster.sacct(j["slurm_id"])) for j in self.db.open_jobs()]

    # ----------------------------------------------------------- reschedule
    def reschedule(
        self,
        commitish: str | None = None,
        since: str | None = None,
        alt_dir: str | None = "__same__",
    ) -> list[int]:
        """``datalad slurm-reschedule``: schedule job(s) again from their
        reproducibility records (§5.2). Uses the *current* version of the job
        script, schedules from the recorded ``pwd``, and re-applies all
        conflict checks. Defaults to the most recent slurm job; ``since``
        reschedules every slurm job after that commit."""
        records = self._find_slurm_records(commitish, since)
        if not records:
            raise ScheduleError("no slurm reproducibility records found")
        new_ids = []
        for rec in records:
            outputs = [
                o for o in rec.outputs
                if o not in (rec.slurm_outputs or [])
                and not os.path.basename(o).startswith(("log.slurm-", "slurm-job-"))
            ]
            ad = rec.extras.get("alt_dir") if alt_dir == "__same__" else alt_dir
            new_ids.append(
                self.schedule(
                    script=rec.extras.get("script", rec.cmd.removeprefix("sbatch ").split()[0]),
                    outputs=outputs,
                    inputs=rec.inputs,
                    script_args=rec.extras.get("script_args", ""),
                    pwd=rec.pwd,
                    alt_dir=ad,
                    array_n=int(rec.extras.get("array_n", 1)),
                    message=f"reschedule of slurm job {rec.slurm_job_id}",
                )
            )
        return new_ids

    def _find_slurm_records(
        self, commitish: str | None, since: str | None
    ) -> list[RunRecord]:
        if commitish is not None:
            commit = self.repo.objects.get_commit(self.repo.resolve(commitish))
            rec = RunRecord.from_message(commit["message"])
            if rec is None or rec.slurm_job_id is None:
                raise ScheduleError(f"{commitish} has no slurm reproducibility record")
            return [rec]
        stop = self.repo.resolve(since) if since else None
        found = []
        for oid, commit in self.repo.log():
            if oid == stop:
                break
            rec = RunRecord.from_message(commit["message"])
            if rec is not None and rec.slurm_job_id is not None:
                found.append(rec)
                if since is None:
                    break  # only the most recent
        return list(reversed(found))

    # ----------------------------------------------------- straggler handling
    def find_stragglers(self, factor: float = 3.0, min_samples: int = 3) -> list[dict]:
        """Beyond-paper: flag RUNNING jobs whose elapsed time exceeds
        ``factor`` x the median runtime of completed jobs."""
        runtimes = []
        open_jobs = self.db.open_jobs()
        for job in open_jobs:
            if self.cluster.sacct(job["slurm_id"]) == S.COMPLETED:
                rt = self.cluster.job_runtime(job["slurm_id"])
                if rt:
                    runtimes.append(rt)
        if len(runtimes) < min_samples:
            return []
        median = statistics.median(runtimes)
        stragglers = []
        for job in open_jobs:
            if self.cluster.sacct(job["slurm_id"]) == S.RUNNING:
                rt = self.cluster.job_runtime(job["slurm_id"]) or 0.0
                if rt > factor * median:
                    stragglers.append(job)
        return stragglers

    def reschedule_straggler(self, job_id: int) -> int:
        """Cancel a straggling job, release its outputs, and submit a fresh
        copy with the same specification."""
        job = self.db.get(job_id)
        if job is None:
            raise ScheduleError(f"unknown job {job_id}")
        self.cluster.scancel(job["slurm_id"])
        self.db.close_job(job_id, status="cancelled-straggler")
        return self.schedule(
            script=job["script"],
            outputs=job["outputs"],
            inputs=job["inputs"],
            script_args=job["script_args"],
            pwd=job["pwd"],
            alt_dir=job["alt_dir"],
            array_n=job["array_n"],
            message=f"straggler reschedule of job {job_id}",
        )
