"""Run cache: content-addressed memoization of RunSpec executions (§11).

The functional model (Guix; DVC's run-cache) says an execution is a pure
function of its content-addressed inputs: ``spec_id`` (the exact command,
outputs, pwd, env — PR 2) plus the tree entries of every resolved input
plus an environment fingerprint determine the outputs. This module derives
that **execution key** and fronts the jobdb ``runcache`` table (migration
v3) that maps it to the recorded result: the output tree, the provenance
commit, and the annex keys it references.

``SlurmScheduler.submit_many`` consults the index before sbatch — hits
short-circuit into a memoized provenance commit (scheduler
``_publish_memoized``) while only novel specs reach Slurm. The index is
written exactly once per finished job through the batched finish path
(``JobDB.cache_put`` is INSERT OR REPLACE on the key, so §10 journal
replay of a re-finished batch cannot double-insert), fsck'd by
``Session.verify()`` and pruned by ``Session.gc()``.

Input hashing cost: deriving a key charges one read pass per input file
(``Repository.hash_path_entry``). A per-process stat memo — the DVC
state-db analogue — reuses the hash while the raw ``(size, mtime_ns)``
pair is unchanged, so a 1000-spec sweep over a shared input set pays for
each input once, not once per spec. The memo guards with *uncharged*
``os.stat``: it is an in-memory client-side cache, not simulated-FS state.
"""
from __future__ import annotations

import json
import os

from .hashing import sha256_bytes
from .spec import RunSpec

REPRO_DIR = ".repro"


def env_fingerprint(cache_env: dict | None) -> str:
    """Canonical fingerprint of the execution environment the caller deems
    result-relevant (module stack, container digest, ...). Empty/None — the
    default — fingerprints to the empty string so keys stay stable for
    callers who opt out of environment keying."""
    if not cache_env:
        return ""
    canon = json.dumps(
        {str(k): str(v) for k, v in cache_env.items()},
        sort_keys=True,
        separators=(",", ":"),
    )
    return sha256_bytes(canon.encode())


class RunCache:
    """Execution-key derivation + lookup over the jobdb runcache table."""

    def __init__(self, repo, db, cache_env: dict | None = None):
        self.repo = repo
        self.db = db
        self.env_fp = env_fingerprint(cache_env)
        # rel -> ((st_size, st_mtime_ns), tree entry)
        self._entry_memo: dict[str, tuple[tuple[int, int], dict]] = {}

    # ------------------------------------------------------ key derivation
    def execution_key(self, spec: RunSpec) -> str | None:
        """The execution key for submitting ``spec`` now, or ``None`` when
        an input cannot be resolved (missing literal, unreadable file) —
        unresolvable specs are simply uncacheable and submit as novel."""
        entries = self.input_entries(spec)
        if entries is None:
            return None
        return spec.execution_key(entries, self.env_fp)

    def execution_keys(self, specs: list[RunSpec]) -> list[str | None]:
        return [self.execution_key(s) for s in specs]

    def input_entries(self, spec: RunSpec) -> list[tuple[str, dict]] | None:
        """Resolved ``(relpath, tree entry)`` pairs for every input file of
        ``spec`` (directories walk to their files), or ``None`` if any
        input is unresolvable."""
        try:
            rels = spec.expand_inputs(self.repo.root)
        except (FileNotFoundError, OSError):
            return None
        out: list[tuple[str, dict]] = []
        for rel in dict.fromkeys(rels):
            files = self._files_under(rel)
            if files is None:
                return None
            for f in files:
                entry = self._entry(f)
                if entry is None:
                    return None
                out.append((f, entry))
        return out

    def _files_under(self, rel: str) -> list[str] | None:
        abspath = os.path.join(self.repo.root, rel)
        if os.path.isdir(abspath):
            found: list[str] = []
            for dirpath, dirnames, files in os.walk(abspath):
                dirnames[:] = sorted(d for d in dirnames if d != REPRO_DIR)
                for f in sorted(files):
                    found.append(
                        os.path.relpath(os.path.join(dirpath, f), self.repo.root)
                    )
            return found
        if os.path.isfile(abspath):
            return [rel]
        return None

    def _entry(self, rel: str) -> dict | None:
        abspath = os.path.join(self.repo.root, rel)
        try:
            st = os.stat(abspath)  # raw guard stat — see module docstring
        except OSError:
            return None
        sig = (st.st_size, st.st_mtime_ns)
        memo = self._entry_memo.get(rel)
        if memo is not None and memo[0] == sig:
            return memo[1]
        try:
            entry = self.repo.hash_path_entry(rel)  # charged read pass
        except (OSError, ValueError):
            return None
        self._entry_memo[rel] = (sig, entry)
        return entry

    # ------------------------------------------------------------- lookup
    def lookup(self, exec_keys: list[str | None]) -> dict[str, dict]:
        return self.db.cache_lookup(exec_keys)

    def record(self, rows: list[dict]) -> None:
        self.db.cache_put(rows)

    def bump(self, exec_keys: list[str]) -> None:
        self.db.cache_bump(exec_keys)

    # ------------------------------------------------------- fsck / prune
    def check(self) -> list[tuple[dict, str]]:
        """Fsck the index WITHOUT mutating it: for every cache row, the
        recorded commit must exist in the object store and every recorded
        annex key must be locatable. Returns ``(row, reason)`` for each
        broken row; annex presence is ONE batched ``whereis_many`` over the
        union of keys, not a per-row sweep."""
        rows = self.db.cache_rows()
        if not rows:
            return []
        union = sorted({k for r in rows for k in r["annex_keys"]})
        located = self.repo.whereis_many(union) if union else {}
        broken: list[tuple[dict, str]] = []
        for r in rows:
            if not self.repo.objects.has(r["commit_oid"]):
                broken.append((r, f"missing commit {r['commit_oid'][:12]}"))
                continue
            lost = [k for k in r["annex_keys"] if not located.get(k)]
            if lost:
                broken.append((r, f"missing annex objects: {lost}"))
        return broken

    def evict_missing(self) -> list[str]:
        """Prune rows whose recorded commit or annex objects no longer
        exist (``Session.gc()`` hook). Returns the evicted keys."""
        bad = [r["exec_key"] for r, _ in self.check()]
        self.db.cache_evict(bad)
        return bad
