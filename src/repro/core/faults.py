"""Deterministic fault injection (DESIGN.md §10).

The paper's claim — many concurrent Slurm jobs safely sharing one data
repository — is only believable if it survives what real HPC does to a
process: parallel filesystems throw transient EIO, `sacct`/`sbatch` fail
under controller load, nodes die (NODE_FAIL), jobs are preempted, and the
finish process itself gets killed mid-batch. This module makes every one of
those failures a *first-class, seeded, replayable event* so the recovery
subsystem (:mod:`repro.core.recovery`) can be property-tested: for every
named crash point, killing there and recovering must yield a consistent
repository.

A :class:`FaultPlan` is declarative: a list of :class:`FaultRule`\\ s
("EIO on every 50th read", "fail the 3rd rename under objects/", "sacct
returns a transient error twice then succeeds", "the 2nd task started dies
with NODE_FAIL") plus a ``crash_at`` map of named :dfn:`crash points`
("finish:after-publish" -> crash on the 1st hit). The plan is threaded
through :class:`~repro.core.fsio.FS` and
:class:`~repro.core.slurm.LocalSlurmCluster`; the scheduler and the pack
layer mark their phase boundaries with ``fs.crash_point(name)``.

Crash semantics
---------------
A fired crash point (or a rule with ``error="crash"``) raises
:class:`CrashInjected` — a ``BaseException`` — and flips the plan into the
*crashed* state: from then on **every** injected filesystem and Slurm
operation raises ``CrashInjected`` too. That models a hard kill honestly:
``except``/``finally`` cleanup handlers in the dying "process" cannot
unlink tmp files, release lock files, or close job rows, because their own
I/O is already dead. Cleanup handlers that must survive *soft* errors but
not crashes re-raise via :func:`is_crash` before cleaning up.

Liveness tokens
---------------
Real crash recovery asks "is the owner of this lock / tmp file still
alive?" — normally a pid probe. A *simulated* crash happens inside a live
process, so pid-liveness alone cannot see it. Every ``FS`` therefore
carries an incarnation ``token`` registered in a process-wide live set;
a plan's crash unregisters the tokens of every FS it was attached to.
:func:`owner_is_dead` then answers correctly for all three worlds: a
genuinely dead pid, a dead simulated incarnation of this process, and a
live owner (same or foreign process).
"""
from __future__ import annotations

import fnmatch
import os
import random
import threading
import uuid
from dataclasses import dataclass, field

# -- liveness token registry -------------------------------------------------

_TOKEN_LOCK = threading.Lock()
_LIVE_TOKENS: set[str] = set()


def new_token() -> str:
    """Mint + register a live incarnation token (one per FS instance)."""
    token = uuid.uuid4().hex[:12]
    with _TOKEN_LOCK:
        _LIVE_TOKENS.add(token)
    return token


def kill_token(token: str | None) -> None:
    with _TOKEN_LOCK:
        _LIVE_TOKENS.discard(token)


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    except (OverflowError, ValueError, TypeError):
        return False
    return True


def owner_is_dead(pid, token=None) -> bool:
    """Is the (pid, token) that stamped a lock/tmp file provably dead?

    Dead iff the pid no longer exists, or the pid is *this* process but the
    incarnation token is not in the live set (a simulated crash killed it —
    or, conservatively false, a foreign same-pid-namespace writer; real
    deployments distinguish hosts via the lock's heartbeat TTL instead).
    A live foreign pid is never declared dead here — age/heartbeat rules
    are the caller's cross-host fallback."""
    if pid is None:
        return False
    if not _pid_alive(pid):
        return True
    if pid == os.getpid() and token is not None:
        with _TOKEN_LOCK:
            return token not in _LIVE_TOKENS
    return False


# -- exceptions --------------------------------------------------------------


class CrashInjected(BaseException):
    """A simulated hard process kill (kill -9 / NODE_FAIL of the client).

    Subclasses ``BaseException`` so ordinary ``except Exception`` recovery
    code never converts a simulated death into a handled error; cleanup
    handlers that catch ``BaseException`` re-raise via :func:`is_crash`."""


class InjectedIOError(IOError):
    """A filesystem fault from a :class:`FaultRule` (modeled EIO)."""

    def __init__(self, op: str, path: str, transient: bool = False):
        super().__init__(5, f"injected {'transient ' if transient else ''}EIO during {op} of {path}")
        self.op = op
        self.path = path
        self.transient = transient


class InjectedSlurmError(RuntimeError):
    """A Slurm CLI fault (sbatch/sacct failing under controller load)."""

    def __init__(self, op: str, transient: bool = False):
        super().__init__(f"injected {'transient ' if transient else ''}slurm failure in {op}")
        self.op = op
        self.transient = transient


class InjectedNetworkError(IOError):
    """A network fault from a :class:`~repro.core.remote.NetworkFaultModel`.

    ``reason`` is one of ``error`` (transient request failure), ``timeout``
    (a stall exceeded the transfer timeout) or ``disconnect`` (the link died
    mid-stream — the remote-side tmp of the in-flight transfer is stranded
    and must wait for the owner-stamped sweep). All three are transient:
    the bounded seeded retry loop may re-issue the transfer."""

    def __init__(self, op: str, remote: str, reason: str = "error",
                 transient: bool = True):
        super().__init__(
            5, f"injected network {reason} during {op} on remote {remote!r}")
        self.op = op
        self.remote = remote
        self.reason = reason
        self.transient = transient


class RemoteUnavailable(RuntimeError):
    """A whole-remote outage: the site is down, not just the request.

    Non-transient by design — retrying the same remote is pointless; the
    caller marks the store unavailable and fails over to the next replica
    (or surfaces the error if the remote was an explicit push target)."""

    def __init__(self, remote: str, why: str = "outage"):
        super().__init__(f"remote {remote!r} unavailable ({why})")
        self.remote = remote
        self.transient = False


def is_crash(exc: BaseException) -> bool:
    return isinstance(exc, CrashInjected)


def is_transient(exc: BaseException) -> bool:
    return bool(getattr(exc, "transient", False))


# -- rules & plan ------------------------------------------------------------


@dataclass
class FaultRule:
    """One declarative fault. ``op`` is the injection site:

    filesystem  ``read | write | write-chunk | rename | unlink | listdir |
                stat | exists`` (``write-chunk`` fires *mid-stream* inside
                ``FS.write_chunks`` — a torn write),
    slurm       ``sbatch | sacct | scancel``,
    tasks       ``task`` — ``error`` names the injected terminal state
                (``NODE_FAIL``, ``PREEMPTED``, ``TIMEOUT``, ``FAILED``).

    Triggering: ``nth`` fires on exactly the nth matching call; ``every``
    fires on each k-th; ``p`` fires with seeded probability; none of the
    three = every matching call. ``times`` caps total fires. ``path`` is a
    substring (or fnmatch glob) filter on the touched path. ``error`` is
    ``"io"`` (default), ``"crash"``, or a task state name."""

    op: str
    path: str | None = None
    nth: int | None = None
    every: int | None = None
    p: float | None = None
    times: int | None = None
    error: str = "io"
    transient: bool = False
    calls: int = 0
    fires: int = 0

    def _matches_path(self, path: str | None) -> bool:
        if self.path is None:
            return True
        if path is None:
            return False
        if any(c in self.path for c in "*?["):
            return fnmatch.fnmatch(path, self.path)
        return self.path in path


class FaultPlan:
    """Seeded, declarative fault schedule shared by FS + cluster + scheduler.

    Thread-safe: counters mutate under one lock (ingest workers inject
    concurrently). ``record_points=True`` turns the plan into a crash-point
    *recorder* — a clean run logs every boundary it passes in
    ``crash_point_log``, which is how the crash-matrix test discovers the
    full set of named points before killing at each one."""

    def __init__(
        self,
        seed: int = 0,
        rules: list[FaultRule] | tuple = (),
        crash_at: dict[str, int] | None = None,
        record_points: bool = False,
        max_fs_retries: int = 4,
        max_slurm_retries: int = 4,
        backoff_base_s: float = 0.05,
    ):
        self.rng = random.Random(seed)
        self.rules = list(rules)
        self.crash_at = dict(crash_at or {})
        self.record_points = record_points
        self.max_fs_retries = max_fs_retries
        self.max_slurm_retries = max_slurm_retries
        self.backoff_base_s = backoff_base_s
        self.crashed = False
        self.crash_origin: str | None = None
        self.crash_point_log: list[str] = []
        self._point_hits: dict[str, int] = {}
        self._attached_fs: list = []
        self._lock = threading.Lock()

    # -- wiring ---------------------------------------------------------
    def attach_fs(self, fs) -> None:
        with self._lock:
            self._attached_fs.append(fs)

    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff charge for retry attempt ``attempt``."""
        return self.backoff_base_s * (2 ** attempt)

    # -- firing ---------------------------------------------------------
    def _check_crashed(self) -> None:
        if self.crashed:
            raise CrashInjected(self.crash_origin or "process already crashed")

    def _fire(self, rule: FaultRule) -> bool:
        """Count one matching call; decide (under the lock) whether the
        rule fires on it."""
        with self._lock:
            rule.calls += 1
            if rule.times is not None and rule.fires >= rule.times:
                return False
            if rule.nth is not None:
                fire = rule.calls == rule.nth
            elif rule.every is not None:
                fire = rule.calls % rule.every == 0
            elif rule.p is not None:
                fire = self.rng.random() < rule.p
            else:
                fire = True
            if fire:
                rule.fires += 1
            return fire

    def _do_crash(self, origin: str) -> None:
        with self._lock:
            self.crashed = True
            self.crash_origin = origin
            dead = list(self._attached_fs)
        for fs in dead:
            kill_token(getattr(fs, "token", None))
        raise CrashInjected(origin)

    def on_fs(self, op: str, path: str, fs=None) -> None:
        """FS injection hook: called before the real operation runs."""
        self._check_crashed()
        for rule in self.rules:
            if rule.op != op or not rule._matches_path(path):
                continue
            if self._fire(rule):
                if rule.error == "crash":
                    self._do_crash(f"{op}:{path}")
                raise InjectedIOError(op, path, transient=rule.transient)

    def on_slurm(self, op: str) -> None:
        """Slurm CLI injection hook (sbatch/sacct/scancel)."""
        self._check_crashed()
        for rule in self.rules:
            if rule.op != op:
                continue
            if self._fire(rule):
                if rule.error == "crash":
                    self._do_crash(f"slurm:{op}")
                raise InjectedSlurmError(op, transient=rule.transient)

    def task_fate(self) -> str | None:
        """Forced terminal state for the task now starting (rules with
        ``op="task"``; ``error`` is the state name), or None to run it."""
        self._check_crashed()
        for rule in self.rules:
            if rule.op != "task":
                continue
            if self._fire(rule):
                return rule.error
        return None

    def crash_point(self, name: str, fs=None) -> None:
        """A named phase boundary. Crashes when ``crash_at[name]`` hits
        are reached; always appended to the log when recording."""
        self._check_crashed()
        with self._lock:
            hits = self._point_hits.get(name, 0) + 1
            self._point_hits[name] = hits
            if self.record_points:
                self.crash_point_log.append(name)
        want = self.crash_at.get(name)
        if want is not None and hits == want:
            self._do_crash(name)
