"""Machine-actionable reproducibility records (paper §3, Figures 2 & 4).

A record is a structured JSON block embedded in the commit message between
sentinel lines, exactly like DataLad's ``[DATALAD RUNCMD]``:

    [REPRO RUNCMD] <human title>

    === Do not change lines below ===
    { "chain": [], "cmd": ..., "dsid": ..., "exit": 0,
      "extra_inputs": [], "inputs": [...], "outputs": [...], "pwd": "." }
    ^^^ Do not change lines above ^^^

``run`` executes a command and commits its outputs with such a record;
``rerun`` re-executes a past record and *hash-verifies* the outputs against
the recorded tree (paper §3 step 8: "based on file hashes and doesn't even
need the original outputs"). Scheduler records (Figure 4) add slurm fields.
"""
from __future__ import annotations

import json
import os
import subprocess
from dataclasses import dataclass, field

from .repo import Repository

BEGIN = "=== Do not change lines below ==="
END = "^^^ Do not change lines above ^^^"

TITLE_RUN = "[REPRO RUNCMD]"
TITLE_SLURM = "[REPRO SLURM RUN]"


@dataclass
class RunRecord:
    cmd: str
    dsid: str
    inputs: list[str] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)
    extra_inputs: list[str] = field(default_factory=list)
    chain: list[str] = field(default_factory=list)
    exit: int | None = 0
    pwd: str = "."
    # slurm extension fields (paper Fig. 4); None for plain run records
    slurm_job_id: int | None = None
    slurm_outputs: list[str] | None = None
    extras: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        d = {
            "chain": self.chain,
            "cmd": self.cmd,
            "dsid": self.dsid,
            "exit": self.exit,
            "extra_inputs": self.extra_inputs,
            "inputs": self.inputs,
            "outputs": self.outputs,
            "pwd": self.pwd,
        }
        if self.slurm_job_id is not None:
            d["slurm_job_id"] = self.slurm_job_id
            d["slurm_outputs"] = self.slurm_outputs or []
        d.update(self.extras)
        return d

    def to_message(self, title: str, kind: str = TITLE_RUN) -> str:
        body = json.dumps(self.to_json(), indent=1, sort_keys=True)
        return f"{kind} {title}\n\n{BEGIN}\n{body}\n{END}\n"

    @classmethod
    def from_message(cls, message: str) -> "RunRecord | None":
        if BEGIN not in message or END not in message:
            return None
        blob = message.split(BEGIN, 1)[1].split(END, 1)[0]
        d = json.loads(blob)
        known = {
            "chain", "cmd", "dsid", "exit", "extra_inputs", "inputs", "outputs",
            "pwd", "slurm_job_id", "slurm_outputs",
        }
        extras = {k: v for k, v in d.items() if k not in known}
        return cls(
            cmd=d["cmd"],
            dsid=d["dsid"],
            inputs=d.get("inputs", []),
            outputs=d.get("outputs", []),
            extra_inputs=d.get("extra_inputs", []),
            chain=d.get("chain", []),
            exit=d.get("exit"),
            pwd=d.get("pwd", "."),
            slurm_job_id=d.get("slurm_job_id"),
            slurm_outputs=d.get("slurm_outputs"),
            extras=extras,
        )


class RunFailed(RuntimeError):
    def __init__(self, cmd: str, returncode: int, stderr: str = ""):
        super().__init__(f"command failed (exit {returncode}): {cmd}\n{stderr}")
        self.returncode = returncode


def _prepare_io(repo: Repository, inputs: list[str], outputs: list[str]) -> None:
    """Paper §3 step 1: datalad-get inputs, unlock outputs."""
    for p in inputs:
        abspath = os.path.join(repo.root, p)
        if os.path.isdir(abspath):
            for dirpath, _, files in os.walk(abspath):
                for f in files:
                    repo.annex_get(os.path.relpath(os.path.join(dirpath, f), repo.root))
        elif os.path.exists(abspath):
            repo.annex_get(p)
        else:
            raise FileNotFoundError(f"input does not exist: {p}")
    for p in outputs:
        repo.unlock(p)


def run(
    repo: Repository,
    cmd: str,
    inputs: list[str] | None = None,
    outputs: list[str] | None = None,
    message: str = "",
    pwd: str = ".",
    chain: list[str] | None = None,
) -> str:
    """``datalad run`` equivalent: execute ``cmd``, commit outputs + record.

    Returns the commit oid. The command runs blocking (paper §3 step 2); a
    non-zero exit aborts without committing.
    """
    inputs = inputs or []
    outputs = outputs or []
    _prepare_io(repo, inputs, outputs)
    workdir = os.path.join(repo.root, pwd)
    proc = subprocess.run(
        cmd, shell=True, cwd=workdir, capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RunFailed(cmd, proc.returncode, proc.stderr)
    record = RunRecord(
        cmd=cmd,
        dsid=repo.dsid,
        inputs=inputs,
        outputs=outputs,
        chain=chain or [],
        exit=0,
        pwd=pwd,
    )
    save_paths = outputs if outputs else None
    return repo.save(paths=save_paths, message=record.to_message(message or cmd))


def rerun(repo: Repository, commitish: str, report_only: bool = False) -> dict:
    """``datalad rerun`` equivalent (paper §3 steps 6-8).

    Re-executes the record at ``commitish`` with the *current* inputs, then
    hash-compares the produced outputs against the recorded tree. If bitwise
    identical, no new commit is made. Returns a report dict:
    ``{"bitwise": bool, "new_commit": oid|None, "outputs": {path: same?}}``.
    """
    oid = repo.resolve(commitish)
    commit = repo.objects.get_commit(oid)
    record = RunRecord.from_message(commit["message"])
    if record is None:
        raise ValueError(f"commit {oid} has no reproducibility record")
    recorded_tree = repo.tree_of(oid)

    _prepare_io(repo, record.inputs, record.outputs)
    workdir = os.path.join(repo.root, record.pwd)
    proc = subprocess.run(
        record.cmd, shell=True, cwd=workdir, capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RunFailed(record.cmd, proc.returncode, proc.stderr)

    # hash-verify each output against the recorded entries
    per_output: dict[str, bool] = {}
    changed = False
    for out in record.outputs:
        abspath = os.path.join(repo.root, out)
        paths = []
        if os.path.isdir(abspath):
            for dirpath, _, files in os.walk(abspath):
                paths.extend(
                    os.path.relpath(os.path.join(dirpath, f), repo.root) for f in files
                )
        else:
            paths.append(out)
        for p in paths:
            new_entry = repo._hash_working_file(p)
            same = recorded_tree.get(p) == new_entry
            per_output[p] = same
            changed |= not same
    report = {"bitwise": not changed, "new_commit": None, "outputs": per_output}
    if changed and not report_only:
        new_record = RunRecord(
            cmd=record.cmd,
            dsid=repo.dsid,
            inputs=record.inputs,
            outputs=record.outputs,
            chain=record.chain + [oid],
            exit=0,
            pwd=record.pwd,
        )
        report["new_commit"] = repo.save(
            paths=record.outputs or None,
            message=new_record.to_message(f"rerun of {oid[:12]}"),
        )
    return report
