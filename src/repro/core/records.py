"""Machine-actionable reproducibility records (paper §3, Figures 2 & 4).

A record is a structured JSON block embedded in the commit message between
sentinel lines, exactly like DataLad's ``[DATALAD RUNCMD]``:

    [REPRO RUNCMD] <human title>

    === Do not change lines below ===
    { "chain": [], "cmd": ..., "dsid": ..., "exit": 0,
      "extra_inputs": [], "inputs": [...], "outputs": [...], "pwd": ".",
      "spec": { ...RunSpec JSON... } }
    ^^^ Do not change lines above ^^^

``run`` executes a command and commits its outputs with such a record;
``rerun`` re-executes a past record and *hash-verifies* the outputs against
the recorded tree (paper §3 step 8: "based on file hashes and doesn't even
need the original outputs"). Scheduler records (Figure 4) add slurm fields.

Since the spec layer (``repro.core.spec``), every execution is driven by a
declarative :class:`~repro.core.spec.RunSpec` and the spec's JSON is embedded
twice: as a first-class ``spec`` field of the commit object itself (so replay
needs no message parsing at all) and inside the RUNCMD block (for human /
DataLad-style introspection). ``rerun`` deserializes that spec verbatim —
byte-identical ``spec_id`` — and only falls back to reconstructing a spec
from the legacy free-text record fields for pre-spec history.
"""
from __future__ import annotations

import json
import os
import subprocess
from dataclasses import dataclass, field

from .conflicts import has_wildcard, normalize, proper_prefixes
from .repo import Repository
from .spec import RunSpec, SpecError

BEGIN = "=== Do not change lines below ==="
END = "^^^ Do not change lines above ^^^"

TITLE_RUN = "[REPRO RUNCMD]"
TITLE_SLURM = "[REPRO SLURM RUN]"


@dataclass
class RunRecord:
    cmd: str
    dsid: str
    inputs: list[str] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)
    extra_inputs: list[str] = field(default_factory=list)
    chain: list[str] = field(default_factory=list)
    exit: int | None = 0
    pwd: str = "."
    # the originating RunSpec, verbatim (None only for pre-spec history)
    spec: dict | None = None
    # slurm extension fields (paper Fig. 4); None for plain run records
    slurm_job_id: int | None = None
    slurm_outputs: list[str] | None = None
    extras: dict = field(default_factory=dict)

    @property
    def memoized(self) -> bool:
        """True for a §11 run-cache hit: no execution happened — the record
        replays an earlier run's recorded result."""
        return bool(self.extras.get("memoized"))

    @property
    def memoized_of(self) -> str | None:
        """The original run's commit oid for a memoized record, else None."""
        return self.extras.get("memoized_of")

    def to_json(self) -> dict:
        d = {
            "chain": self.chain,
            "cmd": self.cmd,
            "dsid": self.dsid,
            "exit": self.exit,
            "extra_inputs": self.extra_inputs,
            "inputs": self.inputs,
            "outputs": self.outputs,
            "pwd": self.pwd,
        }
        if self.spec is not None:
            d["spec"] = self.spec
        if self.slurm_job_id is not None:
            d["slurm_job_id"] = self.slurm_job_id
            d["slurm_outputs"] = self.slurm_outputs or []
        d.update(self.extras)
        return d

    def to_message(self, title: str, kind: str = TITLE_RUN) -> str:
        body = json.dumps(self.to_json(), indent=1, sort_keys=True)
        return f"{kind} {title}\n\n{BEGIN}\n{body}\n{END}\n"

    @classmethod
    def from_message(cls, message: str) -> "RunRecord | None":
        if BEGIN not in message or END not in message:
            return None
        blob = message.split(BEGIN, 1)[1].split(END, 1)[0]
        d = json.loads(blob)
        known = {
            "chain", "cmd", "dsid", "exit", "extra_inputs", "inputs", "outputs",
            "pwd", "spec", "slurm_job_id", "slurm_outputs",
        }
        extras = {k: v for k, v in d.items() if k not in known}
        return cls(
            cmd=d["cmd"],
            dsid=d["dsid"],
            inputs=d.get("inputs", []),
            outputs=d.get("outputs", []),
            extra_inputs=d.get("extra_inputs", []),
            chain=d.get("chain", []),
            exit=d.get("exit"),
            pwd=d.get("pwd", "."),
            spec=d.get("spec"),
            slurm_job_id=d.get("slurm_job_id"),
            slurm_outputs=d.get("slurm_outputs"),
            extras=extras,
        )


class RunFailed(RuntimeError):
    def __init__(self, cmd: str, returncode: int, stderr: str = ""):
        super().__init__(f"command failed (exit {returncode}): {cmd}\n{stderr}")
        self.returncode = returncode


def _prepare_io(repo: Repository, spec: RunSpec) -> None:
    """Paper §3 step 1: datalad-get inputs, unlock outputs. Wildcard inputs
    glob-expand against the worktree (datalad-run semantics, matching what
    ``SlurmScheduler`` accepts); a missing literal input raises."""
    for p in spec.expand_inputs(repo.root):
        abspath = os.path.join(repo.root, p)
        if os.path.isdir(abspath):
            for dirpath, _, files in os.walk(abspath):
                for f in files:
                    repo.annex_get(os.path.relpath(os.path.join(dirpath, f), repo.root))
        else:
            repo.annex_get(p)
    for p in spec.outputs:
        repo.unlock(p)


def _execute_spec(repo: Repository, spec: RunSpec) -> None:
    """Blocking execution of a command spec from its recorded ``pwd``, with
    the spec's env overlayed. Non-zero exit raises :class:`RunFailed`."""
    if spec.cmd is None:
        raise SpecError(
            "a script spec is scheduled, not run; use SlurmScheduler.submit "
            "/ Session.submit (or reschedule for provenance replay)"
        )
    _prepare_io(repo, spec)
    workdir = os.path.join(repo.root, spec.pwd)
    env = None
    if spec.env:
        env = dict(os.environ)
        env.update(dict(spec.env))
    proc = subprocess.run(
        spec.cmd, shell=True, cwd=workdir, env=env, capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RunFailed(spec.cmd, proc.returncode, proc.stderr)


def run_spec(repo: Repository, spec: RunSpec, chain: list[str] | None = None) -> str:
    """Execute a command :class:`RunSpec` and commit outputs + record.

    The spec JSON rides along verbatim — as the commit object's ``spec``
    field and inside the RUNCMD block — so ``rerun`` reconstructs the exact
    spec (equal ``spec_id``). Returns the commit oid; a non-zero exit aborts
    without committing.
    """
    _execute_spec(repo, spec)
    spec_json = spec.to_json()
    record = RunRecord(
        cmd=spec.cmd,
        dsid=repo.dsid,
        inputs=list(spec.inputs),
        outputs=list(spec.outputs),
        chain=chain or [],
        exit=0,
        pwd=spec.pwd,
        spec=spec_json,
    )
    save_paths = list(spec.outputs) if spec.outputs else None
    return repo.save(
        paths=save_paths,
        message=record.to_message(spec.title()),
        spec=spec_json,
    )


def run(
    repo: Repository,
    cmd: str,
    inputs: list[str] | None = None,
    outputs: list[str] | None = None,
    message: str = "",
    pwd: str = ".",
    chain: list[str] | None = None,
    env: dict | None = None,
) -> str:
    """``datalad run`` equivalent — legacy keyword shim over :func:`run_spec`.

    Builds a validated :class:`RunSpec` and delegates; prefer
    ``Session.run`` / :func:`run_spec` in new code.
    """
    spec = RunSpec(
        cmd=cmd,
        inputs=tuple(inputs or ()),
        outputs=tuple(outputs or ()),
        pwd=pwd,
        message=message,
        env=tuple((env or {}).items()),
    )
    return run_spec(repo, spec, chain=chain)


def spec_of(repo: Repository, commitish: str) -> RunSpec:
    """The originating :class:`RunSpec` of a recorded commit.

    Prefers the commit object's first-class ``spec`` field (no message
    involvement at all), then the spec embedded in the RUNCMD block, and
    only for pre-spec history reconstructs an equivalent spec from the
    legacy record fields.
    """
    oid = repo.resolve(commitish)
    commit = repo.objects.get_commit(oid)
    return _spec_from_commit(oid, commit, RunRecord.from_message(commit["message"]))


def _fold_legacy_outputs(outputs: list[str]) -> tuple[str, ...]:
    """Pre-spec records were never validated, so their output lists may
    contain duplicates, entries nested under a listed directory, or even
    wildcards — all of which `RunSpec` construction rejects. Fold them into
    a spec-legal equivalent (normalize, dedup, drop nested entries, drop
    wildcards) so that history stays replayable: a directory entry's walk
    covers anything that was nested under it."""
    normed: list[str] = []
    seen: set[str] = set()
    for o in outputs:
        if has_wildcard(o):
            continue
        try:
            n = normalize(o)
        except ValueError:
            continue
        if n not in seen:
            seen.add(n)
            normed.append(n)
    return tuple(
        n for n in normed if not any(p in seen for p in proper_prefixes(n))
    )


def _spec_from_commit(oid: str, commit: dict, record: RunRecord | None) -> RunSpec:
    """Spec extraction shared by ``spec_of`` and ``rerun`` (which already
    hold the fetched commit + parsed record)."""
    spec_json = commit.get("spec")
    if spec_json is not None:
        return RunSpec.from_json(spec_json)
    if record is None:
        raise ValueError(f"commit {oid} has no reproducibility record")
    if record.spec is not None:
        return RunSpec.from_json(record.spec)
    # pre-spec history: reassemble from the record's free-form fields
    if record.slurm_job_id is not None:
        outputs = [
            o for o in record.outputs
            if o not in (record.slurm_outputs or [])
            and not os.path.basename(o).startswith(("log.slurm-", "slurm-job-"))
        ]
        return RunSpec(
            script=record.extras.get(
                "script", record.cmd.removeprefix("sbatch ").split()[0]
            ),
            script_args=record.extras.get("script_args", ""),
            inputs=tuple(record.inputs),
            outputs=_fold_legacy_outputs(outputs),
            pwd=record.pwd,
            alt_dir=record.extras.get("alt_dir"),
            array_n=int(record.extras.get("array_n", 1)),
        )
    return RunSpec(
        cmd=record.cmd,
        inputs=tuple(record.inputs),
        outputs=_fold_legacy_outputs(record.outputs),
        pwd=record.pwd,
    )


def rerun(repo: Repository, commitish: str, report_only: bool = False) -> dict:
    """``datalad rerun`` equivalent (paper §3 steps 6-8).

    Reconstructs the commit's originating :class:`RunSpec` (verbatim for
    spec-recorded history), re-executes it with the *current* inputs, then
    hash-compares the produced outputs against the recorded tree. If bitwise
    identical, no new commit is made. Returns a report dict:
    ``{"bitwise": bool, "new_commit": oid|None, "outputs": {path: same?},
    "spec_id": str}``.
    """
    oid = repo.resolve(commitish)
    commit = repo.objects.get_commit(oid)
    record = RunRecord.from_message(commit["message"])
    spec = _spec_from_commit(oid, commit, record)
    chain = (record.chain if record else []) + [oid]
    recorded_tree = repo.tree_of(oid)

    _execute_spec(repo, spec)

    # hash-verify each output against the recorded entries
    per_output: dict[str, bool] = {}
    changed = False
    for out in spec.outputs:
        abspath = os.path.join(repo.root, out)
        paths = []
        if os.path.isdir(abspath):
            for dirpath, _, files in os.walk(abspath):
                paths.extend(
                    os.path.relpath(os.path.join(dirpath, f), repo.root) for f in files
                )
        else:
            paths.append(out)
        for p in paths:
            new_entry = repo.hash_path_entry(p)  # read-only: no writes
            same = recorded_tree.get(p) == new_entry
            per_output[p] = same
            changed |= not same
    report = {
        "bitwise": not changed,
        "new_commit": None,
        "outputs": per_output,
        "spec_id": spec.spec_id,
    }
    if changed and not report_only:
        spec_json = spec.to_json()
        new_record = RunRecord(
            cmd=spec.cmd,
            dsid=repo.dsid,
            inputs=list(spec.inputs),
            outputs=list(spec.outputs),
            chain=chain,
            exit=0,
            pwd=spec.pwd,
            spec=spec_json,
        )
        report["new_commit"] = repo.save(
            paths=list(spec.outputs) or None,
            message=new_record.to_message(f"rerun of {oid[:12]}"),
            spec=spec_json,
        )
    return report
