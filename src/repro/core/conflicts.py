"""Output-conflict detection: protected names N and prefixes P (paper §5.5).

A job's output specification (files or exclusive directories) is checked
against the outputs of all *currently scheduled* jobs. With the path
normalized relative to the repository root:

    (1) name ∈ N                      -> conflict (same output claimed twice)
    (2) name ∈ P                      -> conflict (claims a super-directory of
                                          another job's output)
    (3) any proper prefix of name ∈ N -> conflict (a super-directory is
                                          already claimed exclusively)

If no check fires, ``name`` joins N and all its proper prefixes join P.
This is O(depth) per output with hash sets — the feasibility answer to the
regex-intersection problem that rules out wildcards (§5.4, citing
Backurs & Indyk 2016).
"""
from __future__ import annotations

import posixpath

WILDCARD_CHARS = set("*?[]{}")


class OutputConflict(Exception):
    def __init__(self, name: str, reason: str, other_job: int | None = None):
        self.name = name
        self.reason = reason
        self.other_job = other_job
        job = f" (held by job {other_job})" if other_job is not None else ""
        super().__init__(f"output conflict on {name!r}: {reason}{job}")


class WildcardOutputError(ValueError):
    def __init__(self, name: str):
        super().__init__(
            f"wildcard patterns are not allowed in output specifications: {name!r} "
            "(paper §5.4: potential-conflict matching between regular expressions "
            "is infeasible)"
        )


def has_wildcard(name: str) -> bool:
    return any(c in WILDCARD_CHARS for c in name)


def normalize(name: str) -> str:
    """Normalize to a repo-root-relative posix path without '..' or trailing /."""
    name = name.replace("\\", "/")
    norm = posixpath.normpath(name)
    if norm.startswith("/"):
        norm = norm.lstrip("/")
    if norm.startswith("..") or norm in (".", ""):
        raise ValueError(f"output path escapes the repository or is empty: {name!r}")
    return norm


def proper_prefixes(name: str) -> list[str]:
    """All non-trivial super-directories, e.g. 'a/b/c' -> ['a/b', 'a']."""
    out = []
    parts = name.split("/")
    for i in range(len(parts) - 1, 0, -1):
        out.append("/".join(parts[:i]))
    return out


def check_intra_job(normed: list[str]) -> None:
    """Reject two outputs of the same job that are equal or nested (used by
    both the in-memory N/P sets and the job database's indexed checks).
    O(outputs x depth): each output's proper prefixes are probed against the
    full set, which catches nesting in either listing order."""
    seen = set(normed)
    if len(seen) != len(normed):
        dup = next(n for n in normed if normed.count(n) > 1)
        raise OutputConflict(dup, "listed twice in the same job")
    for n in normed:
        for pre in proper_prefixes(n):
            if pre in seen:
                raise OutputConflict(n, f"nested under sibling output {pre!r}")


class ProtectedOutputs:
    """In-memory N/P sets with the three §5.5 checks.

    ``owners`` maps a protected name (in N) to the owning job id so conflicts
    can report who holds the claim. The persistent counterpart lives in the
    job database (:mod:`repro.core.jobdb`); this class is also used standalone
    in tests and benchmarks.
    """

    def __init__(self) -> None:
        self.names: dict[str, int] = {}  # N: name -> owning job
        self.prefixes: dict[str, set[int]] = {}  # P: prefix -> jobs using it

    def check(self, name: str) -> None:
        """Raise OutputConflict if ``name`` conflicts; no mutation."""
        name = normalize(name)
        if has_wildcard(name):
            raise WildcardOutputError(name)
        if name in self.names:  # check (1)
            raise OutputConflict(name, "already protected", self.names[name])
        if name in self.prefixes:  # check (2)
            other = next(iter(self.prefixes[name]))
            raise OutputConflict(
                name, "is a super-directory of another job's output", other
            )
        for pre in proper_prefixes(name):  # check (3)
            if pre in self.names:
                raise OutputConflict(
                    name,
                    f"super-directory {pre!r} is claimed exclusively",
                    self.names[pre],
                )

    def add(self, name: str, job_id: int) -> None:
        name = normalize(name)
        self.names[name] = job_id
        for pre in proper_prefixes(name):
            self.prefixes.setdefault(pre, set()).add(job_id)

    def check_and_add_all(self, names: list[str], job_id: int) -> list[str]:
        """Atomically check every output, then protect all of them. Also
        rejects intra-job conflicts (two outputs of the same job nesting)."""
        normed = [normalize(n) for n in names]
        for n in normed:
            self.check(n)
        check_intra_job(normed)
        for n in normed:
            self.add(n, job_id)
        return normed

    def release(self, job_id: int) -> None:
        self.names = {n: j for n, j in self.names.items() if j != job_id}
        for pre in list(self.prefixes):
            self.prefixes[pre].discard(job_id)
            if not self.prefixes[pre]:
                del self.prefixes[pre]
