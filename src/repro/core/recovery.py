"""Crash recovery: locks, intent journals, recover(), verify() (DESIGN.md §10).

The scheduler's durability story before this module was *ordering*: publish
the ref before closing the job, write the pack before unlinking loose
objects. Ordering bounds the damage of a crash but doesn't clean it up — a
finish process killed mid-batch leaves open job rows whose commits exist but
were never published, annex ``tmp-*`` files, a held lock file, and (worst) a
window between ref publish and job close where a naive re-finish would
commit the same job twice. This module closes that story:

``FileLock``
    An O_CREAT|O_EXCL lock file stamped with ``(pid, incarnation token,
    heartbeat timestamp)``. Staleness is decided by
    :func:`repro.core.faults.owner_is_dead` (dead pid, or dead simulated
    incarnation of this process) with a heartbeat TTL as the cross-host
    fallback; stale locks are broken automatically on acquire. Used for the
    finish publish phase (``refs``) and for ``repack`` — a crash can no
    longer disable compaction or ref publication forever.

Intent journals
    ``submit_many`` and ``finish`` write a journal file under
    ``.repro/journal/`` before their effects start landing (header via
    fsynced tmp+rename, one JSONL line appended per applied step, unlink on
    completion). The finish journal records each job's commit oid *before*
    the ref is published, so replay can distinguish the three crash windows:
    committed-not-published (publish from the journal), published-not-closed
    (close the row), and not-yet-committed (re-run finish for exactly those
    jobs — re-ingest is idempotent via content addressing, and the orphaned
    pre-crash commit, if any, is unreachable garbage rather than a duplicate
    published record). That is the exactly-once guarantee.

``recover(session)``
    Break stale locks, sweep dead-owner annex tmps, replay journals, close
    unsubmitted orphan rows, release orphaned output protection.

``verify(session)``
    fsck: cross-checks refs ↔ object store ↔ annex ↔ jobdb and reports
    divergence (broken refs, missing annex objects, duplicate slurm
    records, orphan rows/protection); ``repair=True`` fixes what can be
    fixed without inventing data.
"""
from __future__ import annotations

import json
import os
import socket
import time
from typing import TYPE_CHECKING

from .faults import InjectedNetworkError, RemoteUnavailable, owner_is_dead

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycles
    from .fsio import FS
    from .session import Session

JOURNAL_DIR = "journal"
LOCKS_DIR = "locks"


class LockHeld(RuntimeError):
    """The lock is held by a live owner and the wait budget ran out."""


class FileLock:
    """Crash-safe advisory lock: an exclusive file stamped with owner
    identity. Complements (never replaces) the in-process locks — threads
    serialize on ``Repository.ref_lock`` / ``ObjectStore._repack_lock``
    first, so this file only arbitrates across processes and across crash
    boundaries.

    Staleness: owner pid dead, owner's incarnation token dead (simulated
    crash in this process), an unparseable payload (torn by a crash), or a
    heartbeat older than ``ttl_s`` (cross-host fallback; long holders call
    :meth:`beat`). Stale locks are broken and re-acquired atomically —
    ``create_exclusive`` arbitrates racing breakers."""

    _GONE = object()  # sentinel: lock file vanished between probe and read

    def __init__(self, fs: "FS", path: str, ttl_s: float | None = 600.0):
        self.fs = fs
        self.path = path
        self.ttl_s = ttl_s
        self._held = False

    def _payload(self) -> bytes:
        return json.dumps({
            "pid": os.getpid(),
            "token": getattr(self.fs, "token", None),
            "host": socket.gethostname(),
            "heartbeat": time.time(),
        }).encode()

    def read_info(self):
        try:
            data = self.fs.read_bytes(self.path)
        except FileNotFoundError:
            return self._GONE
        try:
            info = json.loads(data)
            return info if isinstance(info, dict) else None
        except (ValueError, UnicodeDecodeError):
            return None  # torn payload -> crashed writer -> stale

    def is_stale(self, info) -> bool:
        if info is self._GONE:
            return False
        if info is None:
            return True
        if owner_is_dead(info.get("pid"), info.get("token")):
            return True
        hb = info.get("heartbeat")
        if self.ttl_s is not None and isinstance(hb, (int, float)):
            return (time.time() - hb) > self.ttl_s
        return False

    def break_if_stale(self) -> bool:
        """Recovery sweep entry: break the lock iff its owner is dead."""
        info = self.read_info()
        if info is self._GONE or not self.is_stale(info):
            return False
        self.break_lock()
        return True

    def break_lock(self) -> None:
        try:
            self.fs.unlink(self.path)
        except FileNotFoundError:
            pass

    def acquire(self, wait_s: float = 30.0, poll_s: float = 0.02) -> "FileLock":
        deadline = time.monotonic() + wait_s
        while True:
            try:
                self.fs.create_exclusive(self.path, self._payload())
                self._held = True
                return self
            except FileExistsError:
                info = self.read_info()
                if info is self._GONE:
                    continue  # released between probe and read: retry now
                if self.is_stale(info):
                    self.break_lock()
                    continue
                if time.monotonic() >= deadline:
                    raise LockHeld(
                        f"{self.path} held by pid {info.get('pid')}"
                        f" on {info.get('host')}"
                    ) from None
                time.sleep(poll_s)

    def beat(self) -> None:
        """Refresh the heartbeat (long-held locks: repack of a huge store)."""
        if self._held:
            self.fs.write_atomic(self.path, self._payload(), fsync=False)

    def release(self) -> None:
        if self._held:
            self._held = False
            try:
                self.fs.unlink(self.path)
            except FileNotFoundError:
                pass
            except Exception:
                # release runs during exception unwind: it must not mask the
                # error that got us here, and a lock left behind by a failed
                # charged unlink would wedge the next holder until the TTL.
                # Raw best-effort fallback — an injected hard crash is a
                # BaseException and still propagates, keeping the lock held
                # exactly like a dead process would.
                try:
                    os.unlink(self.path)
                except OSError:
                    pass

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


# -- intent journal ----------------------------------------------------------


def _journal_dir(repro_dir: str) -> str:
    return os.path.join(repro_dir, JOURNAL_DIR)


class JournalHandle:
    """One in-flight batch's journal file. The header line is published
    atomically (fsynced tmp+rename) *before* any effect of the batch lands;
    per-step lines are appended as each effect is applied; :meth:`done`
    retires the journal. Present journal file == possibly-interrupted batch."""

    _seq = 0

    def __init__(self, fs: "FS", path: str):
        self.fs = fs
        self.path = path

    @classmethod
    def begin(cls, fs: "FS", repro_dir: str, kind: str, header: dict) -> "JournalHandle":
        cls._seq += 1
        name = (
            f"{kind}-{int(time.time() * 1000):013d}-{os.getpid()}"
            f"-{cls._seq:04d}.jsonl"
        )
        path = os.path.join(_journal_dir(repro_dir), name)
        line = json.dumps({"kind": kind, **header}, sort_keys=True) + "\n"
        fs.write_atomic(path, line.encode(), fsync=True)
        return cls(fs, path)

    def append(self, record: dict) -> None:
        self.fs.append_text(self.path, json.dumps(record, sort_keys=True) + "\n")

    def append_many(self, records: list[dict]) -> None:
        """Batch append: ONE charged write for a whole batch's entries. The
        §11 memoize path journals N hits in a single append so the per-hit
        charge stays at ~one commit write; all-or-nothing durability of the
        batch's lines is exactly what its replay assumes."""
        if not records:
            return
        self.fs.append_text(
            self.path,
            "".join(json.dumps(r, sort_keys=True) + "\n" for r in records),
        )

    def done(self) -> None:
        try:
            self.fs.unlink(self.path)
        except FileNotFoundError:
            pass


def list_journals(fs: "FS", repro_dir: str) -> list[str]:
    d = _journal_dir(repro_dir)
    if not os.path.isdir(d):
        return []
    return [os.path.join(d, n) for n in fs.listdir(d) if n.endswith(".jsonl")]


def read_journal(fs: "FS", path: str) -> tuple[dict | None, list[dict]]:
    """(header, entries). A torn trailing line (the crash interrupted an
    append) is skipped — its effect never happened or will be re-derived.
    A torn/missing header returns (None, [])."""
    try:
        raw = fs.read_bytes(path)
    except FileNotFoundError:
        return None, []
    records = []
    for line in raw.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue  # torn write: drop
        if isinstance(rec, dict):
            records.append(rec)
    if not records or "kind" not in records[0]:
        return None, []
    return records[0], records[1:]


# -- recover -----------------------------------------------------------------


def recover(
    session: "Session",
    close_unsubmitted: bool = True,
    max_tmp_age_s: float | None = 3600.0,
) -> dict:
    """Bring the repository back to a consistent state after a crash.
    Idempotent; cheap when there is nothing to do. See module docstring
    for the exactly-once argument. Returns a report dict."""
    repo = session.repo
    fs = repo.fs
    sched = session.scheduler
    db = sched.db
    report = {
        "locks_broken": 0,
        "stale_tmps_swept": 0,
        "journals_replayed": 0,
        "slurm_ids_recovered": 0,
        "commits_republished": 0,
        "memoized_republished": 0,
        "jobs_refinished": 0,
        "jobs_closed_unsubmitted": 0,
        "protection_released": 0,
        "pushes_resumed": 0,
        "pulls_resumed": 0,
        "remote_keys_resent": 0,
        "dag_pipelines_resumed": 0,
        "dag_levels_resubmitted": 0,
        "errors": [],
    }
    # 1. stale locks — before journal replay, which needs to take them
    locks_dir = os.path.join(repo.repro_dir, LOCKS_DIR)
    if os.path.isdir(locks_dir):
        for name in fs.listdir(locks_dir):
            if not name.endswith(".lock"):
                continue
            if FileLock(fs, os.path.join(locks_dir, name)).break_if_stale():
                report["locks_broken"] += 1
    # 2. dead-owner annex tmps (local store + remotes)
    for store in [repo.annex] + list(repo._remotes):
        report["stale_tmps_swept"] += store.sweep_stale_tmps(
            max_age_s=max_tmp_age_s
        )
    # 3. journals, oldest first (names sort by timestamp) — with one
    # exception: ``dag`` journals replay LAST, after every submit journal
    # has recovered its level's slurm ids / closed its dead rows. Replaying
    # a pipeline before its levels' own journals would misread rows the
    # submit replay was about to fix and double-submit their stages.
    journals = [
        (path, *read_journal(fs, path))
        for path in sorted(list_journals(fs, repo.repro_dir))
    ]
    is_dag = lambda h: h is not None and h.get("kind") == "dag"  # noqa: E731
    ordered = (
        [j for j in journals if not is_dag(j[1])]
        + [j for j in journals if is_dag(j[1])]
    )
    for path, header, entries in ordered:
        ok = True
        if header is None:
            pass  # header never landed: the batch had no effects yet
        elif header.get("kind") == "submit":
            _replay_submit(db, header, entries, report)
        elif header.get("kind") == "finish":
            ok = _replay_finish(session, header, entries, report)
        elif header.get("kind") == "memoize":
            ok = _replay_memoize(session, header, entries, report)
        elif header.get("kind") == "push":
            ok = _replay_push(session, header, entries, report)
        elif header.get("kind") == "pull":
            ok = _replay_pull(session, header, entries, report)
        elif header.get("kind") == "dag":
            ok = _replay_dag(session, header, entries, report)
        if ok:
            fs.unlink(path)
            report["journals_replayed"] += 1
    # 4. orphan rows a journal never covered (crash before the journal, or
    # pre-journal databases)
    if close_unsubmitted:
        for row in db.unsubmitted_open_jobs():
            db.close_job(row["job_id"], status="closed-unsubmitted")
            report["jobs_closed_unsubmitted"] += 1
    # 5. protection owned by rows that are no longer open
    orphans = db.orphan_protection()
    if orphans:
        db.release_protection(orphans)
        report["protection_released"] += len(orphans)
    return report


def _replay_submit(db, header: dict, entries: list[dict], report: dict) -> None:
    """Crash window: between sbatch calls and the batched set_slurm_ids.
    Every journaled (job_id, slurm_id) pair IS submitted — persist it (the
    UPDATE is idempotent). Header-listed jobs with no journaled pair never
    reached sbatch — close them, releasing their output protection."""
    pairs = [
        (e["job_id"], e["slurm_id"])
        for e in entries
        if "job_id" in e and "slurm_id" in e
    ]
    if pairs:
        db.set_slurm_ids(pairs)
        report["slurm_ids_recovered"] += len(pairs)
    for job_id in header.get("job_ids", ()):
        row = db.get(job_id)
        if row and row["status"] == "scheduled" and row["slurm_id"] is None:
            db.close_job(job_id, status="closed-unsubmitted")
            report["jobs_closed_unsubmitted"] += 1


def _replay_finish(session: "Session", header: dict, entries: list[dict],
                   report: dict) -> bool:
    """Exactly-once finish replay. Per journaled entry (written after the
    commit object existed, before the ref moved):

      row closed                  -> done pre-crash, skip;
      commit exists, ref at it    -> publish landed, just close the row;
      commit exists, ref at its   -> publish from the journal — never
        parent                       recommit;
      otherwise                   -> fall through to a re-finish.

    Jobs with no (usable) entry are re-finished through the normal path —
    their ingest work is deduplicated by content addressing, and any
    pre-crash commit object that existed but wasn't journaled is
    unreachable garbage, not a published duplicate. Returns False when the
    re-finish couldn't run (e.g. the cluster no longer knows the jobs), in
    which case the journal is kept for a later recover()."""
    repo = session.repo
    sched = session.scheduler
    db = sched.db
    flags = header.get("flags", {})
    branch = header.get("branch")
    octopus_done = any("octopus" in e for e in entries)
    branch_names: list[str] = []
    for e in entries:
        if "octopus" in e:
            continue
        jid = e.get("job_id")
        commit = e.get("commit")
        job_branch = e.get("job_branch")
        if job_branch:
            branch_names.append(job_branch)
        row = db.get(jid) if jid is not None else None
        if row is None or row["status"] != "scheduled":
            continue
        if not commit or not repo.objects.has(commit):
            continue  # commit never landed: re-finish below
        if job_branch:
            # per-job-branch mode: the branch roots at the shared base and
            # only this job ever publishes it
            if repo.branch_head(job_branch) != commit:
                repo.set_branch(job_branch, commit)
            db.close_job(jid, status="finished")
            report["commits_republished"] += 1
        else:
            head = repo.branch_head(branch)
            parents = repo.objects.get_commit(commit).get("parents", [])
            if head == commit:
                db.close_job(jid, status="finished")
                report["commits_republished"] += 1
            elif head in parents:
                repo.set_branch(branch, commit)
                db.close_job(jid, status="finished")
                report["commits_republished"] += 1
            # else: the chain advanced past other commits first — this
            # journaled commit can't fast-forward; re-finish the job
    remaining = [
        j["job_id"] for j in header.get("jobs", ())
        if (db.get(j["job_id"]) or {}).get("status") == "scheduled"
    ]
    if remaining:
        try:
            res = sched.finish(
                job_ids=remaining,
                close_failed_jobs=flags.get("close_failed_jobs", False),
                commit_failed_jobs=flags.get("commit_failed_jobs", False),
                branches=flags.get("branches", False),
                octopus=False,  # merged below, with the replayed branches
                engine=flags.get("engine", "incremental"),
                data_plane=flags.get("data_plane", "fused"),
            )
        except Exception as e:
            report["errors"].append(f"re-finish of jobs {remaining}: {e}")
            return False
        report["jobs_refinished"] += len(remaining)
        branch_names += [r.branch for r in res if r.branch]
    if flags.get("octopus") and branch_names and not octopus_done:
        heads = {
            h for h in (repo.branch_head(b) for b in branch_names)
            if h is not None
        }
        head_commit = repo.head_commit()
        merged = (
            set(repo.objects.get_commit(head_commit).get("parents", []))
            if head_commit else set()
        )
        if heads and not heads <= merged:
            repo.merge_octopus(
                sorted(set(branch_names)),
                message=(
                    f"octopus merge of {len(set(branch_names))} slurm jobs"
                    " (recovered)"
                ),
            )
    return True


def _replay_memoize(session: "Session", header: dict, entries: list[dict],
                    report: dict) -> bool:
    """Exactly-once §11 memoize replay. The memoize journal is written in
    two strokes: the header before any commit, then ONE batched append
    naming every (job_id, commit) after ALL commits exist but before the
    single ref publication. So either no entries survived (the commits, if
    any, are unreachable garbage — republish every still-open hit from the
    durable cache rows) or every entry's commit exists and the only
    question is how far the ref moved, answered by walking the current head
    back to the journaled base.

    Cache rows are never (re-)inserted here — memoization only *reads* the
    index, and `JobDB.cache_put` is keyed INSERT OR REPLACE — so replay
    cannot double-insert cache entries."""
    from .jobdb import job_spec

    repo = session.repo
    sched = session.scheduler
    db = sched.db
    branch = header.get("branch")
    base = header.get("base")
    # commits already reachable from the head, back to the journaled base
    published: set[str] = set()
    head = repo.branch_head(branch) if branch else None
    oid = head
    while oid and oid != base and repo.objects.has(oid):
        published.add(oid)
        parents = repo.objects.get_commit(oid).get("parents", [])
        oid = parents[0] if parents else None
    last = head
    for e in entries:
        jid = e.get("job_id")
        commit = e.get("commit")
        row = db.get(jid) if jid is not None else None
        if row is None or row["status"] != "scheduled":
            continue
        if not commit or not repo.objects.has(commit):
            continue  # never landed: republished from the cache rows below
        if commit in published or head == commit:
            db.close_job(jid, status="memoized")
            report["memoized_republished"] += 1
            continue
        parents = repo.objects.get_commit(commit).get("parents", [])
        if last in parents or (last is None and not parents):
            repo.set_branch(branch, commit)
            published.add(commit)
            last = head = commit
            db.close_job(jid, status="memoized")
            report["memoized_republished"] += 1
    # hits the crash left without a journaled commit: re-derive them from
    # the cache index (durable since their original run) and re-publish
    remaining = [
        j for j in header.get("jobs", ())
        if (db.get(j.get("job_id")) or {}).get("status") == "scheduled"
    ]
    if remaining:
        cached = db.cache_lookup([j.get("exec_key") for j in remaining])
        hits = []
        for j in remaining:
            jid = j["job_id"]
            row = cached.get(j.get("exec_key"))
            if row is None:
                # index row gone (evicted between crash and recovery):
                # nothing to replay from — close the orphan, releasing its
                # output protection, and surface the loss
                db.close_job(jid, status="closed-unsubmitted")
                report["jobs_closed_unsubmitted"] += 1
                report["errors"].append(
                    f"memoize replay: cache row missing for job {jid}"
                )
                continue
            hits.append((jid, job_spec(db.get(jid)), j["exec_key"], row))
        if hits:
            try:
                sched._publish_memoized(hits)
            except Exception as e:
                report["errors"].append(
                    f"memoize re-publish of jobs"
                    f" {[h[0] for h in hits]}: {e}"
                )
                return False
            report["memoized_republished"] += len(hits)
    return True


def _replay_push(session: "Session", header: dict, entries: list[dict],
                 report: dict) -> bool:
    """Exactly-once push replay (DESIGN §13). The push journal records the
    intent (remote + key list) before byte one and appends one entry per
    key fully landed on the remote (manifest bound last). Replay simply
    re-runs the push over the *whole* key list: the batched fresh presence
    pre-pass skips every journaled key and — for the key the crash
    interrupted mid-object — every chunk that already landed, so only the
    chunks absent from the remote are re-sent and nothing duplicates.
    Returns False (journal kept) when the remote is currently unreachable;
    a remote that vanished from the config retires the journal with an
    error recorded."""
    from .remote import push_keys

    repo = session.repo
    keys = list(header.get("keys", ()))
    done = {e["key"] for e in entries if "key" in e}
    try:
        store = repo.remote_by_name(header.get("remote"))
    except KeyError:
        report["errors"].append(
            f"push replay: remote {header.get('remote')!r} no longer configured"
        )
        return True  # nothing to resume against
    try:
        r = push_keys(repo, store, keys, journal=False,
                      db=session.scheduler.db)
    except (InjectedNetworkError, RemoteUnavailable) as e:
        report["errors"].append(f"push replay to {store.name}: {e}")
        return False  # keep the journal for a later recover()
    report["pushes_resumed"] += 1
    report["remote_keys_resent"] += max(0, r["keys_sent"] - (len(keys) - len(done)))
    return True


def _replay_pull(session: "Session", header: dict, entries: list[dict],
                 report: dict) -> bool:
    """Exactly-once pull replay: re-run the pull over the journaled key
    list — keys already local (journaled or landed just before the crash)
    are skipped by pull's missing-only filter, chunks already local by its
    presence pre-pass. Returns False (journal kept) when no replica can
    currently serve a key."""
    from .remote import pull_keys

    repo = session.repo
    del entries  # completed keys are detected locally, not from the journal
    try:
        pull_keys(repo, list(header.get("keys", ())), journal=False,
                  db=session.scheduler.db)
    except (InjectedNetworkError, RemoteUnavailable, FileNotFoundError) as e:
        report["errors"].append(f"pull replay: {e}")
        return False
    report["pulls_resumed"] += 1
    return True


def _replay_dag(session: "Session", header: dict, entries: list[dict],
                report: dict) -> bool:
    """Exactly-once pipeline-submission replay (§14).

    Crash window: anywhere between the dag journal's creation and its
    retirement. The header carries the complete pipeline (stage specs +
    edges), so the DAG is rebuilt and walked level by level:

      - stages whose rows landed (found by their pipeline/stage row tags,
        after every submit journal has already replayed) are *reused* —
        their dependency edges are re-recorded idempotently;
      - rows the crash left open with no slurm id are closed (the standard
        unsubmitted-orphan rule) and their stages resubmitted;
      - stages with no row at all are resubmitted, chained via afterok onto
        whichever parent rows are real jobs.

    Nothing runs twice: landed rows are never re-sbatched, and the
    resubmission goes through submit_many's own journal discipline.
    """
    from .dag import Pipeline, PipelineError
    from .spec import RunSpec, SpecError

    del entries  # what landed is read back from the tagged rows, not trusted
    sched = session.scheduler
    db = sched.db
    pid = header["pipeline"]
    try:
        pipeline = Pipeline({
            n: RunSpec.from_json(js)
            for n, js in header.get("stages", {}).items()
        })
    except (PipelineError, SpecError, KeyError, TypeError) as e:
        report["errors"].append(f"dag replay {pid}: bad journal header: {e}")
        return True  # unreplayable: retire it; the rows tell the story
    rows = db.pipeline_rows(pid)
    stage_jobs: dict[str, int] = {}
    resubmitted = 0
    for i, level in enumerate(pipeline.levels()):
        missing: list[str] = []
        for name in level:
            row = rows.get(name)
            if row is None:
                missing.append(name)
                continue
            if row["status"] == "scheduled" and row["slurm_id"] is None:
                # submission never completed and no submit journal covered
                # it: close the orphan (releasing protection) and redo it
                db.close_job(row["job_id"], status="closed-unsubmitted")
                report["jobs_closed_unsubmitted"] += 1
                missing.append(name)
                continue
            if row["status"] in ("closed-unsubmitted", "submit-failed"):
                missing.append(name)
                continue
            stage_jobs[name] = row["job_id"]
        # re-record landed stages' edges: the crash may have hit between
        # dag:level-submitted and dag:deps-recorded (add_deps is idempotent)
        db.add_deps(
            [
                (stage_jobs[c], stage_jobs[p])
                for c in level if c in stage_jobs
                for p in pipeline.parents[c] if p in stage_jobs
            ],
            pipeline=pid,
        )
        if not missing:
            continue
        try:
            sched._submit_level(
                pipeline, pid, i, missing, stage_jobs,
                refresh=bool(header.get("refresh")),
            )
        except Exception as e:
            report["errors"].append(f"dag replay {pid} level {i}: {e}")
            return False
        resubmitted += 1
    report["dag_levels_resubmitted"] += resubmitted
    report["dag_pipelines_resumed"] += 1
    return True


# -- verify (fsck) -----------------------------------------------------------

_DIVERGENCE_KINDS = {
    "broken-ref",
    "missing-commit",
    "missing-annex",
    "missing-chunk",
    "broken-manifest",
    "duplicate-record",
    "orphan-job",
    "orphan-protection",
    "broken-cache",
    "remote-manifest-divergence",
}


def verify(session: "Session", repair: bool = False) -> dict:
    """Cross-check jobdb ↔ refs ↔ object store ↔ annex (``repro fsck``).

    Reports issues as ``{"kind", "detail", ...}`` dicts; ``divergence``
    counts the ones that mean the stores disagree (stale tmps and pending
    journals are warnings — recover() owns those). ``repair=True`` fixes
    what is safe: re-ingests a missing annex object from an intact worktree
    copy, closes orphan rows, releases orphan protection, sweeps dead tmps.
    Never invents data — a missing annex object with no worktree copy stays
    reported."""
    from .records import RunRecord  # local: records -> repo -> recovery

    repo = session.repo
    fs = repo.fs
    db = session.scheduler.db
    issues: list[dict] = []
    repaired: list[dict] = []

    def issue(kind: str, detail: str, **extra) -> dict:
        rec = {"kind": kind, "detail": detail, **extra}
        issues.append(rec)
        return rec

    # -- refs -> commits -> trees: walk every branch once ---------------
    annex_keys: dict[str, str] = {}  # key -> an example path needing it
    slurm_records: dict[int, list[str]] = {}
    seen: set[str] = set()
    n_commits = 0
    for b in repo.branches():
        head = repo.branch_head(b)
        if head is None:
            continue
        if not repo.objects.has(head):
            issue("broken-ref", f"branch {b} points at missing commit", branch=b,
                  commit=head)
            continue
        frontier = [head]
        while frontier:
            oid = frontier.pop()
            if oid in seen:
                continue
            seen.add(oid)
            try:
                commit = repo.objects.get_commit(oid)
            except Exception:
                issue("missing-commit", f"commit {oid[:12]} unreadable",
                      commit=oid)
                continue
            n_commits += 1
            rec = RunRecord.from_message(commit.get("message", ""))
            if rec is not None and rec.slurm_job_id is not None:
                slurm_records.setdefault(rec.slurm_job_id, []).append(oid)
            frontier.extend(commit.get("parents", []))
        try:
            for path, entry in repo.tree_of(head).items():
                if entry.get("t") == "annex":
                    annex_keys.setdefault(entry["key"], path)
        except Exception as e:
            issue("broken-ref", f"tree of branch {b} unreadable: {e}", branch=b)

    # exactly-once: one published record per slurm job, ever
    for slurm_id, oids in sorted(slurm_records.items()):
        if len(oids) > 1:
            issue(
                "duplicate-record",
                f"slurm job {slurm_id} recorded by {len(oids)} commits",
                slurm_id=slurm_id, commits=sorted(oids),
            )

    # -- annex presence across all stores --------------------------------
    if annex_keys:
        where = repo.whereis_many(sorted(annex_keys))
        for key, path in sorted(annex_keys.items()):
            if where.get(key):
                continue
            rec = issue("missing-annex", f"no store holds {key} ({path})",
                        key=key, path=path)
            if repair:
                abspath = os.path.join(repo.root, path)
                if os.path.isfile(abspath):
                    try:
                        if repo.hash_path_entry(path).get("key") == key:
                            repo.annex.put_file(key, abspath)
                            rec["repaired"] = True
                            repaired.append(rec)
                    except Exception:
                        pass

    # -- chunk tier (§12): a store holding a manifest must hold its chunks --
    # (that is the invariant read/copy_to depend on — chunk presence in
    # *some other* store doesn't make this store's manifest readable)
    if annex_keys and repo.annex.chunk_aware:
        stores = [
            s for s in [repo.annex, *repo._remotes]
            if getattr(s, "available", True)  # a dead site can't be fsck'd
        ]
        for key, path in sorted(annex_keys.items()):
            # local truth for the §13 remote-manifest fsck: what the local
            # store says the chunk list of this key is (None when the local
            # copy is absent or stored whole)
            truth: list[str] | None = None
            if repo.annex.has(key):
                try:
                    truth = repo.annex.manifest_of(key)
                except (OSError, ValueError):
                    pass  # flagged as broken-manifest in the loop below
            for store in stores:
                if not store.has(key):
                    continue
                try:
                    chunks = store.manifest_of(key)
                except (OSError, ValueError) as e:
                    issue("broken-manifest", f"{key} in {store.name}: {e}",
                          key=key, store=store.name)
                    continue
                if (
                    store is not repo.annex
                    and chunks is not None
                    and truth is not None
                    and chunks != truth
                ):
                    # same key => same content => same cutter output: a
                    # remote manifest that disagrees with local truth is
                    # corruption, not a legitimate alternative encoding
                    rec = issue(
                        "remote-manifest-divergence",
                        f"{store.name} manifest for {key} disagrees with "
                        f"local truth ({len(chunks)} vs {len(truth)} chunks)",
                        key=key, store=store.name,
                    )
                    if repair:
                        try:
                            for ck in truth:
                                if not store.has(ck):
                                    store.receive_file(
                                        ck, repo.annex.fs, repo.annex._path(ck)
                                    )
                            store.drop(key)
                            store.put_manifest(key, truth)
                            rec["repaired"] = True
                            repaired.append(rec)
                            chunks = truth
                        except Exception:
                            pass
                if not chunks:
                    continue
                for ck in sorted(set(chunks) - store.has_many(chunks)):
                    rec = issue(
                        "missing-chunk",
                        f"{store.name} lacks chunk {ck} of {key} ({path})",
                        key=key, chunk=ck, store=store.name, path=path,
                    )
                    if not repair:
                        continue
                    # safe repairs only: copy the chunk from a store that
                    # still has it, else re-cut an intact worktree copy
                    # (the returned key proves the content was genuine)
                    try:
                        src = next(
                            (s for s in stores if s is not store and s.has(ck)),
                            None,
                        )
                        if src is not None:
                            # route through the transfer methods so network
                            # stores charge the link, not the local profile
                            if src is repo.annex:
                                store.receive_file(ck, src.fs, src._path(ck))
                            else:
                                src.fetch_into(ck, store)
                            rec["repaired"] = True
                            repaired.append(rec)
                        elif store is repo.annex:
                            abspath = os.path.join(repo.root, path)
                            if os.path.isfile(abspath) and (
                                repo.annex.ingest_file(abspath, chunked=True)
                                == key
                            ):
                                rec["repaired"] = True
                                repaired.append(rec)
                    except Exception:
                        pass

    # -- jobdb ------------------------------------------------------------
    for row in db.unsubmitted_open_jobs():
        rec = issue(
            "orphan-job",
            f"job {row['job_id']} open with no slurm id",
            job_id=row["job_id"],
        )
        if repair:
            db.close_job(row["job_id"], status="closed-unsubmitted")
            rec["repaired"] = True
            repaired.append(rec)
    orphans = db.orphan_protection()
    if orphans:
        rec = issue(
            "orphan-protection",
            f"closed jobs {orphans} still hold output protection",
            job_ids=orphans,
        )
        if repair:
            db.release_protection(orphans)
            rec["repaired"] = True
            repaired.append(rec)

    # -- run-cache index (§11): every row must still be materializable ----
    from .runcache import RunCache

    for row, reason in RunCache(repo, db).check():
        rec = issue(
            "broken-cache",
            f"cache row {row['exec_key'][:12]}: {reason}",
            exec_key=row["exec_key"], commit=row["commit_oid"],
        )
        if repair:
            # eviction is always safe: the cache is derived state — losing
            # a row costs a re-execution, never data
            db.cache_evict([row["exec_key"]])
            rec["repaired"] = True
            repaired.append(rec)

    # -- remote-location hints (jobdb v4): cross-check vs fresh probes ----
    # Location rows are derived state recorded after verified transfers —
    # like the known-key set, they are hints: disagreement is a *warning*
    # (repair refreshes the rows), never divergence, because nothing
    # numcopies-critical ever trusts them.
    loc_rows = db.locations_all()
    if loc_rows:
        from .remote import RemoteStore

        by_remote: dict[str, list[str]] = {}
        for key, rname in loc_rows:
            by_remote.setdefault(rname, []).append(key)
        store_names = {s.name for s in repo._remotes}
        for rname, loc_keys in sorted(by_remote.items()):
            if rname not in store_names:
                rec = issue(
                    "stale-location",
                    f"{len(loc_keys)} location rows for unknown remote {rname!r}",
                    remote=rname, count=len(loc_keys),
                )
                if repair:
                    db.locations_forget(rname)
                    rec["repaired"] = True
                    repaired.append(rec)
                continue
            store = repo.remote_by_name(rname)
            if isinstance(store, RemoteStore) and not store.available:
                continue  # a dead site can't be cross-checked; hints stay
            try:
                present = store.has_many(loc_keys, fresh=True)
            except Exception:
                continue  # unreachable right now: leave the hints alone
            gone = sorted(set(loc_keys) - present)
            if gone:
                rec = issue(
                    "stale-location",
                    f"{rname} no longer holds {len(gone)} recorded key(s)",
                    remote=rname, count=len(gone),
                )
                if repair:
                    db.locations_forget(rname, gone)
                    rec["repaired"] = True
                    repaired.append(rec)

    # -- crash litter (warnings: recover() owns these) -------------------
    for path in list_journals(fs, repo.repro_dir):
        issue("pending-journal", f"unreplayed journal {os.path.basename(path)}",
              path=path)
    for store in [repo.annex] + list(repo._remotes):
        n = store.count_stale_tmps()
        if n:
            rec = issue("stale-tmp", f"{n} dead-owner tmp files in {store.name}",
                        store=store.name, count=n)
            if repair:
                store.sweep_stale_tmps(max_age_s=None)
                rec["repaired"] = True
                repaired.append(rec)
    locks_dir = os.path.join(repo.repro_dir, LOCKS_DIR)
    if os.path.isdir(locks_dir):
        for name in fs.listdir(locks_dir):
            if not name.endswith(".lock"):
                continue
            lock = FileLock(fs, os.path.join(locks_dir, name))
            info = lock.read_info()
            if info is not FileLock._GONE and lock.is_stale(info):
                rec = issue("stale-lock", f"dead-owner lock {name}", lock=name)
                if repair:
                    lock.break_lock()
                    rec["repaired"] = True
                    repaired.append(rec)

    unrepaired = [i for i in issues if not i.get("repaired")]
    return {
        "divergence": sum(
            1 for i in unrepaired if i["kind"] in _DIVERGENCE_KINDS
        ),
        "issues": issues,
        "repaired": repaired,
        "checked_commits": n_commits,
        "checked_annex_keys": len(annex_keys),
    }
