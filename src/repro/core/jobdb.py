"""Intermediate job database (paper §5.3).

A sqlite database *hidden from the data repository* — it lives under
``.repro/`` which is never committed, so it is never synchronized via the
version store. Its scope is the current clone; a single instance is shared by
all branches. It tracks every scheduled-but-not-finished job and persists the
protected-output sets N and P used by the §5.5 conflict checks.

The checks run as indexed point lookups against the ``protected`` table —
O(path depth) queries per output — never by loading the whole table into
memory, so ``add_job``/``check_outputs`` stay O(1) in the number of
scheduled jobs and protected paths.
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time

from .conflicts import (
    OutputConflict,
    WildcardOutputError,
    check_intra_job,
    has_wildcard,
    normalize,
    proper_prefixes,
)

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id      INTEGER PRIMARY KEY AUTOINCREMENT,
    slurm_id    INTEGER,
    status      TEXT NOT NULL DEFAULT 'scheduled',
    script      TEXT NOT NULL,
    script_args TEXT NOT NULL DEFAULT '',
    pwd         TEXT NOT NULL DEFAULT '.',
    inputs      TEXT NOT NULL DEFAULT '[]',
    outputs     TEXT NOT NULL DEFAULT '[]',
    alt_dir     TEXT,
    is_array    INTEGER NOT NULL DEFAULT 0,
    array_n     INTEGER NOT NULL DEFAULT 1,
    message     TEXT NOT NULL DEFAULT '',
    submitted_at REAL NOT NULL,
    finished_at REAL,
    heartbeat   REAL
);
CREATE TABLE IF NOT EXISTS protected (
    name   TEXT NOT NULL,
    kind   TEXT NOT NULL CHECK (kind IN ('name', 'prefix')),
    job_id INTEGER NOT NULL REFERENCES jobs(job_id),
    PRIMARY KEY (name, kind, job_id)
);
CREATE INDEX IF NOT EXISTS idx_protected_name ON protected(name, kind);
CREATE INDEX IF NOT EXISTS idx_jobs_status ON jobs(status);
"""


class JobDB:
    def __init__(self, repro_dir: str):
        self.path = os.path.join(repro_dir, "jobdb.sqlite")
        self._local = threading.local()
        with self._conn() as c:
            c.executescript(_SCHEMA)

    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=30.0)
            conn.row_factory = sqlite3.Row
            self._local.conn = conn
        return conn

    # ------------------------------------------------------------------
    def add_job(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
    ) -> int:
        """Insert a job and protect its outputs atomically.

        Performs the §5.5 conflict checks against the persisted N/P sets
        inside the same transaction, so two concurrent ``schedule`` calls
        cannot both claim the same output.
        """
        conn = self._conn()
        with conn:  # single transaction: check + insert + protect
            cur = conn.execute(
                "INSERT INTO jobs (script, script_args, pwd, inputs, outputs,"
                " alt_dir, is_array, array_n, message, submitted_at)"
                " VALUES (?,?,?,?,?,?,?,?,?,?)",
                (
                    script,
                    script_args,
                    pwd,
                    json.dumps(inputs or []),
                    json.dumps(outputs),
                    alt_dir,
                    int(array_n > 1),
                    array_n,
                    message,
                    time.time(),
                ),
            )
            job_id = cur.lastrowid
            normed = [normalize(n) for n in outputs]
            for n in normed:
                self._check_one(conn, n)  # raises on conflict -> rollback
            check_intra_job(normed)
            conn.executemany(
                "INSERT OR IGNORE INTO protected (name, kind, job_id) VALUES (?,?,?)",
                [(n, "name", job_id) for n in normed]
                + [
                    (p, "prefix", job_id)
                    for n in normed
                    for p in proper_prefixes(n)
                ],
            )
            conn.execute(
                "UPDATE jobs SET outputs=? WHERE job_id=?",
                (json.dumps(normed), job_id),
            )
        return job_id

    @staticmethod
    def _check_one(conn: sqlite3.Connection, name: str) -> None:
        """The three §5.5 checks as indexed point lookups against the
        persisted N/P sets — O(path depth) queries, never a full table load.
        ``name`` must already be normalized."""
        if has_wildcard(name):
            raise WildcardOutputError(name)
        row = conn.execute(
            "SELECT job_id FROM protected WHERE name=? AND kind='name' LIMIT 1",
            (name,),
        ).fetchone()
        if row:  # check (1): name in N
            raise OutputConflict(name, "already protected", row[0])
        row = conn.execute(
            "SELECT job_id FROM protected WHERE name=? AND kind='prefix' LIMIT 1",
            (name,),
        ).fetchone()
        if row:  # check (2): name in P
            raise OutputConflict(
                name, "is a super-directory of another job's output", row[0]
            )
        for pre in proper_prefixes(name):  # check (3): a proper prefix in N
            row = conn.execute(
                "SELECT job_id FROM protected WHERE name=? AND kind='name' LIMIT 1",
                (pre,),
            ).fetchone()
            if row:
                raise OutputConflict(
                    name,
                    f"super-directory {pre!r} is claimed exclusively",
                    row[0],
                )

    def check_outputs(self, outputs: list[str]) -> None:
        """Non-mutating §5.5 check (used by reschedule previews)."""
        conn = self._conn()
        for o in outputs:
            self._check_one(conn, normalize(o))

    # ------------------------------------------------------------------
    def set_slurm_id(self, job_id: int, slurm_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET slurm_id=? WHERE job_id=?", (slurm_id, job_id))

    def heartbeat(self, job_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET heartbeat=? WHERE job_id=?", (time.time(), job_id))

    def close_job(self, job_id: int, status: str) -> None:
        """Mark finished/failed-closed and release protected outputs."""
        with self._conn() as c:
            c.execute(
                "UPDATE jobs SET status=?, finished_at=? WHERE job_id=?",
                (status, time.time(), job_id),
            )
            c.execute("DELETE FROM protected WHERE job_id=?", (job_id,))

    def get(self, job_id: int) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM jobs WHERE job_id=?", (job_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def by_slurm_id(self, slurm_id: int) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM jobs WHERE slurm_id=?", (slurm_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def open_jobs(self) -> list[dict]:
        rows = self._conn().execute(
            "SELECT * FROM jobs WHERE status='scheduled' ORDER BY job_id"
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def n_protected(self) -> int:
        return self._conn().execute(
            "SELECT COUNT(*) FROM protected WHERE kind='name'"
        ).fetchone()[0]


def _to_dict(row: sqlite3.Row) -> dict:
    d = dict(row)
    d["inputs"] = json.loads(d["inputs"])
    d["outputs"] = json.loads(d["outputs"])
    return d
