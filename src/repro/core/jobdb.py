"""Intermediate job database (paper §5.3).

A sqlite database *hidden from the data repository* — it lives under
``.repro/`` which is never committed, so it is never synchronized via the
version store. Its scope is the current clone; a single instance is shared by
all branches. It tracks every scheduled-but-not-finished job and persists the
protected-output sets N and P used by the §5.5 conflict checks.
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time

from .conflicts import OutputConflict, ProtectedOutputs

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id      INTEGER PRIMARY KEY AUTOINCREMENT,
    slurm_id    INTEGER,
    status      TEXT NOT NULL DEFAULT 'scheduled',
    script      TEXT NOT NULL,
    script_args TEXT NOT NULL DEFAULT '',
    pwd         TEXT NOT NULL DEFAULT '.',
    inputs      TEXT NOT NULL DEFAULT '[]',
    outputs     TEXT NOT NULL DEFAULT '[]',
    alt_dir     TEXT,
    is_array    INTEGER NOT NULL DEFAULT 0,
    array_n     INTEGER NOT NULL DEFAULT 1,
    message     TEXT NOT NULL DEFAULT '',
    submitted_at REAL NOT NULL,
    finished_at REAL,
    heartbeat   REAL
);
CREATE TABLE IF NOT EXISTS protected (
    name   TEXT NOT NULL,
    kind   TEXT NOT NULL CHECK (kind IN ('name', 'prefix')),
    job_id INTEGER NOT NULL REFERENCES jobs(job_id),
    PRIMARY KEY (name, kind, job_id)
);
CREATE INDEX IF NOT EXISTS idx_protected_name ON protected(name, kind);
CREATE INDEX IF NOT EXISTS idx_jobs_status ON jobs(status);
"""


class JobDB:
    def __init__(self, repro_dir: str):
        self.path = os.path.join(repro_dir, "jobdb.sqlite")
        self._local = threading.local()
        with self._conn() as c:
            c.executescript(_SCHEMA)

    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=30.0)
            conn.row_factory = sqlite3.Row
            self._local.conn = conn
        return conn

    # ------------------------------------------------------------------
    def add_job(
        self,
        script: str,
        outputs: list[str],
        inputs: list[str] | None = None,
        script_args: str = "",
        pwd: str = ".",
        alt_dir: str | None = None,
        array_n: int = 1,
        message: str = "",
    ) -> int:
        """Insert a job and protect its outputs atomically.

        Performs the §5.5 conflict checks against the persisted N/P sets
        inside the same transaction, so two concurrent ``schedule`` calls
        cannot both claim the same output.
        """
        conn = self._conn()
        with conn:  # single transaction: check + insert + protect
            prot = self._load_protected(conn)
            cur = conn.execute(
                "INSERT INTO jobs (script, script_args, pwd, inputs, outputs,"
                " alt_dir, is_array, array_n, message, submitted_at)"
                " VALUES (?,?,?,?,?,?,?,?,?,?)",
                (
                    script,
                    script_args,
                    pwd,
                    json.dumps(inputs or []),
                    json.dumps(outputs),
                    alt_dir,
                    int(array_n > 1),
                    array_n,
                    message,
                    time.time(),
                ),
            )
            job_id = cur.lastrowid
            normed = prot.check_and_add_all(outputs, job_id)  # raises on conflict
            conn.executemany(
                "INSERT OR IGNORE INTO protected (name, kind, job_id) VALUES (?,?,?)",
                [(n, "name", job_id) for n in normed]
                + [
                    (p, "prefix", job_id)
                    for n in normed
                    for p in _prefixes(n)
                ],
            )
            conn.execute(
                "UPDATE jobs SET outputs=? WHERE job_id=?",
                (json.dumps(normed), job_id),
            )
        return job_id

    @staticmethod
    def _load_protected(conn: sqlite3.Connection) -> ProtectedOutputs:
        prot = ProtectedOutputs()
        for row in conn.execute("SELECT name, kind, job_id FROM protected"):
            if row["kind"] == "name":
                prot.names[row["name"]] = row["job_id"]
            else:
                prot.prefixes.setdefault(row["name"], set()).add(row["job_id"])
        return prot

    def check_outputs(self, outputs: list[str]) -> None:
        """Non-mutating §5.5 check (used by reschedule previews)."""
        conn = self._conn()
        prot = self._load_protected(conn)
        for o in outputs:
            prot.check(o)

    # ------------------------------------------------------------------
    def set_slurm_id(self, job_id: int, slurm_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET slurm_id=? WHERE job_id=?", (slurm_id, job_id))

    def heartbeat(self, job_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET heartbeat=? WHERE job_id=?", (time.time(), job_id))

    def close_job(self, job_id: int, status: str) -> None:
        """Mark finished/failed-closed and release protected outputs."""
        with self._conn() as c:
            c.execute(
                "UPDATE jobs SET status=?, finished_at=? WHERE job_id=?",
                (status, time.time(), job_id),
            )
            c.execute("DELETE FROM protected WHERE job_id=?", (job_id,))

    def get(self, job_id: int) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM jobs WHERE job_id=?", (job_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def by_slurm_id(self, slurm_id: int) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM jobs WHERE slurm_id=?", (slurm_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def open_jobs(self) -> list[dict]:
        rows = self._conn().execute(
            "SELECT * FROM jobs WHERE status='scheduled' ORDER BY job_id"
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def n_protected(self) -> int:
        return self._conn().execute(
            "SELECT COUNT(*) FROM protected WHERE kind='name'"
        ).fetchone()[0]


def _prefixes(name: str) -> list[str]:
    parts = name.split("/")
    return ["/".join(parts[:i]) for i in range(len(parts) - 1, 0, -1)]


def _to_dict(row: sqlite3.Row) -> dict:
    d = dict(row)
    d["inputs"] = json.loads(d["inputs"])
    d["outputs"] = json.loads(d["outputs"])
    return d
