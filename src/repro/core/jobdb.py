"""Intermediate job database (paper §5.3).

A sqlite database *hidden from the data repository* — it lives under
``.repro/`` which is never committed, so it is never synchronized via the
version store. Its scope is the current clone; a single instance is shared by
all branches. It tracks every scheduled-but-not-finished job and persists the
protected-output sets N and P used by the §5.5 conflict checks.

Every job row stores the canonical JSON of its originating
:class:`~repro.core.spec.RunSpec`, so ``reschedule`` / straggler resubmission
deserialize the exact spec instead of reassembling keyword arguments from
the legacy columns (which are kept, populated from the spec, for
introspection and pre-spec databases).

The checks run as indexed point lookups against the ``protected`` table —
O(path depth) queries per output — never by loading the whole table into
memory, so ``add_job``/``check_outputs`` stay O(1) in the number of
scheduled jobs and protected paths. :meth:`add_jobs` amortizes a whole
batch: N inserts + one shared conflict pass in ONE transaction (each output
checked exactly once, cross-spec conflicts included because earlier specs'
protection rows are visible to later checks inside the same transaction).
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time

from .conflicts import (
    OutputConflict,
    WildcardOutputError,
    has_wildcard,
    normalize,
    proper_prefixes,
)
from .spec import RunSpec

# Ordered schema migrations, tracked by ``PRAGMA user_version``. Each step
# runs exactly once per database; a fresh database replays all of them, a
# pre-versioning database has its version detected from its shape first.
_SCHEMA_V1 = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id      INTEGER PRIMARY KEY AUTOINCREMENT,
    slurm_id    INTEGER,
    status      TEXT NOT NULL DEFAULT 'scheduled',
    script      TEXT NOT NULL,
    script_args TEXT NOT NULL DEFAULT '',
    pwd         TEXT NOT NULL DEFAULT '.',
    inputs      TEXT NOT NULL DEFAULT '[]',
    outputs     TEXT NOT NULL DEFAULT '[]',
    alt_dir     TEXT,
    is_array    INTEGER NOT NULL DEFAULT 0,
    array_n     INTEGER NOT NULL DEFAULT 1,
    message     TEXT NOT NULL DEFAULT '',
    submitted_at REAL NOT NULL,
    finished_at REAL,
    heartbeat   REAL
);
CREATE TABLE IF NOT EXISTS protected (
    name   TEXT NOT NULL,
    kind   TEXT NOT NULL CHECK (kind IN ('name', 'prefix')),
    job_id INTEGER NOT NULL REFERENCES jobs(job_id),
    PRIMARY KEY (name, kind, job_id)
);
CREATE INDEX IF NOT EXISTS idx_protected_name ON protected(name, kind);
CREATE INDEX IF NOT EXISTS idx_jobs_status ON jobs(status);
"""

_SCHEMA_V2 = """
ALTER TABLE jobs ADD COLUMN spec TEXT;
"""

_SCHEMA_V3 = """
CREATE TABLE IF NOT EXISTS runcache (
    exec_key    TEXT PRIMARY KEY,
    spec_id     TEXT NOT NULL,
    commit_oid  TEXT NOT NULL,
    output_tree TEXT NOT NULL,
    annex_keys  TEXT NOT NULL DEFAULT '[]',
    created_at  REAL NOT NULL,
    hits        INTEGER NOT NULL DEFAULT 0,
    last_hit    REAL
);
CREATE INDEX IF NOT EXISTS idx_runcache_spec ON runcache(spec_id);
ALTER TABLE jobs ADD COLUMN exec_key TEXT;
"""

_SCHEMA_V4 = """
CREATE TABLE IF NOT EXISTS annex_locations (
    key     TEXT NOT NULL,
    remote  TEXT NOT NULL,
    seen_at REAL NOT NULL,
    PRIMARY KEY (key, remote)
);
CREATE INDEX IF NOT EXISTS idx_locations_remote ON annex_locations(remote);
"""

_SCHEMA_V5 = """
CREATE TABLE IF NOT EXISTS job_deps (
    child_job  INTEGER NOT NULL REFERENCES jobs(job_id),
    parent_job INTEGER NOT NULL REFERENCES jobs(job_id),
    pipeline   TEXT,
    PRIMARY KEY (child_job, parent_job)
);
CREATE INDEX IF NOT EXISTS idx_deps_parent ON job_deps(parent_job);
CREATE TABLE IF NOT EXISTS job_pipeline (
    job_id   INTEGER PRIMARY KEY REFERENCES jobs(job_id),
    pipeline TEXT NOT NULL,
    stage    TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_pipeline ON job_pipeline(pipeline);
"""

_MIGRATIONS: tuple[tuple[int, str], ...] = (
    (1, _SCHEMA_V1),  # base schema (pre-spec)
    (2, _SCHEMA_V2),  # canonical spec stored per row (PR 2)
    (3, _SCHEMA_V3),  # run-cache index + execution key per row (PR 7)
    (4, _SCHEMA_V4),  # remote-location bookkeeping for the annex tier (PR 9)
    (5, _SCHEMA_V5),  # pipeline tier: afterok dependency edges (PR 10)
)


class JobDB:
    def __init__(self, repro_dir: str):
        self.path = os.path.join(repro_dir, "jobdb.sqlite")
        self._local = threading.local()
        self._migrate(self._conn())

    @staticmethod
    def _detect_version(c: sqlite3.Connection) -> int:
        """Schema version of a pre-versioning database, inferred from its
        shape (fresh file -> 0 so every migration applies)."""
        tables = {
            r[0]
            for r in c.execute("SELECT name FROM sqlite_master WHERE type='table'")
        }
        if "jobs" not in tables:
            return 0
        if "job_deps" in tables:
            return 5
        if "annex_locations" in tables:
            return 4
        if "runcache" in tables:
            return 3
        cols = {r[1] for r in c.execute("PRAGMA table_info(jobs)")}
        return 2 if "spec" in cols else 1

    @classmethod
    def _migrate(cls, c: sqlite3.Connection) -> None:
        version = c.execute("PRAGMA user_version").fetchone()[0]
        if version == 0:
            version = cls._detect_version(c)
        applied = version
        for target, script in _MIGRATIONS:
            if applied < target:
                c.executescript(script)
                applied = target
        if applied != version or version == 0:
            # PRAGMA cannot be parameterized; `applied` is an int literal
            c.execute(f"PRAGMA user_version = {applied:d}")
            c.commit()

    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=30.0)
            conn.row_factory = sqlite3.Row
            self._local.conn = conn
        return conn

    # ------------------------------------------------------------------
    def add_jobs(
        self,
        specs: list[RunSpec],
        exec_keys: list[str | None] | None = None,
        pipeline: str | None = None,
        stages: list[str] | None = None,
    ) -> list[int]:
        """Insert a batch of specs and protect their outputs atomically.

        ONE transaction for the whole batch: N row inserts plus one shared
        §5.5 conflict pass (each output checked exactly once against the
        persisted N/P sets; conflicts *between* specs in the batch are
        caught because each spec's protection rows are inserted before the
        next spec is checked). Any conflict rolls the entire batch back —
        two concurrent ``submit_many`` calls cannot both claim an output,
        and a failed batch leaves no partial protection behind.
        """
        conn = self._conn()
        job_ids: list[int] = []
        keys = exec_keys if exec_keys is not None else [None] * len(specs)
        stage_names = stages if stages is not None else [None] * len(specs)
        with conn:  # single transaction: all checks + inserts + protection
            for spec, ekey, stage in zip(specs, keys, stage_names):
                cur = conn.execute(
                    "INSERT INTO jobs (script, script_args, pwd, inputs, outputs,"
                    " alt_dir, is_array, array_n, message, spec, exec_key,"
                    " submitted_at)"
                    " VALUES (?,?,?,?,?,?,?,?,?,?,?,?)",
                    (
                        spec.script or spec.cmd or "",
                        spec.script_args,
                        spec.pwd,
                        json.dumps(list(spec.inputs)),
                        json.dumps(list(spec.outputs)),
                        spec.alt_dir,
                        int(spec.array_n > 1),
                        spec.array_n,
                        spec.message,
                        spec.canonical_bytes().decode(),
                        ekey,
                        time.time(),
                    ),
                )
                job_id = cur.lastrowid
                job_ids.append(job_id)
                if pipeline is not None and stage is not None:
                    conn.execute(
                        "INSERT OR REPLACE INTO job_pipeline"
                        " (job_id, pipeline, stage) VALUES (?,?,?)",
                        (job_id, pipeline, stage),
                    )
                # RunSpec construction already normalized the outputs and
                # rejected intra-spec nesting; only cross-job checks remain
                normed = list(spec.outputs)
                for n in normed:
                    self._check_one(conn, n)  # raises on conflict -> rollback
                conn.executemany(
                    "INSERT OR IGNORE INTO protected (name, kind, job_id)"
                    " VALUES (?,?,?)",
                    [(n, "name", job_id) for n in normed]
                    + [
                        (p, "prefix", job_id)
                        for n in normed
                        for p in proper_prefixes(n)
                    ],
                )
        return job_ids

    def add_job(self, spec: RunSpec) -> int:
        """Single-spec convenience wrapper over :meth:`add_jobs`."""
        return self.add_jobs([spec])[0]

    @staticmethod
    def _check_one(conn: sqlite3.Connection, name: str) -> None:
        """The three §5.5 checks as indexed point lookups against the
        persisted N/P sets — O(path depth) queries, never a full table load.
        ``name`` must already be normalized."""
        if has_wildcard(name):
            raise WildcardOutputError(name)
        row = conn.execute(
            "SELECT job_id FROM protected WHERE name=? AND kind='name' LIMIT 1",
            (name,),
        ).fetchone()
        if row:  # check (1): name in N
            raise OutputConflict(name, "already protected", row[0])
        row = conn.execute(
            "SELECT job_id FROM protected WHERE name=? AND kind='prefix' LIMIT 1",
            (name,),
        ).fetchone()
        if row:  # check (2): name in P
            raise OutputConflict(
                name, "is a super-directory of another job's output", row[0]
            )
        for pre in proper_prefixes(name):  # check (3): a proper prefix in N
            row = conn.execute(
                "SELECT job_id FROM protected WHERE name=? AND kind='name' LIMIT 1",
                (pre,),
            ).fetchone()
            if row:
                raise OutputConflict(
                    name,
                    f"super-directory {pre!r} is claimed exclusively",
                    row[0],
                )

    def check_outputs(self, outputs: list[str]) -> None:
        """Non-mutating §5.5 check (used by reschedule previews)."""
        conn = self._conn()
        for o in outputs:
            self._check_one(conn, normalize(o))

    # ------------------------------------------------------------------
    def set_slurm_id(self, job_id: int, slurm_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET slurm_id=? WHERE job_id=?", (slurm_id, job_id))

    def set_slurm_ids(self, pairs: list[tuple[int, int]]) -> None:
        """Batched ``(job_id, slurm_id)`` update — one transaction for a
        whole ``submit_many`` batch."""
        if not pairs:
            return
        with self._conn() as c:
            c.executemany(
                "UPDATE jobs SET slurm_id=? WHERE job_id=?",
                [(slurm_id, job_id) for job_id, slurm_id in pairs],
            )

    def heartbeat(self, job_id: int) -> None:
        with self._conn() as c:
            c.execute("UPDATE jobs SET heartbeat=? WHERE job_id=?", (time.time(), job_id))

    def close_job(self, job_id: int, status: str) -> None:
        """Mark finished/failed-closed and release protected outputs."""
        with self._conn() as c:
            c.execute(
                "UPDATE jobs SET status=?, finished_at=? WHERE job_id=?",
                (status, time.time(), job_id),
            )
            c.execute("DELETE FROM protected WHERE job_id=?", (job_id,))

    # Every row query goes through this join so job dicts uniformly carry
    # ``pipeline``/``stage`` (NULL for non-pipeline jobs) without widening
    # the jobs table itself — keeps every migration pure CREATE TABLE.
    _JOB_SELECT = (
        "SELECT j.*, p.pipeline AS pipeline, p.stage AS stage FROM jobs j"
        " LEFT JOIN job_pipeline p ON p.job_id = j.job_id"
    )

    def get(self, job_id: int) -> dict | None:
        row = self._conn().execute(
            self._JOB_SELECT + " WHERE j.job_id=?", (job_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def by_slurm_id(self, slurm_id: int) -> dict | None:
        row = self._conn().execute(
            self._JOB_SELECT + " WHERE j.slurm_id=?", (slurm_id,)
        ).fetchone()
        return _to_dict(row) if row else None

    def open_jobs(self) -> list[dict]:
        rows = self._conn().execute(
            self._JOB_SELECT + " WHERE j.status='scheduled' ORDER BY j.job_id"
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def all_jobs(self) -> list[dict]:
        rows = self._conn().execute(
            self._JOB_SELECT + " ORDER BY j.job_id"
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def unsubmitted_open_jobs(self) -> list[dict]:
        """Rows a crash left open with no slurm id (died between
        ``add_jobs`` and ``set_slurm_ids``): unqueryable orphans, the §10
        sweep target."""
        rows = self._conn().execute(
            self._JOB_SELECT
            + " WHERE j.status='scheduled' AND j.slurm_id IS NULL"
            " ORDER BY j.job_id"
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def orphan_protection(self) -> list[int]:
        """Job ids owning protection rows despite no longer being open.
        ``close_job`` releases protection in the same transaction as the
        status flip, so these only arise from out-of-band divergence — the
        §10 fsck cross-check reports (and can release) them."""
        rows = self._conn().execute(
            "SELECT DISTINCT p.job_id FROM protected p JOIN jobs j"
            " ON p.job_id = j.job_id WHERE j.status != 'scheduled'"
        ).fetchall()
        return [r[0] for r in rows]

    def release_protection(self, job_ids: list[int]) -> None:
        if not job_ids:
            return
        with self._conn() as c:
            c.executemany(
                "DELETE FROM protected WHERE job_id=?",
                [(j,) for j in job_ids],
            )

    def n_protected(self) -> int:
        return self._conn().execute(
            "SELECT COUNT(*) FROM protected WHERE kind='name'"
        ).fetchone()[0]

    # ------------------------------------------- pipeline tier (v5, §14)
    def add_deps(
        self, pairs: list[tuple[int, int]], pipeline: str | None = None
    ) -> None:
        """Record afterok edges as (child_job, parent_job) pairs.
        Idempotent (INSERT OR REPLACE) so journal replay can re-record."""
        if not pairs:
            return
        with self._conn() as c:
            c.executemany(
                "INSERT OR REPLACE INTO job_deps (child_job, parent_job,"
                " pipeline) VALUES (?,?,?)",
                [(child, parent, pipeline) for child, parent in pairs],
            )

    def dependents_of(self, job_id: int) -> list[dict]:
        """Job rows with an afterok edge on ``job_id`` (any status)."""
        rows = self._conn().execute(
            self._JOB_SELECT + " JOIN job_deps d ON j.job_id = d.child_job"
            " WHERE d.parent_job=? ORDER BY j.job_id", (job_id,)
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def parents_of(self, job_id: int) -> list[dict]:
        rows = self._conn().execute(
            self._JOB_SELECT + " JOIN job_deps d ON j.job_id = d.parent_job"
            " WHERE d.child_job=? ORDER BY j.job_id", (job_id,)
        ).fetchall()
        return [_to_dict(r) for r in rows]

    def replace_dep_parent(
        self, old_parent: int, new_parent: int,
        children: list[int] | None = None,
    ) -> None:
        """Rewire edges on ``old_parent`` to ``new_parent`` (straggler
        replacement: dependents chain off the substitute job). With
        ``children``, only those child rows move — callers pass exactly the
        dependents the cluster actually detached, so jobdb edges never point
        at the replacement while the cluster still chains to the original."""
        if children is not None and not children:
            return
        sql = "UPDATE OR REPLACE job_deps SET parent_job=? WHERE parent_job=?"
        params: tuple = (new_parent, old_parent)
        if children is not None:
            sql += f" AND child_job IN ({','.join('?' * len(children))})"
            params += tuple(children)
        with self._conn() as c:
            c.execute(sql, params)

    def pipeline_rows(self, pipeline: str) -> dict[str, dict]:
        """Latest job row per stage for one pipeline submission (keyed by
        stage name) — how dag-journal replay finds what already landed."""
        rows = self._conn().execute(
            self._JOB_SELECT + " WHERE p.pipeline=? ORDER BY j.job_id",
            (pipeline,),
        ).fetchall()
        return {r["stage"]: _to_dict(r) for r in rows if r["stage"]}

    # --------------------------------------------------- run cache (§11)
    def cache_lookup(self, exec_keys: list[str | None]) -> dict[str, dict]:
        """Point-lookup a batch of execution keys; returns the hit rows
        keyed by exec_key (misses and ``None`` keys are simply absent)."""
        conn = self._conn()
        hits: dict[str, dict] = {}
        for key in exec_keys:
            if key is None or key in hits:
                continue
            row = conn.execute(
                "SELECT * FROM runcache WHERE exec_key=?", (key,)
            ).fetchone()
            if row:
                hits[key] = _cache_to_dict(row)
        return hits

    def cache_put(self, rows: list[dict]) -> None:
        """Record a batch of finished executions — ONE transaction, and
        idempotent (``INSERT OR REPLACE`` on the exec_key primary key) so
        journal replay of an already-recorded finish cannot double-insert."""
        if not rows:
            return
        now = time.time()
        with self._conn() as c:
            c.executemany(
                "INSERT OR REPLACE INTO runcache"
                " (exec_key, spec_id, commit_oid, output_tree, annex_keys,"
                "  created_at) VALUES (?,?,?,?,?,?)",
                [
                    (
                        r["exec_key"],
                        r["spec_id"],
                        r["commit_oid"],
                        json.dumps(r["output_tree"], sort_keys=True),
                        json.dumps(sorted(r["annex_keys"])),
                        now,
                    )
                    for r in rows
                ],
            )

    def cache_bump(self, exec_keys: list[str]) -> None:
        """Batched hit accounting (one transaction per memoized batch)."""
        if not exec_keys:
            return
        now = time.time()
        with self._conn() as c:
            c.executemany(
                "UPDATE runcache SET hits=hits+1, last_hit=? WHERE exec_key=?",
                [(now, k) for k in exec_keys],
            )

    def cache_rows(self) -> list[dict]:
        rows = self._conn().execute(
            "SELECT * FROM runcache ORDER BY exec_key"
        ).fetchall()
        return [_cache_to_dict(r) for r in rows]

    def cache_evict(self, exec_keys: list[str]) -> None:
        if not exec_keys:
            return
        with self._conn() as c:
            c.executemany(
                "DELETE FROM runcache WHERE exec_key=?",
                [(k,) for k in exec_keys],
            )

    def cache_count(self) -> int:
        return self._conn().execute(
            "SELECT COUNT(*) FROM runcache"
        ).fetchone()[0]

    # -- remote-location bookkeeping (DESIGN §13) -----------------------
    # Rows are *hints* recorded after a transfer verifiably completed:
    # whereis uses them as the cheap first answer, verify() cross-checks
    # them against fresh probes, and nothing numcopies-critical ever
    # trusts them — drops re-probe the remotes, always.
    def locations_record(self, remote: str, keys: list[str]) -> None:
        if not keys:
            return
        now = time.time()
        with self._conn() as c:
            c.executemany(
                "INSERT OR REPLACE INTO annex_locations (key, remote, seen_at)"
                " VALUES (?, ?, ?)",
                [(k, remote, now) for k in keys],
            )

    def locations_forget(self, remote: str, keys: list[str] | None = None) -> None:
        with self._conn() as c:
            if keys is None:
                c.execute("DELETE FROM annex_locations WHERE remote=?", (remote,))
            else:
                c.executemany(
                    "DELETE FROM annex_locations WHERE key=? AND remote=?",
                    [(k, remote) for k in keys],
                )

    def locations_of(self, keys: list[str]) -> dict[str, list[str]]:
        """key -> sorted remote names last seen holding it (hint tier)."""
        out: dict[str, list[str]] = {k: [] for k in keys}
        c = self._conn()
        for k in keys:
            rows = c.execute(
                "SELECT remote FROM annex_locations WHERE key=? ORDER BY remote",
                (k,),
            ).fetchall()
            out[k] = [r[0] for r in rows]
        return out

    def locations_all(self) -> list[tuple[str, str]]:
        """Every (key, remote) row — verify()'s cross-check sweep."""
        return [
            (r[0], r[1])
            for r in self._conn().execute(
                "SELECT key, remote FROM annex_locations ORDER BY key, remote"
            )
        ]


def job_spec(job: dict) -> RunSpec:
    """The :class:`RunSpec` of a job row: the stored canonical spec when
    present, else (pre-spec rows) one reassembled from the legacy columns."""
    if job.get("spec"):
        return RunSpec.from_json(job["spec"])
    return RunSpec(
        script=job["script"],
        script_args=job["script_args"],
        inputs=tuple(job["inputs"]),
        outputs=tuple(job["outputs"]),
        pwd=job["pwd"],
        alt_dir=job["alt_dir"],
        array_n=job["array_n"],
        message=job["message"],
    )


def _to_dict(row: sqlite3.Row) -> dict:
    d = dict(row)
    d["inputs"] = json.loads(d["inputs"])
    d["outputs"] = json.loads(d["outputs"])
    d["spec"] = json.loads(d["spec"]) if d.get("spec") else None
    return d


def _cache_to_dict(row: sqlite3.Row) -> dict:
    d = dict(row)
    d["output_tree"] = json.loads(d["output_tree"])
    d["annex_keys"] = json.loads(d["annex_keys"])
    return d
