"""Pack layer: bound object-store directory pressure (DESIGN.md §8).

The parallel-FS cost model (fsio.py) charges every metadata op an extra
``dir_degrade * (entries - degrade_threshold)`` against the directory being
touched. Loose objects accumulate one file per object *forever* in the 256
``objects/<2-hex>/`` shards, so the degradation term on new writes grows
with repository age even after the incremental commit engine made the *op
count* O(changed paths). Packs remove the remaining slope: many immutable
objects are consolidated into one append-only ``.pack`` file plus a JSON
``.idx`` (oid -> offset/length), the shards are emptied, and every shard's
entry count drops back below ``degrade_threshold`` — metadata ops return to
base cost regardless of how many objects the repository has ever stored.

Format
------
``objects/pack/pack-<id>.pack``   concatenation of the objects' *loose file
                                  bytes* (zlib-compressed ``<kind> <len>\\0
                                  <payload>`` frames), in index order.
``objects/pack/pack-<id>.idx``    ``{"version": 1, "objects":
                                  {oid: [offset, length], ...}}``.

``<id>`` is the sha256 of the pack data, so re-packing identical content is
idempotent. A pack holds the byte-identical compressed frame the loose file
held, so reads are equivalence-testable byte for byte.

Crash-safety invariant
----------------------
A pack *exists* only once its index does. ``ObjectStore.repack`` writes the
data file, publishes the index atomically (write + rename), and only then
unlinks the loose files it packed. A crash at any point therefore leaves
either (a) no index — the stray data file is garbage, every object still
loose — or (b) an index plus loose duplicates; never a missing object. The
read path prefers the pack and treats a loose duplicate as dead weight for
the next repack to sweep.

:class:`PackManager` holds no :class:`~repro.core.fsio.FS` reference —
callers pass their current ``fs`` so stores whose ``fs`` is swapped after
``clone`` stay consistent. All on-disk probing is charged through that
``fs``; index lookups after load are pure in-memory dict/bisect work.
"""
from __future__ import annotations

import bisect
import hashlib
import json
import os
import threading
import time
import uuid

from .faults import is_crash
from .fsio import FS

PACK_DIR = "pack"
INDEX_VERSION = 1


class PackError(IOError):
    pass


class PackManager:
    """In-memory index over every published pack under ``objects/pack/``.

    Lazily loads all ``.idx`` files on first use (one charged ``isdir`` +
    ``listdir`` + one charged read per index); packs created in-process via
    :meth:`add_pack` are registered directly without re-scanning.
    """

    def __init__(self, root: str):
        self.root = root  # .../objects/pack
        self._lock = threading.Lock()
        self._load_lock = threading.Lock()  # serializes the one-time scan
        self._loaded = False
        # oid -> (pack data path, offset, length)
        self._where: dict[str, tuple[str, int, int]] = {}
        # every registered pack, INCLUDING ones whose oids are all shadowed
        # by a newer pack (crash mid-consolidation) — consolidation must see
        # those to sweep their files, so ids are tracked independently of
        # which pack currently serves each oid
        self._pack_ids: set[str] = set()
        self._sorted_oids: list[str] | None = []  # None = dirty, rebuild
        self._mtime_at_load: float | None = None

    # -- loading ---------------------------------------------------------
    def _data_path(self, pack_id: str) -> str:
        return os.path.join(self.root, f"pack-{pack_id}.pack")

    def _index_path(self, pack_id: str) -> str:
        return os.path.join(self.root, f"pack-{pack_id}.idx")

    def load(self, fs: FS, force: bool = False) -> None:
        """Scan ``objects/pack/`` for published indexes (charged via ``fs``)
        and REPLACE the in-memory state with what is on disk — a reload
        therefore also prunes packs another process consolidated away.
        The new state is built aside and swapped in under the lock, so a
        concurrent reader never observes a half-populated index."""
        if self._loaded and not force:
            return
        with self._load_lock:
            if self._loaded and not force:
                return
            new_where: dict[str, tuple[str, int, int]] = {}
            new_ids: set[str] = set()
            # stamp BEFORE scanning: a foreign publish racing the scan then
            # leaves the stamp stale, so maybe_reload rescans once instead
            # of permanently masking the pack we half-missed
            self._stamp_current()
            if fs.isdir(self.root):
                for name in fs.listdir(self.root):
                    if not name.endswith(".idx"):
                        continue
                    pack_id = name[len("pack-"):-len(".idx")]
                    index = json.loads(
                        fs.read_bytes(os.path.join(self.root, name))
                    )
                    if index.get("version") != INDEX_VERSION:
                        raise PackError(
                            f"unsupported pack index version in pack-{pack_id}"
                        )
                    data = self._data_path(pack_id)
                    new_ids.add(pack_id)
                    for oid, (off, length) in index["objects"].items():
                        new_where[oid] = (data, off, length)
            with self._lock:
                self._where = new_where
                self._pack_ids = new_ids
                self._sorted_oids = None
            self._loaded = True

    def maybe_reload(self, fs: FS) -> bool:
        """Rescan only if ``objects/pack/`` changed since the last load
        (one charged stat vs. a full ~2x-packs-op rescan) — the cheap gate
        for the miss-retry paths. Returns True if a rescan happened.
        Caveat: on filesystems with coarse mtime granularity a foreign
        publish inside the same tick as our load can be missed here;
        ``get``'s unconditional force-reload retry still self-heals reads."""
        try:
            current = fs.stat_mtime(self.root)
        except OSError:
            return False
        if current == self._mtime_at_load:
            return False
        self.load(fs, force=True)
        return True

    def _register(self, pack_id: str, index: dict) -> None:
        if index.get("version") != INDEX_VERSION:
            raise PackError(f"unsupported pack index version in pack-{pack_id}")
        data = self._data_path(pack_id)
        with self._lock:
            self._pack_ids.add(pack_id)
            for oid, (off, length) in index["objects"].items():
                self._where[oid] = (data, off, length)
            self._sorted_oids = None  # rebuilt lazily on next prefix search
        # deliberately NOT restamped: our own add_pack/drop also moves the
        # dir mtime, so the next miss-retry rescans once — wasteful-looking,
        # but stamping here would mask any FOREIGN pack published between
        # our last load and this write, and resolve would then miss it

    def _stamp_current(self) -> None:
        """Record the pack dir mtime the in-memory state corresponds to
        (only from ``load``, which mirrors disk exactly at that moment)."""
        try:
            self._mtime_at_load = os.path.getmtime(self.root)
        except OSError:
            pass

    # -- queries ---------------------------------------------------------
    def has(self, oid: str, fs: FS) -> bool:
        self.load(fs)
        with self._lock:
            return oid in self._where

    def read(self, oid: str, fs: FS) -> bytes:
        """The packed object's compressed frame (loose-file-identical bytes)."""
        self.load(fs)
        with self._lock:
            loc = self._where.get(oid)
        if loc is None:
            raise KeyError(f"object {oid} is not packed")
        path, off, length = loc
        return fs.read_range(path, off, length)

    def oids_with_prefix(self, prefix: str, fs: FS) -> list[str]:
        """All packed oids starting with ``prefix`` (in-memory bisect)."""
        self.load(fs)
        with self._lock:
            if self._sorted_oids is None:
                self._sorted_oids = sorted(self._where)
            oids = self._sorted_oids
            lo = bisect.bisect_left(oids, prefix)
            out = []
            for i in range(lo, len(oids)):
                if not oids[i].startswith(prefix):
                    break
                out.append(oids[i])
            return out

    def n_packed(self, fs: FS) -> int:
        self.load(fs)
        with self._lock:
            return len(self._where)

    def pack_ids(self, fs: FS) -> list[str]:
        self.load(fs)
        with self._lock:
            return sorted(self._pack_ids)

    def pack_data_size(self, pack_id: str, fs: FS) -> int:
        return fs.stat_size(self._data_path(pack_id))

    def read_pack_objects(self, pack_id: str, fs: FS):
        """Yield every ``(oid, frame)`` currently served from ``pack_id`` —
        one whole-file read, sliced lazily so consolidation keeps at most
        one pack plus one frame resident at a time."""
        self.load(fs)
        data_path = self._data_path(pack_id)
        with self._lock:
            spans = [
                (oid, off, length)
                for oid, (path, off, length) in self._where.items()
                if path == data_path
            ]
        if not spans:
            return
        data = fs.read_bytes(data_path)
        for oid, off, length in spans:
            yield oid, data[off:off + length]

    def sweep_garbage(self, fs: FS, min_age_s: float = 86400.0) -> int:
        """Unlink crash leftovers in ``objects/pack/``: ``*.tmp`` files and
        data files with no published index, but only once their mtime is
        ``min_age_s`` stale. Unreferenced files can never be *served from*,
        but a young one may be a concurrent foreign repack's in-flight work
        — in particular its data file in the rename-to-``.pack``-before-
        index-publish window, which WILL be referenced moments later. A
        live repack's tmp keeps a fresh mtime while ``write_chunks``
        streams into it, and the rename-to-publish gap is milliseconds, so
        a day-stale mtime really means a crash; genuine garbage is
        collected on the first repack after it ages out, keeping the pack
        directory's entry bound honest. Returns the number removed."""
        if not fs.isdir(self.root):
            return 0
        names = fs.listdir(self.root)
        indexed = {
            n[len("pack-"):-len(".idx")] for n in names if n.endswith(".idx")
        }
        swept = 0
        for n in names:
            orphan_data = (
                n.endswith(".pack")
                and n[len("pack-"):-len(".pack")] not in indexed
            )
            if not (n.endswith(".tmp") or orphan_data):
                continue
            path = os.path.join(self.root, n)
            try:
                if time.time() - fs.stat_mtime(path) < min_age_s:
                    continue  # possibly someone's in-flight pack: leave it
            except OSError:
                continue  # vanished already (its owner finished or cleaned)
            fs.unlink(path)
            swept += 1
        return swept

    def drop_pack_files(self, pack_id: str, fs: FS) -> None:
        """Unlink a superseded pack's files. The caller must already have
        re-registered every one of its oids in a newer pack — in-memory
        locations are untouched here. Index first, then data: a crash in
        between leaves an unindexed (garbage) data file, never an index
        pointing at missing data."""
        fs.unlink(self._index_path(pack_id))
        fs.unlink(self._data_path(pack_id))
        with self._lock:
            self._pack_ids.discard(pack_id)

    # -- writing ---------------------------------------------------------
    def add_pack(self, objects, fs: FS) -> str | None:
        """Write + atomically publish one pack holding ``objects`` (an
        iterable of ``(oid, compressed frame bytes)`` pairs — consumed
        lazily, so a multi-GB repack holds at most one loose frame (or one
        consolidated pack) plus the offset index in memory). Returns the
        pack id, or None if the iterable was empty. The caller owns unlinking the loose copies —
        and must do so only *after* this returns (the crash-safety
        invariant)."""
        self.load(fs)
        index: dict[str, list[int]] = {}
        digest = hashlib.sha256()
        offset = 0

        def stream():
            nonlocal offset
            for oid, frame in objects:
                index[oid] = [offset, len(frame)]
                offset += len(frame)
                digest.update(frame)
                yield frame

        # stream to a collision-free temp name (the id isn't known until
        # the data is hashed), then rename into place — still before the
        # index publish
        tmp_data = os.path.join(
            self.root, f"incoming-{os.getpid()}-{uuid.uuid4().hex[:8]}.tmp"
        )
        try:
            fs.write_chunks(tmp_data, stream())
        except BaseException as e:
            if is_crash(e):
                raise  # a dead process runs no cleanup: sweep_garbage's job
            fs.unlink(tmp_data)  # no half-written tmp left behind
            raise
        if not index:
            fs.unlink(tmp_data)
            return None
        pack_id = digest.hexdigest()[:16]
        fs.rename(tmp_data, self._data_path(pack_id))
        # §10 crash matrix: data renamed into place, index not yet published
        # — the sweep_garbage invariant window
        fs.crash_point("repack:data-renamed")
        # publish: the index appears atomically or not at all
        tmp = self._index_path(pack_id) + ".tmp"
        fs.write_bytes(
            tmp,
            json.dumps(
                {"version": INDEX_VERSION, "objects": index}, sort_keys=True
            ).encode(),
        )
        fs.rename(tmp, self._index_path(pack_id))
        self._register(pack_id, {"version": INDEX_VERSION, "objects": index})
        return pack_id
