"""Batch-executor interface with Slurm semantics + a local implementation.

The paper targets Slurm "as a synonym for all other HPC job schedulers" (§2.7)
and the presented extension is "a template for corresponding extensions for
other job schedulers". Accordingly the scheduler (:mod:`repro.core.scheduler`)
talks to this small interface; :class:`LocalSlurmCluster` implements it with a
thread pool + subprocesses so the complete protocol is executable and testable
in this container, reproducing:

  - sbatch/sacct/scancel semantics and job states
    (PENDING / RUNNING / COMPLETED / FAILED / CANCELLED / TIMEOUT),
  - array jobs (one submission, many tasks, per-task states; the array is
    COMPLETED only if every task is),
  - the ``log.slurm-<id>.out`` output file and the ``slurm-job-<id>.env.json``
    metadata file of paper §5.2,
  - submission latency on the shared virtual clock (``sbatch_cost_s`` ≈ the
    paper's measured ~0.05 s baseline) so benchmarks can compare
    schedule-vs-sbatch like Figure 7.

On a real cluster, a ``SubprocessSlurmCluster`` shelling out to the real
``sbatch``/``sacct`` is a drop-in replacement (provided, but not exercisable
here).
"""
from __future__ import annotations

import json
import os
import subprocess
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

from . import faults as _faults
from .fsio import SimClock

# canonical Slurm states we model
PENDING = "PENDING"
RUNNING = "RUNNING"
COMPLETED = "COMPLETED"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
TIMEOUT = "TIMEOUT"
NODE_FAIL = "NODE_FAIL"
PREEMPTED = "PREEMPTED"
TERMINAL = {COMPLETED, FAILED, CANCELLED, TIMEOUT, NODE_FAIL, PREEMPTED}


def fold_states(states: list[str]) -> str:
    """Collapse raw per-task sacct state strings into one job state with the
    precedence both of SubprocessSlurmCluster's accounting paths (single and
    batched) share — a job is only COMPLETED when nothing else applies to
    any of its rows. NOTE: LocalSlurmCluster's ``aggregate_state`` orders
    terminal states CANCELLED > TIMEOUT > ... > FAILED instead; for mixed-
    terminal array jobs the simulated and real backends can report different
    (but equally terminal) states."""
    if not states:
        return PENDING
    for precedence in (
        RUNNING, PENDING, NODE_FAIL, PREEMPTED, FAILED, CANCELLED, TIMEOUT
    ):
        if any(s.startswith(precedence) for s in states):
            return precedence
    return COMPLETED


@dataclass
class TaskState:
    state: str = PENDING
    exit_code: int | None = None
    start_time: float | None = None
    end_time: float | None = None


@dataclass
class SlurmJob:
    job_id: int
    script: str
    args: str
    workdir: str
    array_n: int = 1
    time_limit_s: float | None = None
    env: dict | None = None  # extra job environment (RunSpec.env)
    submit_time: float = field(default_factory=time.time)
    tasks: list[TaskState] = field(default_factory=list)
    cancelled: bool = False
    dependency: list[int] = field(default_factory=list)  # afterok parents
    held: bool = False  # scontrol hold: stay PENDING even with no deps
    started: bool = False  # tasks handed to the pool (at most once)

    def aggregate_state(self) -> str:
        states = [t.state for t in self.tasks]
        if any(s == RUNNING for s in states):
            return RUNNING
        if any(s == PENDING for s in states):
            return PENDING
        if all(s == COMPLETED for s in states):
            return COMPLETED
        if any(s == CANCELLED for s in states):
            return CANCELLED
        if any(s == TIMEOUT for s in states):
            return TIMEOUT
        if any(s == NODE_FAIL for s in states):
            return NODE_FAIL
        if any(s == PREEMPTED for s in states):
            return PREEMPTED
        return FAILED


class SlurmCluster:
    """Executor interface (sbatch/sacct/scancel)."""

    def sbatch(self, script: str, workdir: str, args: str = "", array_n: int = 1,
               time_limit_s: float | None = None, env: dict | None = None,
               dependency: list[int] | None = None) -> int:
        """Submit a job. ``dependency`` is a list of parent job ids with
        ``afterok`` semantics: the job stays PENDING until every parent is
        COMPLETED, and is cancelled if any parent ends in another terminal
        state (real Slurm leaves it DependencyNeverSatisfied; we model the
        ``--kill-on-invalid-dep=yes`` behaviour so campaigns drain)."""
        raise NotImplementedError

    def scontrol_update_dependency(
        self, job_id: int, add: list[int] | None = None,
        remove: list[int] | None = None, hold: bool = False,
    ) -> bool:
        """Rewire a *pending* job's afterok parents (``scontrol update
        Dependency=...``). ``hold`` additionally holds the job so it does
        not start even if its dependency set becomes empty — callers use
        remove+hold, then add+release once the replacement parent exists.
        Returns False if the job already started or finished."""
        raise NotImplementedError

    def scontrol_release(self, job_id: int) -> None:
        """Clear a hold set by :meth:`scontrol_update_dependency`."""
        raise NotImplementedError

    def sacct(self, job_id: int) -> str:
        raise NotImplementedError

    def sacct_many(self, job_ids: list[int]) -> dict[int, str]:
        """States for a whole set of jobs in ONE accounting query (one CLI
        startup, not one per job). Backends override with a genuinely
        batched call; this fallback preserves semantics for exotic
        implementations that only provide ``sacct``."""
        return {j: self.sacct(j) for j in job_ids}

    def sacct_tasks(self, job_id: int) -> list[str]:
        raise NotImplementedError

    def scancel(self, job_id: int) -> str | None:
        """Cancel a job. Idempotent: cancelling an already-terminal or
        unknown job is a no-op. Returns the job's state after the call when
        the backend knows it (None for backends that don't report one)."""
        raise NotImplementedError

    def wait(self, job_ids: list[int] | None = None, timeout: float = 300.0) -> None:
        raise NotImplementedError


class LocalSlurmCluster(SlurmCluster):
    def __init__(
        self,
        max_workers: int = 8,
        clock: SimClock | None = None,
        sbatch_cost_s: float = 0.05,
        sacct_cost_s: float = 0.02,
        first_job_id: int = 11_452_000,
        faults: "_faults.FaultPlan | None" = None,
    ):
        self.pool = ThreadPoolExecutor(max_workers=max_workers)
        self.clock = clock or SimClock()
        self.faults = faults
        self.sbatch_cost_s = sbatch_cost_s
        self.sacct_cost_s = sacct_cost_s
        self._jobs: dict[int, SlurmJob] = {}
        self._procs: dict[tuple[int, int], subprocess.Popen] = {}
        # RLock: dependency resolution runs inside _maybe_done, which is
        # reached both with and without the lock held
        self._lock = threading.RLock()
        self._next_id = first_job_id
        self._done_events: dict[int, threading.Event] = {}
        self._waiting: dict[int, set[int]] = {}  # held job -> unmet parents
        self._dependents: dict[int, list[int]] = {}  # parent -> held children

    # -- submission ------------------------------------------------------
    def sbatch(self, script: str, workdir: str, args: str = "", array_n: int = 1,
               time_limit_s: float | None = None, env: dict | None = None,
               dependency: list[int] | None = None) -> int:
        if self.faults is not None:
            self.faults.on_slurm("sbatch")
        self.clock.charge(self.sbatch_cost_s)
        if not os.path.exists(os.path.join(workdir, script)) and not os.path.isabs(script):
            raise FileNotFoundError(f"job script not found: {script} (cwd {workdir})")
        failed_parent = False
        with self._lock:
            # validate the whole dependency list BEFORE registering the job:
            # raising mid-registration would leave a phantom never-terminal
            # PENDING row plus stale _dependents entries for earlier parents
            for p in dependency or []:
                if p not in self._jobs:
                    raise KeyError(f"unknown dependency job {p}")
            job_id = self._next_id
            self._next_id += 1
            job = SlurmJob(
                job_id=job_id, script=script, args=args, workdir=workdir,
                array_n=array_n, time_limit_s=time_limit_s, env=env,
                tasks=[TaskState() for _ in range(array_n)],
                dependency=list(dependency or []),
            )
            self._jobs[job_id] = job
            self._done_events[job_id] = threading.Event()
            waiting: set[int] = set()
            for p in job.dependency:
                parent = self._jobs[p]
                # done-event set means the parent's dependent resolution
                # already ran (or is running): resolve this edge inline —
                # a late registration would never be visited again
                if self._done_events[p].is_set():
                    if parent.aggregate_state() != COMPLETED:
                        failed_parent = True
                    continue
                waiting.add(p)
                self._dependents.setdefault(p, []).append(job_id)
            if failed_parent:
                self._detach(job_id)
            elif waiting:
                self._waiting[job_id] = waiting
        if failed_parent:
            self._cancel_dependent(job)
        elif not waiting:
            self._start_tasks(job)
        return job_id

    def _start_tasks(self, job: SlurmJob) -> None:
        with self._lock:
            if job.started or job.cancelled:
                return
            job.started = True
        for task_id in range(job.array_n):
            self.pool.submit(self._run_task, job, task_id)

    def _detach(self, job_id: int) -> None:
        """Drop every parent->job_id registration (lock held by caller)."""
        self._waiting.pop(job_id, None)
        for deps in self._dependents.values():
            while job_id in deps:
                deps.remove(job_id)

    def _cancel_dependent(self, job: SlurmJob) -> None:
        """A parent ended non-COMPLETED: the afterok child dies PENDING."""
        with self._lock:
            job.cancelled = True
            for t in job.tasks:
                if t.state == PENDING:
                    t.state = CANCELLED
        self._maybe_done(job)

    def _resolve_dependents(self, job: SlurmJob) -> None:
        """Called once `job` is terminal: release or cancel held children."""
        state = job.aggregate_state()
        to_start: list[SlurmJob] = []
        to_cancel: list[SlurmJob] = []
        with self._lock:
            for child_id in self._dependents.pop(job.job_id, []):
                waiting = self._waiting.get(child_id)
                if waiting is None:
                    continue
                child = self._jobs[child_id]
                if state == COMPLETED:
                    waiting.discard(job.job_id)
                    if not waiting:
                        del self._waiting[child_id]
                        if not child.held:
                            to_start.append(child)
                else:
                    self._detach(child_id)
                    to_cancel.append(child)
        for child in to_start:
            self._start_tasks(child)
        for child in to_cancel:
            self._cancel_dependent(child)  # cascades via _maybe_done

    def _log_path(self, job: SlurmJob, task_id: int) -> str:
        if job.array_n > 1:
            return os.path.join(job.workdir, f"log.slurm-{job.job_id}_{task_id}.out")
        return os.path.join(job.workdir, f"log.slurm-{job.job_id}.out")

    def _run_task(self, job: SlurmJob, task_id: int) -> None:
        task = job.tasks[task_id]
        with self._lock:
            if job.cancelled:
                task.state = CANCELLED
                self._maybe_done(job)
                return
            task.state = RUNNING
            task.start_time = time.time()
        if self.faults is not None:
            # injected node-level fate (NODE_FAIL / PREEMPTED / TIMEOUT /
            # FAILED): the task "ran" on a node that died — it never gets
            # to execute, but accounting still reports a terminal state
            try:
                fate = self.faults.task_fate()
            except _faults.CrashInjected:
                fate = None  # the *client* died; compute nodes are unaffected
            if fate is not None:
                task.state = fate
                task.exit_code = -1
                task.end_time = time.time()
                self._write_env_json(job)
                self._maybe_done(job)
                return
        env = dict(os.environ)
        if job.env:
            env.update(job.env)  # spec env first; SLURM identity vars win
        env.update(
            SLURM_JOB_ID=str(job.job_id),
            SLURM_ARRAY_TASK_ID=str(task_id),
            SLURM_ARRAY_TASK_COUNT=str(job.array_n),
            SLURM_JOB_NAME=os.path.basename(job.script),
            SLURM_JOB_PARTITION="simulated",
            SLURM_JOB_NUM_NODES="1",
            SLURM_SUBMIT_DIR=job.workdir,
        )
        logpath = self._log_path(job, task_id)
        cmd = f"bash {job.script} {job.args}".strip()
        try:
            with open(logpath, "w") as log:
                proc = subprocess.Popen(
                    cmd, shell=True, cwd=job.workdir, env=env,
                    stdout=log, stderr=subprocess.STDOUT,
                )
                with self._lock:
                    self._procs[(job.job_id, task_id)] = proc
                try:
                    rc = proc.wait(timeout=job.time_limit_s)
                    task.exit_code = rc
                    task.state = COMPLETED if rc == 0 else FAILED
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait()
                    task.state = TIMEOUT
                    task.exit_code = -1
        except Exception:
            task.state = FAILED
            task.exit_code = -1
        finally:
            task.end_time = time.time()
            with self._lock:
                self._procs.pop((job.job_id, task_id), None)
                if job.cancelled and task.state not in (COMPLETED,):
                    task.state = CANCELLED
            self._write_env_json(job)
            self._maybe_done(job)

    def _write_env_json(self, job: SlurmJob) -> None:
        """The paper's extra output: slurm-job-<id>.env.json with all Slurm
        metadata about the job (§5.2)."""
        meta = {
            "SLURM_JOB_ID": job.job_id,
            "SLURM_JOB_NAME": os.path.basename(job.script),
            "SLURM_JOB_PARTITION": "simulated",
            "SLURM_SUBMIT_DIR": job.workdir,
            "SLURM_ARRAY_TASK_COUNT": job.array_n,
            "SubmitTime": job.submit_time,
            "State": job.aggregate_state(),
            "ExitCodes": [t.exit_code for t in job.tasks],
            "Elapsed": [
                (t.end_time - t.start_time) if t.start_time and t.end_time else None
                for t in job.tasks
            ],
        }
        path = os.path.join(job.workdir, f"slurm-job-{job.job_id}.env.json")
        with open(path, "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)

    def _maybe_done(self, job: SlurmJob) -> None:
        if all(t.state in TERMINAL for t in job.tasks):
            self._done_events[job.job_id].set()
            self._resolve_dependents(job)

    # -- queries -----------------------------------------------------------
    def sacct(self, job_id: int) -> str:
        if self.faults is not None:
            self.faults.on_slurm("sacct")
        self.clock.charge(self.sacct_cost_s)
        job = self._jobs.get(job_id)
        if job is None:
            raise KeyError(f"unknown slurm job {job_id}")
        return job.aggregate_state()

    def sacct_many(self, job_ids: list[int]) -> dict[int, str]:
        if not job_ids:
            return {}  # nothing to poll -> no CLI invocation, no charge
        if self.faults is not None:
            self.faults.on_slurm("sacct")
        # one poll = one CLI-startup charge, however many jobs it covers
        self.clock.charge(self.sacct_cost_s)
        out = {}
        for job_id in job_ids:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown slurm job {job_id}")
            out[job_id] = job.aggregate_state()
        return out

    def sacct_tasks(self, job_id: int) -> list[str]:
        if self.faults is not None:
            self.faults.on_slurm("sacct")
        self.clock.charge(self.sacct_cost_s)
        return [t.state for t in self._jobs[job_id].tasks]

    def job_runtime(self, job_id: int) -> float | None:
        job = self._jobs[job_id]
        starts = [t.start_time for t in job.tasks if t.start_time]
        if not starts:
            return None
        ends = [t.end_time or time.time() for t in job.tasks]
        return max(ends) - min(starts)

    def slurm_output_files(self, job_id: int) -> list[str]:
        job = self._jobs[job_id]
        logs = [
            os.path.basename(self._log_path(job, t)) for t in range(job.array_n)
        ]
        return logs + [f"slurm-job-{job_id}.env.json"]

    # -- control -------------------------------------------------------------
    def scancel(self, job_id: int) -> str | None:
        """Idempotent cancel (real ``scancel`` semantics): unknown ids and
        already-terminal jobs are no-ops — a straggler that completed
        between being flagged and being cancelled keeps its COMPLETED state
        (the caller inspects the returned state to decide what to do)."""
        if self.faults is not None:
            self.faults.on_slurm("scancel")
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                return None
            if all(t.state in TERMINAL for t in job.tasks):
                return job.aggregate_state()
            job.cancelled = True
            self._detach(job_id)  # a directly-cancelled held job stops waiting
            for t in job.tasks:
                if t.state == PENDING:
                    t.state = CANCELLED
            procs = [
                p for (jid, _), p in self._procs.items() if jid == job_id
            ]
        for p in procs:
            p.kill()
        self._maybe_done(job)
        return job.aggregate_state()

    def scontrol_update_dependency(
        self, job_id: int, add: list[int] | None = None,
        remove: list[int] | None = None, hold: bool = False,
    ) -> bool:
        if self.faults is not None:
            self.faults.on_slurm("scontrol")
        failed_parent = False
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.started or job.cancelled:
                return False
            # validate before mutating: a KeyError mid-rewire would leave
            # the job half-detached and dropped from _waiting for good
            for a in add or []:
                if a not in self._jobs:
                    raise KeyError(f"unknown dependency job {a}")
            waiting = self._waiting.pop(job_id, set())
            for r in remove or []:
                waiting.discard(r)
                if r in self._dependents:
                    while job_id in self._dependents[r]:
                        self._dependents[r].remove(job_id)
                if r in job.dependency:
                    job.dependency.remove(r)
            for a in add or []:
                parent = self._jobs[a]
                job.dependency.append(a)
                if self._done_events[a].is_set():
                    if parent.aggregate_state() != COMPLETED:
                        failed_parent = True
                    continue
                waiting.add(a)
                self._dependents.setdefault(a, []).append(job_id)
            if hold:
                job.held = True
            if failed_parent:
                self._detach(job_id)
            elif waiting:
                self._waiting[job_id] = waiting
            release_now = not failed_parent and not waiting and not job.held
        if failed_parent:
            self._cancel_dependent(job)
        elif release_now:
            self._start_tasks(job)
        return True

    def scontrol_release(self, job_id: int) -> None:
        if self.faults is not None:
            self.faults.on_slurm("scontrol")
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                return
            job.held = False
            start = (
                not job.started and not job.cancelled
                and job_id not in self._waiting
            )
        if start:
            self._start_tasks(job)

    def wait(self, job_ids: list[int] | None = None, timeout: float = 300.0) -> None:
        ids = job_ids if job_ids is not None else list(self._jobs)
        deadline = time.time() + timeout
        for jid in ids:
            remaining = max(0.0, deadline - time.time())
            if not self._done_events[jid].wait(timeout=remaining):
                raise TimeoutError(f"slurm job {jid} did not finish in {timeout}s")

    def shutdown(self) -> None:
        self.pool.shutdown(wait=False, cancel_futures=True)


class SubprocessSlurmCluster(SlurmCluster):
    """Real-cluster backend: shells out to actual sbatch/sacct/scancel.

    Provided for deployment; cannot be exercised in this container (no Slurm).
    The command construction mirrors the datalad-slurm plugin.
    """

    def sbatch(self, script: str, workdir: str, args: str = "", array_n: int = 1,
               time_limit_s: float | None = None, env: dict | None = None,
               dependency: list[int] | None = None) -> int:
        cmd = ["sbatch", "--parsable"]
        if array_n > 1:
            cmd.append(f"--array=0-{array_n - 1}")
        if time_limit_s:
            cmd.append(f"--time={max(1, int(time_limit_s // 60))}")
        if dependency:
            # kill-on-invalid-dep so a failed parent drains the cone instead
            # of leaving DependencyNeverSatisfied jobs pinning the queue —
            # matching LocalSlurmCluster's cancel-on-parent-failure model
            cmd.append("--dependency=afterok:" + ":".join(str(d) for d in dependency))
            cmd.append("--kill-on-invalid-dep=yes")
        cmd += [script] + ([a for a in args.split() if a] if args else [])
        # spec env goes through the submission environment (sbatch defaults
        # to --export=ALL), not the --export flag — values with commas or
        # '=' would corrupt the flag's comma-separated list
        proc_env = {**os.environ, **env} if env else None
        out = subprocess.run(
            cmd, cwd=workdir, env=proc_env, capture_output=True, text=True,
            check=True,
        )
        return int(out.stdout.strip().split(";")[0])

    def sacct(self, job_id: int) -> str:
        out = subprocess.run(
            ["sacct", "-j", str(job_id), "-X", "-n", "-o", "State%20"],
            capture_output=True, text=True, check=True,
        )
        states = [s.strip().rstrip("+") for s in out.stdout.splitlines() if s.strip()]
        return fold_states(states)

    def sacct_many(self, job_ids: list[int]) -> dict[int, str]:
        """One ``sacct -j id1,id2,...`` invocation for the whole set —
        sacct accepts a comma-separated job list, so a 1000-job poll is one
        CLI startup instead of 1000."""
        if not job_ids:
            return {}
        out = subprocess.run(
            ["sacct", "-j", ",".join(str(j) for j in job_ids), "-X", "-n",
             "-o", "JobID%20,State%20"],
            capture_output=True, text=True, check=True,
        )
        states: dict[int, list[str]] = {j: [] for j in job_ids}
        for line in out.stdout.splitlines():
            parts = line.split()
            if len(parts) < 2:
                continue
            jid = parts[0].split("_")[0].split(".")[0]
            if jid.isdigit() and int(jid) in states:
                states[int(jid)].append(parts[1].rstrip("+"))
        return {j: fold_states(sts) for j, sts in states.items()}

    def sacct_tasks(self, job_id: int) -> list[str]:
        out = subprocess.run(
            ["sacct", "-j", str(job_id), "-n", "-o", "State%20"],
            capture_output=True, text=True, check=True,
        )
        return [s.strip() for s in out.stdout.splitlines() if s.strip()]

    def scancel(self, job_id: int) -> str | None:
        # real scancel is already idempotent on terminal jobs (exit 0)
        subprocess.run(["scancel", str(job_id)], check=True)
        return None

    def scontrol_update_dependency(
        self, job_id: int, add: list[int] | None = None,
        remove: list[int] | None = None, hold: bool = False,
    ) -> bool:
        # hold FIRST: 'scontrol update Dependency=' replaces the whole
        # expression, and a job left momentarily dependency-free before a
        # later hold would be eligible to start — defeating the
        # detach-and-hold invariant reschedule_straggler relies on
        if hold:
            if subprocess.run(["scontrol", "hold", str(job_id)]).returncode != 0:
                return False
        ok = self._rewrite_dependency(job_id, add or [], remove or [])
        if not ok and hold:
            # don't leave a stray user hold on a job we failed to rewire
            subprocess.run(["scontrol", "release", str(job_id)])
        return ok

    def _rewrite_dependency(
        self, job_id: int, add: list[int], remove: list[int]
    ) -> bool:
        # real scontrol REPLACES the Dependency expression: read the
        # current one and write back current - remove + add so a
        # remove-only call keeps the job's other afterok parents (and any
        # non-afterok clauses) instead of clearing them
        out = subprocess.run(
            ["scontrol", "show", "job", str(job_id)],
            capture_output=True, text=True,
        )
        if out.returncode != 0:
            return False
        state, expr = "", ""
        for tok in out.stdout.split():
            if tok.startswith("JobState="):
                state = tok.split("=", 1)[1]
            elif tok.startswith("Dependency="):
                expr = tok.split("=", 1)[1]
        if state != PENDING:
            return False  # started/finished jobs cannot be rewired
        afterok: list[int] = []
        others: list[str] = []
        if expr not in ("", "(null)"):
            for clause in expr.split(","):
                kind, _, rest = clause.partition(":")
                if kind == "afterok":
                    # newer Slurm annotates ids, e.g. afterok:123(unfulfilled)
                    ids = [p.partition("(")[0] for p in rest.split(":")]
                    afterok += [int(p) for p in ids if p.isdigit()]
                else:
                    others.append(clause)
        keep = [i for i in afterok if i not in set(remove)]
        keep += [a for a in add if a not in keep]
        clauses = others + (
            ["afterok:" + ":".join(str(i) for i in keep)] if keep else []
        )
        return subprocess.run(
            ["scontrol", "update", f"JobId={job_id}",
             f"Dependency={','.join(clauses)}"],
        ).returncode == 0

    def scontrol_release(self, job_id: int) -> None:
        subprocess.run(["scontrol", "release", str(job_id)], check=True)

    def wait(self, job_ids: list[int] | None = None, timeout: float = 300.0) -> None:
        deadline = time.time() + timeout
        ids = list(job_ids or [])
        while time.time() < deadline:
            if all(s in TERMINAL for s in self.sacct_many(ids).values()):
                return
            time.sleep(5.0)
        raise TimeoutError(f"jobs {ids} still running after {timeout}s")
