"""Latency-modeled filesystem layer.

The paper's central performance finding (Figures 7-10) is *filesystem*
behaviour: a DataLad repository on a parallel file system (GPFS) suffers
superlinear per-job ``slurm-finish`` cost once the repository holds more than
~50 000 files, while a repository on a node-local file system (XFS ``/tmp``)
stays ~flat. This container has neither GPFS nor Slurm, so every filesystem
operation performed by the version store goes through this layer, which

  1. actually performs the operation (so correctness is real), and
  2. charges its *modeled* cost on a virtual clock (``SimClock``), using an
     ``FSProfile`` whose parameters are calibrated against the paper's
     measurements.

Benchmarks report both the simulated (FS-bound) seconds and the real
wall-clock seconds of the code path; EXPERIMENTS.md labels them explicitly.

Cost model
----------
A metadata operation (create/stat/unlink/rename/open-for-append) costs

    meta_op_s + dir_degrade * max(0, n_repo_files - degrade_threshold)

reproducing the paper's observation that per-op cost grows with the number
of files a repository has accumulated on a parallel FS (inode/metadata
pressure, paper §6 "How fast is finishing jobs?"), while local file systems
have ``dir_degrade == 0``. Data transfer costs ``bytes / bandwidth``.
"""
from __future__ import annotations

import os
import shutil
import threading
from dataclasses import dataclass, field


@dataclass
class FSProfile:
    name: str
    meta_op_s: float  # base metadata-op latency (seconds)
    read_bw: float  # bytes/second
    write_bw: float  # bytes/second
    degrade_threshold: int = 0  # repo-file count beyond which metadata degrades
    dir_degrade: float = 0.0  # extra seconds per metadata op per file beyond threshold


# Calibrated against the paper's evaluation cluster:
#  - pure `sbatch` ~0.05 s/job, `slurm-schedule` 0.35-0.7 s/job (Fig. 7),
#  - `slurm-finish` blowing past 10 s/job beyond ~50k repo files on GPFS,
#    vs 0.6-1.7 s/job flat on local XFS (Fig. 9).
GPFS = FSProfile(
    name="gpfs",
    meta_op_s=2.0e-3,
    read_bw=2.0e9,
    write_bw=1.5e9,
    degrade_threshold=50_000,
    dir_degrade=2.2e-6,
)
LOCAL_XFS = FSProfile(
    name="xfs-local",
    meta_op_s=2.5e-5,
    read_bw=1.2e9,
    write_bw=0.9e9,
    degrade_threshold=0,
    dir_degrade=0.0,
)
# A zero-cost profile for unit tests that don't care about timing.
NULL_FS = FSProfile(name="null", meta_op_s=0.0, read_bw=float("inf"), write_bw=float("inf"))


@dataclass
class SimClock:
    """Virtual clock accumulating modeled filesystem seconds (thread-safe)."""

    total: float = 0.0
    meta_ops: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def charge(self, seconds: float) -> None:
        with self._lock:
            self.total += seconds

    def snapshot(self) -> float:
        with self._lock:
            return self.total


class FS:
    """Filesystem wrapper: performs real ops, charges modeled time.

    ``n_files`` tracks how many files this FS instance has accumulated (the
    repository's footprint) — the quantity the paper identifies as the driver
    of parallel-FS degradation.
    """

    def __init__(self, profile: FSProfile = NULL_FS, clock: SimClock | None = None):
        self.profile = profile
        self.clock = clock or SimClock()
        self._nfiles_lock = threading.Lock()
        self.n_files = 0

    # -- cost charging -------------------------------------------------
    def _meta(self, n: int = 1) -> None:
        p = self.profile
        extra = p.dir_degrade * max(0, self.n_files - p.degrade_threshold)
        self.clock.charge(n * (p.meta_op_s + extra))
        self.clock.meta_ops += n

    def _xfer(self, nbytes: int, write: bool) -> None:
        bw = self.profile.write_bw if write else self.profile.read_bw
        if bw != float("inf"):
            self.clock.charge(nbytes / bw)
        if write:
            self.clock.bytes_written += nbytes
        else:
            self.clock.bytes_read += nbytes

    def _track_new_file(self, path: str, existed: bool) -> None:
        if not existed:
            with self._nfiles_lock:
                self.n_files += 1

    # -- operations ----------------------------------------------------
    def exists(self, path: str) -> bool:
        self._meta()
        return os.path.exists(path)

    def stat_size(self, path: str) -> int:
        self._meta()
        return os.stat(path).st_size

    def mkdir(self, path: str) -> None:
        self._meta()
        os.makedirs(path, exist_ok=True)

    def listdir(self, path: str) -> list[str]:
        self._meta()
        return sorted(os.listdir(path))

    def write_bytes(self, path: str, data: bytes) -> None:
        existed = os.path.exists(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "wb") as f:
            f.write(data)
        self._meta(2)  # open+close
        self._xfer(len(data), write=True)
        self._track_new_file(path, existed)

    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            data = f.read()
        self._meta(2)
        self._xfer(len(data), write=False)
        return data

    def append_text(self, path: str, text: str) -> None:
        existed = os.path.exists(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "a") as f:
            f.write(text)
        self._meta(2)
        self._xfer(len(text), write=True)
        self._track_new_file(path, existed)

    def unlink(self, path: str) -> None:
        self._meta()
        if os.path.exists(path):
            os.unlink(path)
            with self._nfiles_lock:
                self.n_files = max(0, self.n_files - 1)

    def rename(self, src: str, dst: str) -> None:
        self._meta(2)
        os.makedirs(os.path.dirname(dst) or ".", exist_ok=True)
        os.replace(src, dst)

    def copy_file(self, src: str, dst: str) -> int:
        """Deep copy (used by --alt-dir staging). Returns bytes copied."""
        existed = os.path.exists(dst)
        os.makedirs(os.path.dirname(dst) or ".", exist_ok=True)
        shutil.copy2(src, dst)
        n = os.stat(dst).st_size
        self._meta(4)
        self._xfer(n, write=False)
        self._xfer(n, write=True)
        self._track_new_file(dst, existed)
        return n

    def chmod_readonly(self, path: str, readonly: bool = True) -> None:
        self._meta()
        mode = 0o444 if readonly else 0o644
        os.chmod(path, mode)
