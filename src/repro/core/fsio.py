"""Latency-modeled filesystem layer.

The paper's central performance finding (Figures 7-10) is *filesystem*
behaviour: a DataLad repository on a parallel file system (GPFS) suffers
superlinear per-job ``slurm-finish`` cost once the repository holds more than
~50 000 files, while a repository on a node-local file system (XFS ``/tmp``)
stays ~flat. This container has neither GPFS nor Slurm, so every filesystem
operation performed by the version store goes through this layer, which

  1. actually performs the operation (so correctness is real), and
  2. charges its *modeled* cost on a virtual clock (``SimClock``), using an
     ``FSProfile`` whose parameters are calibrated against the paper's
     measurements.

Benchmarks report both the simulated (FS-bound) seconds and the real
wall-clock seconds of the code path; EXPERIMENTS.md labels them explicitly.

Cost model
----------
A metadata operation (create/stat/unlink/rename/open) on a path ``p`` costs

    meta_op_s + dir_degrade * max(0, entries(dirname(p)) - degrade_threshold)

i.e. the degradation the paper measures on a parallel FS is charged where it
physically arises: directory-block contention and metadata-server pressure
grow with the *entry count of the directory being touched* (for the version
store, the ``objects/<2-hex>/`` shards, which accumulate one entry per object
the repository has ever stored). Local file systems have ``dir_degrade == 0``.
``listdir`` is charged against the listed directory itself. Data transfer
costs ``bytes / bandwidth``.

Concurrent transfers (DESIGN.md §9)
-----------------------------------
``bytes / bandwidth`` alone cannot measure parallel data movement: N
overlapping transfers would each be charged as if they had the device to
themselves, so parallelism would look free and contention would be
invisible. Transfers therefore declare themselves on the shared clock:
every streamed operation opens a *stream session* in its direction's pool
(read or write) for the real duration of the I/O, and each chunk moved
while ``k`` sessions are open is charged

    nbytes / min(k * stream_bw, aggregate_bw)

i.e. the effective delivered bandwidth with ``k`` concurrent streams is
``min(k * per-stream cap, aggregate)``. The per-stream cap
(``read_stream_bw``/``write_stream_bw``) models a single client stream
hitting a bounded number of GPFS stripes/NSDs; it defaults to the
aggregate, so profiles that don't declare one — and every serial caller —
are charged *identically to the flat model*. With a cap below the
aggregate, parallel streams show real speedup up to saturation
(``k * cap >= aggregate``) and pure contention past it. The pool is
per-clock and per-direction: every FS sharing a ``SimClock`` contends for
the same modeled backend, which is exactly the paper's one-filesystem-
many-jobs scenario.

The superlinear per-job finish curve of the paper then *emerges* from an
implementation that performs O(repo files) metadata ops per commit against
degraded directories (see ``Repository.save(engine="full")``), while the
incremental commit engine (DESIGN.md §4) performs O(changed paths) ops and
stays flat — the local-FS curve achieved algorithmically.

``FS`` tracks directory entry counts as it creates/removes files; benchmarks
that emulate a repository with a large accumulated footprint seed the counts
via :meth:`FS.preload_dir_entries` (see ``benchmarks/common.py``).
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
import uuid
from contextlib import contextmanager
from dataclasses import dataclass, field

from . import faults as _faults

_CHUNK = 1 << 20  # streaming quantum: charge/hash/copy granularity


@dataclass
class FSProfile:
    name: str
    meta_op_s: float  # base metadata-op latency (seconds)
    read_bw: float  # bytes/second, AGGREGATE across concurrent streams
    write_bw: float  # bytes/second, AGGREGATE across concurrent streams
    degrade_threshold: int = 0  # directory entries beyond which metadata degrades
    dir_degrade: float = 0.0  # extra seconds per metadata op per entry beyond threshold
    # per-stream bandwidth cap (one client stream over a bounded number of
    # stripes); None = the aggregate, i.e. a single stream saturates the
    # device and concurrency buys nothing — the pre-§9 flat model.
    read_stream_bw: float | None = None
    write_stream_bw: float | None = None


# Calibrated against the paper's evaluation cluster:
#  - pure `sbatch` ~0.05 s/job, `slurm-schedule` 0.35-0.7 s/job (Fig. 7),
#  - `slurm-finish` blowing past 10 s/job beyond ~50k repo files on GPFS,
#    vs 0.6-1.7 s/job flat on local XFS (Fig. 9).
# With 256 object-store shards, 50k accumulated objects put ~195 entries in
# each shard, so a threshold of 192 reproduces the paper's ~50k-file onset.
GPFS = FSProfile(
    name="gpfs",
    meta_op_s=2.0e-3,
    read_bw=2.0e9,
    write_bw=1.5e9,
    degrade_threshold=192,
    dir_degrade=2.2e-6,
)
# GPFS with striping made explicit: same aggregate bandwidth and metadata
# behaviour as `GPFS`, but one client stream only drives 1/8 of the stripes
# (~one NSD server's worth), so bytes-heavy work scales with concurrent
# streams up to 8-way saturation — the profile bench_ingest measures the
# paper's "multiple jobs concurrently on the same data repository" claim on.
GPFS_STRIPED = FSProfile(
    name="gpfs-striped",
    meta_op_s=2.0e-3,
    read_bw=2.0e9,
    write_bw=1.5e9,
    degrade_threshold=192,
    dir_degrade=2.2e-6,
    read_stream_bw=2.0e9 / 8,
    write_stream_bw=1.5e9 / 8,
)
LOCAL_XFS = FSProfile(
    name="xfs-local",
    meta_op_s=2.5e-5,
    read_bw=1.2e9,
    write_bw=0.9e9,
    degrade_threshold=0,
    dir_degrade=0.0,
)
# A zero-cost profile for unit tests that don't care about timing.
NULL_FS = FSProfile(name="null", meta_op_s=0.0, read_bw=float("inf"), write_bw=float("inf"))


@dataclass
class SimClock:
    """Virtual clock accumulating modeled filesystem seconds (thread-safe).

    All counters are mutated under the lock; use :meth:`charge_meta` /
    :meth:`charge_xfer` rather than poking ``meta_ops``/``bytes_*`` directly.
    """

    total: float = 0.0
    meta_ops: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    # concurrent-transfer pools (§9): number of stream sessions currently
    # open per direction. [0] = read, [1] = write.
    _active_streams: list = field(default_factory=lambda: [0, 0], repr=False)

    def charge(self, seconds: float) -> None:
        with self._lock:
            self.total += seconds

    def charge_meta(self, n: int, seconds: float) -> None:
        with self._lock:
            self.total += seconds
            self.meta_ops += n

    def charge_xfer(self, nbytes: int, write: bool, seconds: float) -> None:
        with self._lock:
            self.total += seconds
            if write:
                self.bytes_written += nbytes
            else:
                self.bytes_read += nbytes

    # -- concurrent-transfer pool (§9) ---------------------------------
    def stream_begin(self, write: bool) -> None:
        """Open a stream session: from now until :meth:`stream_end`, chunks
        charged in this direction share the aggregate bandwidth with every
        other open session on this clock."""
        with self._lock:
            self._active_streams[int(write)] += 1

    def stream_end(self, write: bool) -> None:
        with self._lock:
            self._active_streams[int(write)] = max(
                0, self._active_streams[int(write)] - 1
            )

    def active_streams(self, write: bool) -> int:
        with self._lock:
            return self._active_streams[int(write)]

    def charge_stream_chunk(
        self, nbytes: int, write: bool, agg_bw: float, stream_bw: float
    ) -> None:
        """Charge one chunk of an open stream session: with ``k`` sessions
        open in this direction, the effective delivered bandwidth is
        ``min(k * stream_bw, agg_bw)``, so every byte moved while k streams
        overlap advances the clock by 1/eff — summed over all streams'
        chunks this yields the *makespan* of the overlapping transfers, and
        degenerates to ``nbytes / agg_bw`` for a lone serial caller with the
        default ``stream_bw == agg_bw``."""
        with self._lock:
            k = max(1, self._active_streams[int(write)])
            eff = min(agg_bw, k * stream_bw)
            if eff != float("inf"):
                self.total += nbytes / eff
            if write:
                self.bytes_written += nbytes
            else:
                self.bytes_read += nbytes

    def snapshot(self) -> float:
        with self._lock:
            return self.total


class FS:
    """Filesystem wrapper: performs real ops, charges modeled time.

    ``n_files`` tracks how many files this FS instance has accumulated (the
    repository's footprint); ``_dir_entries`` tracks the per-directory entry
    counts that drive parallel-FS metadata degradation.
    """

    def __init__(
        self,
        profile: FSProfile = NULL_FS,
        clock: SimClock | None = None,
        faults: "_faults.FaultPlan | None" = None,
    ):
        self.profile = profile
        self.clock = clock or SimClock()
        self.faults = faults
        # incarnation token (DESIGN.md §10): stamped into lock files and tmp
        # names so crash recovery can tell a dead owner from a live one even
        # when the "dead" owner was a simulated incarnation of this process
        self.token = _faults.new_token()
        if faults is not None:
            faults.attach_fs(self)
        self._stats_lock = threading.Lock()
        self._mkdir_lock = threading.Lock()
        self._rename_lock = threading.Lock()
        self.n_files = 0
        self._dir_entries: dict[str, int] = {}
        # readdirplus stat cache (consume-on-use): scan_dir() pays ONE
        # charged enumeration and primes per-file sizes; the next
        # stat_size() of each file consumes its entry free of charge —
        # GPFS's stat-ahead / batched RPC behaviour. Any mutating op
        # invalidates the touched path, so a cached size can never mask
        # a write that happened after the scan.
        self._stat_cache: dict[str, int] = {}

    # -- fault injection (§10) -----------------------------------------
    def _fault(self, op: str, path: str) -> None:
        """Injection gate, called before the real operation. Transient
        faults are retried here with SimClock-charged exponential backoff
        (the retry consults the plan again, so per-call counters advance
        and an every-k rule lets the retry through); persistent faults and
        crashes propagate."""
        plan = self.faults
        if plan is None:
            return
        attempt = 0
        while True:
            try:
                plan.on_fs(op, path, self)
                return
            except _faults.InjectedIOError as e:
                if not e.transient or attempt >= plan.max_fs_retries:
                    raise
                self.clock.charge(plan.backoff_s(attempt))
                attempt += 1

    def crash_point(self, name: str) -> None:
        """Named phase boundary for the §10 crash matrix; no-op without a
        fault plan."""
        if self.faults is not None:
            self.faults.crash_point(name, self)

    # -- directory pressure --------------------------------------------
    def _dir_of(self, path: str) -> str:
        return os.path.dirname(os.path.abspath(path))

    def dir_entry_count(self, dirpath: str) -> int:
        with self._stats_lock:
            return self._dir_entries.get(os.path.abspath(dirpath), 0)

    def preload_dir_entries(self, dirpath: str, n: int) -> None:
        """Seed the modeled entry count of ``dirpath`` (benchmark emulation of
        a repository with a large accumulated footprint)."""
        with self._stats_lock:
            self._dir_entries[os.path.abspath(dirpath)] = n

    def _bump_dir(self, dirpath: str, delta: int) -> None:
        with self._stats_lock:
            n = self._dir_entries.get(dirpath, 0) + delta
            self._dir_entries[dirpath] = max(0, n)

    def dir_entries_snapshot(self) -> dict[str, int]:
        """Copy of the modeled per-directory entry counts (bookkeeping read,
        charges nothing — used by repack-pressure checks)."""
        with self._stats_lock:
            return dict(self._dir_entries)

    def purge_phantom_entries(self, dirpath: str) -> int:
        """Unlink every *modeled* entry of ``dirpath`` that has no backing
        file, charging the storm as if each phantom were really unlinked.

        Benchmarks emulate a repository's accumulated object footprint by
        seeding shard entry counts (:meth:`preload_dir_entries`) without
        materializing the files. ``repack`` physically unlinks what exists
        and calls this so the modeled count agrees with the now-compacted
        directory — the i-th phantom unlink is charged at the entry count it
        would have seen (closed form, so purging a 200k-object footprint
        needs no 200k-iteration loop). Returns the number purged; a no-op
        whenever modeled == real (i.e. outside benchmark emulation)."""
        d = os.path.abspath(dirpath)
        real = len(os.listdir(d)) if os.path.isdir(d) else 0
        with self._stats_lock:
            modeled = self._dir_entries.get(d, 0)
            phantom = modeled - real
            if phantom <= 0:
                return 0
            self._dir_entries[d] = real
            self.n_files = max(0, self.n_files - phantom)
        p = self.profile
        total = phantom * p.meta_op_s
        if p.dir_degrade:
            # entry counts seen: modeled, modeled-1, ..., real+1
            def tri(n: int) -> int:  # sum 1..n
                return n * (n + 1) // 2 if n > 0 else 0

            total += p.dir_degrade * (
                tri(modeled - p.degrade_threshold)
                - tri(real - p.degrade_threshold)
            )
        self.clock.charge_meta(phantom, total)
        return phantom

    # -- cost charging -------------------------------------------------
    def _charge_meta(self, n: int, dirpath: str) -> None:
        p = self.profile
        extra = 0.0
        if p.dir_degrade:
            with self._stats_lock:
                entries = self._dir_entries.get(dirpath, 0)
            extra = p.dir_degrade * max(0, entries - p.degrade_threshold)
        self.clock.charge_meta(n, n * (p.meta_op_s + extra))

    def _meta(self, n: int = 1, path: str | None = None) -> None:
        self._charge_meta(n, self._dir_of(path) if path else "")

    def _stream_bws(self, write: bool) -> tuple[float, float]:
        """(aggregate bw, per-stream cap) for a direction; cap defaults to
        the aggregate so undeclared profiles keep the flat model."""
        p = self.profile
        if write:
            return p.write_bw, p.write_stream_bw or p.write_bw
        return p.read_bw, p.read_stream_bw or p.read_bw

    @contextmanager
    def transfer_stream(self, write: bool):
        """Stream session (§9): hold open for the real duration of a
        transfer so overlapping sessions split the aggregate bandwidth.
        Yields a charge function taking the chunk's byte count."""
        agg, cap = self._stream_bws(write)
        clock = self.clock

        def charge(nbytes: int) -> None:
            clock.charge_stream_chunk(nbytes, write, agg, cap)

        clock.stream_begin(write)
        try:
            yield charge
        finally:
            clock.stream_end(write)

    def _xfer(self, nbytes: int, write: bool) -> None:
        """Single-shot transfer charge, in stream-session quanta so even
        monolithic ops contend with (and are discounted by) overlapping
        streams. A lone caller is charged exactly ``nbytes / bandwidth``."""
        with self.transfer_stream(write) as charge:
            left = nbytes
            while True:
                charge(min(left, _CHUNK))
                left -= _CHUNK
                if left <= 0:
                    break

    def _track_new_file(self, path: str, existed: bool) -> None:
        if not existed:
            with self._stats_lock:
                self.n_files += 1
                d = self._dir_of(path)
                self._dir_entries[d] = self._dir_entries.get(d, 0) + 1

    def _makedirs_counted(self, dirpath: str) -> None:
        """makedirs that counts every implicitly created directory as an
        entry of *its* parent. Probe + create + count run under one lock so
        concurrent ingest workers racing to create the same parent don't
        double-count it."""
        if os.path.isdir(dirpath):
            return
        with self._mkdir_lock:
            created = []
            cur = os.path.abspath(dirpath)
            while cur and not os.path.isdir(cur):
                created.append(cur)
                nxt = os.path.dirname(cur)
                if nxt == cur:
                    break
                cur = nxt
            os.makedirs(dirpath, exist_ok=True)
            with self._stats_lock:
                for d in created:
                    pd = os.path.dirname(d)
                    self._dir_entries[pd] = self._dir_entries.get(pd, 0) + 1

    def _ensure_parent(self, path: str) -> None:
        self._makedirs_counted(os.path.dirname(path) or ".")

    # -- operations ----------------------------------------------------
    def exists(self, path: str) -> bool:
        if self.faults is not None:
            self._fault("exists", path)
        self._meta(1, path)
        return os.path.exists(path)

    def isdir(self, path: str) -> bool:
        if self.faults is not None:
            self._fault("exists", path)
        self._meta(1, path)
        return os.path.isdir(path)

    def stat_size(self, path: str) -> int:
        ap = os.path.abspath(path)
        with self._stats_lock:
            cached = self._stat_cache.pop(ap, None)
        if cached is not None:
            # primed by scan_dir(): already paid for by the enumeration
            return cached
        if self.faults is not None:
            self._fault("stat", path)
        self._meta(1, path)
        return os.stat(path).st_size

    def stat_mtime(self, path: str) -> float:
        if self.faults is not None:
            self._fault("stat", path)
        self._meta(1, path)
        return os.stat(path).st_mtime

    def mkdir(self, path: str) -> None:
        if self.faults is not None:
            self._fault("write", path)
        self._meta(1, path)
        self._makedirs_counted(path)

    def listdir(self, path: str) -> list[str]:
        if self.faults is not None:
            self._fault("listdir", path)
        # enumeration cost scales with the listed directory's own entry count
        self._charge_meta(1, os.path.abspath(path))
        return sorted(os.listdir(path))

    def scan_dir(self, path: str) -> list[str]:
        """Enumerate ``path`` *readdirplus-style*: one charged enumeration
        (same cost as :meth:`listdir`) that also primes the stat cache with
        every regular file's size, so the subsequent ``stat_size`` of each
        entry is served from the batch instead of paying its own metadata
        RPC. Entries are consume-on-use and invalidated by any mutating op
        on the path. Returns the sorted entry names."""
        if self.faults is not None:
            self._fault("listdir", path)
        self._charge_meta(1, os.path.abspath(path))
        names: list[str] = []
        with self._stats_lock:
            with os.scandir(path) as it:
                for de in it:
                    names.append(de.name)
                    try:
                        if de.is_file(follow_symlinks=False):
                            self._stat_cache[
                                os.path.abspath(de.path)
                            ] = de.stat(follow_symlinks=False).st_size
                    except OSError:
                        continue
        return sorted(names)

    def _stat_invalidate(self, *paths: str) -> None:
        """Drop stat-cache entries for mutated paths (callers: every op
        that can change a file's size or existence)."""
        with self._stats_lock:
            for p in paths:
                self._stat_cache.pop(os.path.abspath(p), None)

    def stat_cache_clear(self) -> None:
        """Drop every unconsumed stat-cache entry. Batch callers (the
        finish staging plane) clear after their batch: job payloads are
        written by processes outside this FS layer, so a primed size must
        never outlive the batch that scanned it."""
        with self._stats_lock:
            self._stat_cache.clear()

    def write_bytes(self, path: str, data: bytes) -> None:
        self.write_chunks(path, (data,))

    def write_chunks(self, path: str, chunks, fsync: bool = False) -> int:
        """Streamed write: one open/close plus the total bytes, never
        holding more than one chunk in memory — ``write_bytes`` is the
        single-chunk special case, so the charging protocol (2 meta ops,
        write-side transfer, new-file tracking) lives only here. The write
        stream stays open (and charged per chunk) for the real duration of
        the loop, so concurrent writers contend under the §9 model.
        Returns the byte count written."""
        faults = self.faults
        if faults is not None:
            self._fault("write", path)
        self._stat_invalidate(path)
        self._ensure_parent(path)
        # claim the path atomically (probe + create + count under one
        # lock): two workers writing the same path — e.g. put_blob of
        # identical small content from concurrent ingest workers — must not
        # both observe it absent and double-count the directory entry
        with self._rename_lock:
            existed = os.path.exists(path)
            if not existed:
                open(path, "wb").close()
                self._track_new_file(path, existed)
        total = 0
        self._meta(2, path)
        with open(path, "wb") as f, self.transfer_stream(True) as charge:
            for c in chunks:
                if faults is not None:
                    # torn-write site: a fault here leaves a partial file
                    self._fault("write-chunk", path)
                f.write(c)
                total += len(c)
                charge(len(c))
            if fsync:
                f.flush()
                os.fsync(f.fileno())
                self._meta(1, path)
        return total

    def write_atomic(self, path: str, data: bytes, fsync: bool = True) -> None:
        """Durable publish: write to a unique sibling tmp (optionally
        fsynced) and rename onto ``path`` — the §10 journal write protocol.
        Readers never observe a torn file; a crash leaves only a tmp."""
        tmp = f"{path}.tmp-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        self.write_chunks(tmp, (data,), fsync=fsync)
        self.rename(tmp, path)

    def create_exclusive(self, path: str, data: bytes) -> None:
        """Atomic O_CREAT|O_EXCL create+write+fsync — the lock-file
        primitive (§10). Raises ``FileExistsError`` if ``path`` exists."""
        if self.faults is not None:
            self._fault("write", path)
        self._stat_invalidate(path)
        self._ensure_parent(path)
        fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        try:
            os.write(fd, data)
            os.fsync(fd)
        finally:
            os.close(fd)
        self._meta(2, path)
        self._xfer(len(data), write=True)
        self._track_new_file(path, False)

    def read_bytes(self, path: str) -> bytes:
        if self.faults is not None:
            self._fault("read", path)
        with open(path, "rb") as f:
            data = f.read()
        self._meta(2, path)
        self._xfer(len(data), write=False)
        return data

    @contextmanager
    def open_read(self, path: str, chunk_size: int = _CHUNK):
        """Chunked read stream: yields an iterator of byte chunks, charging
        each against the read pool while the session is open — the §9
        primitive the single-pass annex ingest is built on. Charges the
        same 2 meta ops + size bytes a ``read_bytes`` of the file would."""
        if self.faults is not None:
            self._fault("read", path)
        self._meta(2, path)
        with open(path, "rb") as f, self.transfer_stream(False) as charge:

            def chunks():
                while True:
                    c = f.read(chunk_size)
                    if not c:
                        return
                    charge(len(c))
                    yield c

            yield chunks()

    def hash_file(self, path: str, chunk_size: int = _CHUNK) -> tuple[str, int]:
        """sha256 + size of a file, streamed through the cost model (one
        charged read pass) — hashing is data-plane work, not free."""
        h = hashlib.sha256()
        size = 0
        with self.open_read(path, chunk_size) as chunks:
            for c in chunks:
                h.update(c)
                size += len(c)
        return h.hexdigest(), size

    def read_range(self, path: str, offset: int, nbytes: int) -> bytes:
        """Positioned read (the pack-file read path): open + seek + read of
        ``nbytes``. Charged like :meth:`read_bytes` of the range — the seek
        itself is free; only the bytes actually transferred cost time."""
        if self.faults is not None:
            self._fault("read", path)
        with open(path, "rb") as f:
            f.seek(offset)
            data = f.read(nbytes)
        if len(data) != nbytes:
            raise IOError(
                f"short read: wanted [{offset}:{offset + nbytes}) of {path}"
            )
        self._meta(2, path)
        self._xfer(nbytes, write=False)
        return data

    def append_text(self, path: str, text: str) -> None:
        if self.faults is not None:
            self._fault("write", path)
        self._stat_invalidate(path)
        existed = os.path.exists(path)
        self._ensure_parent(path)
        with open(path, "a") as f:
            f.write(text)
        self._meta(2, path)
        self._xfer(len(text), write=True)
        self._track_new_file(path, existed)

    def unlink(self, path: str) -> None:
        if self.faults is not None:
            self._fault("unlink", path)
        self._stat_invalidate(path)
        self._meta(1, path)
        if os.path.exists(path):
            os.unlink(path)
            with self._stats_lock:
                self.n_files = max(0, self.n_files - 1)
                d = self._dir_of(path)
                self._dir_entries[d] = max(0, self._dir_entries.get(d, 0) - 1)

    def rename(self, src: str, dst: str) -> None:
        if self.faults is not None:
            # matched against the destination: "fail the 3rd rename under
            # objects/" targets where the publish lands
            self._fault("rename", dst)
        self._stat_invalidate(src, dst)
        self._meta(1, src)
        self._meta(1, dst)
        self._ensure_parent(dst)
        # probe + replace + count under one lock: two workers publishing
        # onto the same dst (concurrent dedup ingest) must not both observe
        # existed=False and double-count the target directory's entry
        with self._rename_lock:
            existed = os.path.exists(dst)
            os.replace(src, dst)
            self._bump_dir(self._dir_of(src), -1)
            if not existed:
                self._bump_dir(self._dir_of(dst), +1)
            else:
                # two files collapsed into one: the footprint shrank
                with self._stats_lock:
                    self.n_files = max(0, self.n_files - 1)

    def copy_file(self, src: str, dst: str) -> int:
        """Deep copy (used by --alt-dir staging). Chunked, with both stream
        sessions held open for the real duration, so concurrent copies
        contend under the §9 model; a lone copy charges exactly the old
        read + write transfer. Returns bytes copied."""
        if self.faults is not None:
            self._fault("read", src)
            self._fault("write", dst)
        self._stat_invalidate(dst)
        existed = os.path.exists(dst)
        self._ensure_parent(dst)
        n = 0
        self._meta(2, src)
        self._meta(2, dst)
        with open(src, "rb") as fsrc, open(dst, "wb") as fdst, \
                self.transfer_stream(False) as charge_r, \
                self.transfer_stream(True) as charge_w:
            while True:
                c = fsrc.read(_CHUNK)
                if not c:
                    break
                charge_r(len(c))
                fdst.write(c)
                charge_w(len(c))
                n += len(c)
        shutil.copystat(src, dst)
        self._track_new_file(dst, existed)
        return n

    def chmod_readonly(self, path: str, readonly: bool = True) -> None:
        self._meta(1, path)
        mode = 0o444 if readonly else 0o644
        os.chmod(path, mode)
