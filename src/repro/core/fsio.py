"""Latency-modeled filesystem layer.

The paper's central performance finding (Figures 7-10) is *filesystem*
behaviour: a DataLad repository on a parallel file system (GPFS) suffers
superlinear per-job ``slurm-finish`` cost once the repository holds more than
~50 000 files, while a repository on a node-local file system (XFS ``/tmp``)
stays ~flat. This container has neither GPFS nor Slurm, so every filesystem
operation performed by the version store goes through this layer, which

  1. actually performs the operation (so correctness is real), and
  2. charges its *modeled* cost on a virtual clock (``SimClock``), using an
     ``FSProfile`` whose parameters are calibrated against the paper's
     measurements.

Benchmarks report both the simulated (FS-bound) seconds and the real
wall-clock seconds of the code path; EXPERIMENTS.md labels them explicitly.

Cost model
----------
A metadata operation (create/stat/unlink/rename/open) on a path ``p`` costs

    meta_op_s + dir_degrade * max(0, entries(dirname(p)) - degrade_threshold)

i.e. the degradation the paper measures on a parallel FS is charged where it
physically arises: directory-block contention and metadata-server pressure
grow with the *entry count of the directory being touched* (for the version
store, the ``objects/<2-hex>/`` shards, which accumulate one entry per object
the repository has ever stored). Local file systems have ``dir_degrade == 0``.
``listdir`` is charged against the listed directory itself. Data transfer
costs ``bytes / bandwidth``.

The superlinear per-job finish curve of the paper then *emerges* from an
implementation that performs O(repo files) metadata ops per commit against
degraded directories (see ``Repository.save(engine="full")``), while the
incremental commit engine (DESIGN.md §4) performs O(changed paths) ops and
stays flat — the local-FS curve achieved algorithmically.

``FS`` tracks directory entry counts as it creates/removes files; benchmarks
that emulate a repository with a large accumulated footprint seed the counts
via :meth:`FS.preload_dir_entries` (see ``benchmarks/common.py``).
"""
from __future__ import annotations

import os
import shutil
import threading
from dataclasses import dataclass, field


@dataclass
class FSProfile:
    name: str
    meta_op_s: float  # base metadata-op latency (seconds)
    read_bw: float  # bytes/second
    write_bw: float  # bytes/second
    degrade_threshold: int = 0  # directory entries beyond which metadata degrades
    dir_degrade: float = 0.0  # extra seconds per metadata op per entry beyond threshold


# Calibrated against the paper's evaluation cluster:
#  - pure `sbatch` ~0.05 s/job, `slurm-schedule` 0.35-0.7 s/job (Fig. 7),
#  - `slurm-finish` blowing past 10 s/job beyond ~50k repo files on GPFS,
#    vs 0.6-1.7 s/job flat on local XFS (Fig. 9).
# With 256 object-store shards, 50k accumulated objects put ~195 entries in
# each shard, so a threshold of 192 reproduces the paper's ~50k-file onset.
GPFS = FSProfile(
    name="gpfs",
    meta_op_s=2.0e-3,
    read_bw=2.0e9,
    write_bw=1.5e9,
    degrade_threshold=192,
    dir_degrade=2.2e-6,
)
LOCAL_XFS = FSProfile(
    name="xfs-local",
    meta_op_s=2.5e-5,
    read_bw=1.2e9,
    write_bw=0.9e9,
    degrade_threshold=0,
    dir_degrade=0.0,
)
# A zero-cost profile for unit tests that don't care about timing.
NULL_FS = FSProfile(name="null", meta_op_s=0.0, read_bw=float("inf"), write_bw=float("inf"))


@dataclass
class SimClock:
    """Virtual clock accumulating modeled filesystem seconds (thread-safe).

    All counters are mutated under the lock; use :meth:`charge_meta` /
    :meth:`charge_xfer` rather than poking ``meta_ops``/``bytes_*`` directly.
    """

    total: float = 0.0
    meta_ops: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def charge(self, seconds: float) -> None:
        with self._lock:
            self.total += seconds

    def charge_meta(self, n: int, seconds: float) -> None:
        with self._lock:
            self.total += seconds
            self.meta_ops += n

    def charge_xfer(self, nbytes: int, write: bool, seconds: float) -> None:
        with self._lock:
            self.total += seconds
            if write:
                self.bytes_written += nbytes
            else:
                self.bytes_read += nbytes

    def snapshot(self) -> float:
        with self._lock:
            return self.total


class FS:
    """Filesystem wrapper: performs real ops, charges modeled time.

    ``n_files`` tracks how many files this FS instance has accumulated (the
    repository's footprint); ``_dir_entries`` tracks the per-directory entry
    counts that drive parallel-FS metadata degradation.
    """

    def __init__(self, profile: FSProfile = NULL_FS, clock: SimClock | None = None):
        self.profile = profile
        self.clock = clock or SimClock()
        self._stats_lock = threading.Lock()
        self.n_files = 0
        self._dir_entries: dict[str, int] = {}

    # -- directory pressure --------------------------------------------
    def _dir_of(self, path: str) -> str:
        return os.path.dirname(os.path.abspath(path))

    def dir_entry_count(self, dirpath: str) -> int:
        with self._stats_lock:
            return self._dir_entries.get(os.path.abspath(dirpath), 0)

    def preload_dir_entries(self, dirpath: str, n: int) -> None:
        """Seed the modeled entry count of ``dirpath`` (benchmark emulation of
        a repository with a large accumulated footprint)."""
        with self._stats_lock:
            self._dir_entries[os.path.abspath(dirpath)] = n

    def _bump_dir(self, dirpath: str, delta: int) -> None:
        with self._stats_lock:
            n = self._dir_entries.get(dirpath, 0) + delta
            self._dir_entries[dirpath] = max(0, n)

    def dir_entries_snapshot(self) -> dict[str, int]:
        """Copy of the modeled per-directory entry counts (bookkeeping read,
        charges nothing — used by repack-pressure checks)."""
        with self._stats_lock:
            return dict(self._dir_entries)

    def purge_phantom_entries(self, dirpath: str) -> int:
        """Unlink every *modeled* entry of ``dirpath`` that has no backing
        file, charging the storm as if each phantom were really unlinked.

        Benchmarks emulate a repository's accumulated object footprint by
        seeding shard entry counts (:meth:`preload_dir_entries`) without
        materializing the files. ``repack`` physically unlinks what exists
        and calls this so the modeled count agrees with the now-compacted
        directory — the i-th phantom unlink is charged at the entry count it
        would have seen (closed form, so purging a 200k-object footprint
        needs no 200k-iteration loop). Returns the number purged; a no-op
        whenever modeled == real (i.e. outside benchmark emulation)."""
        d = os.path.abspath(dirpath)
        real = len(os.listdir(d)) if os.path.isdir(d) else 0
        with self._stats_lock:
            modeled = self._dir_entries.get(d, 0)
            phantom = modeled - real
            if phantom <= 0:
                return 0
            self._dir_entries[d] = real
            self.n_files = max(0, self.n_files - phantom)
        p = self.profile
        total = phantom * p.meta_op_s
        if p.dir_degrade:
            # entry counts seen: modeled, modeled-1, ..., real+1
            def tri(n: int) -> int:  # sum 1..n
                return n * (n + 1) // 2 if n > 0 else 0

            total += p.dir_degrade * (
                tri(modeled - p.degrade_threshold)
                - tri(real - p.degrade_threshold)
            )
        self.clock.charge_meta(phantom, total)
        return phantom

    # -- cost charging -------------------------------------------------
    def _charge_meta(self, n: int, dirpath: str) -> None:
        p = self.profile
        extra = 0.0
        if p.dir_degrade:
            with self._stats_lock:
                entries = self._dir_entries.get(dirpath, 0)
            extra = p.dir_degrade * max(0, entries - p.degrade_threshold)
        self.clock.charge_meta(n, n * (p.meta_op_s + extra))

    def _meta(self, n: int = 1, path: str | None = None) -> None:
        self._charge_meta(n, self._dir_of(path) if path else "")

    def _xfer(self, nbytes: int, write: bool) -> None:
        bw = self.profile.write_bw if write else self.profile.read_bw
        seconds = nbytes / bw if bw != float("inf") else 0.0
        self.clock.charge_xfer(nbytes, write, seconds)

    def _track_new_file(self, path: str, existed: bool) -> None:
        if not existed:
            with self._stats_lock:
                self.n_files += 1
                d = self._dir_of(path)
                self._dir_entries[d] = self._dir_entries.get(d, 0) + 1

    def _makedirs_counted(self, dirpath: str) -> None:
        """makedirs that counts every implicitly created directory as an
        entry of *its* parent."""
        if os.path.isdir(dirpath):
            return
        created = []
        cur = os.path.abspath(dirpath)
        while cur and not os.path.isdir(cur):
            created.append(cur)
            nxt = os.path.dirname(cur)
            if nxt == cur:
                break
            cur = nxt
        os.makedirs(dirpath, exist_ok=True)
        with self._stats_lock:
            for d in created:
                pd = os.path.dirname(d)
                self._dir_entries[pd] = self._dir_entries.get(pd, 0) + 1

    def _ensure_parent(self, path: str) -> None:
        self._makedirs_counted(os.path.dirname(path) or ".")

    # -- operations ----------------------------------------------------
    def exists(self, path: str) -> bool:
        self._meta(1, path)
        return os.path.exists(path)

    def isdir(self, path: str) -> bool:
        self._meta(1, path)
        return os.path.isdir(path)

    def stat_size(self, path: str) -> int:
        self._meta(1, path)
        return os.stat(path).st_size

    def stat_mtime(self, path: str) -> float:
        self._meta(1, path)
        return os.stat(path).st_mtime

    def mkdir(self, path: str) -> None:
        self._meta(1, path)
        self._makedirs_counted(path)

    def listdir(self, path: str) -> list[str]:
        # enumeration cost scales with the listed directory's own entry count
        self._charge_meta(1, os.path.abspath(path))
        return sorted(os.listdir(path))

    def write_bytes(self, path: str, data: bytes) -> None:
        self.write_chunks(path, (data,))

    def write_chunks(self, path: str, chunks) -> int:
        """Streamed write: one open/close plus the total bytes, never
        holding more than one chunk in memory — ``write_bytes`` is the
        single-chunk special case, so the charging protocol (2 meta ops,
        write-side transfer, new-file tracking) lives only here. Returns
        the byte count written."""
        existed = os.path.exists(path)
        self._ensure_parent(path)
        total = 0
        with open(path, "wb") as f:
            for c in chunks:
                f.write(c)
                total += len(c)
        self._meta(2, path)
        self._xfer(total, write=True)
        self._track_new_file(path, existed)
        return total

    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            data = f.read()
        self._meta(2, path)
        self._xfer(len(data), write=False)
        return data

    def read_range(self, path: str, offset: int, nbytes: int) -> bytes:
        """Positioned read (the pack-file read path): open + seek + read of
        ``nbytes``. Charged like :meth:`read_bytes` of the range — the seek
        itself is free; only the bytes actually transferred cost time."""
        with open(path, "rb") as f:
            f.seek(offset)
            data = f.read(nbytes)
        if len(data) != nbytes:
            raise IOError(
                f"short read: wanted [{offset}:{offset + nbytes}) of {path}"
            )
        self._meta(2, path)
        self._xfer(nbytes, write=False)
        return data

    def append_text(self, path: str, text: str) -> None:
        existed = os.path.exists(path)
        self._ensure_parent(path)
        with open(path, "a") as f:
            f.write(text)
        self._meta(2, path)
        self._xfer(len(text), write=True)
        self._track_new_file(path, existed)

    def unlink(self, path: str) -> None:
        self._meta(1, path)
        if os.path.exists(path):
            os.unlink(path)
            with self._stats_lock:
                self.n_files = max(0, self.n_files - 1)
                d = self._dir_of(path)
                self._dir_entries[d] = max(0, self._dir_entries.get(d, 0) - 1)

    def rename(self, src: str, dst: str) -> None:
        self._meta(1, src)
        self._meta(1, dst)
        self._ensure_parent(dst)
        existed = os.path.exists(dst)
        os.replace(src, dst)
        self._bump_dir(self._dir_of(src), -1)
        if not existed:
            self._bump_dir(self._dir_of(dst), +1)
        else:
            # two files collapsed into one: the footprint shrank
            with self._stats_lock:
                self.n_files = max(0, self.n_files - 1)

    def copy_file(self, src: str, dst: str) -> int:
        """Deep copy (used by --alt-dir staging). Returns bytes copied."""
        existed = os.path.exists(dst)
        self._ensure_parent(dst)
        shutil.copy2(src, dst)
        n = os.stat(dst).st_size
        self._meta(2, src)
        self._meta(2, dst)
        self._xfer(n, write=False)
        self._xfer(n, write=True)
        self._track_new_file(dst, existed)
        return n

    def chmod_readonly(self, path: str, readonly: bool = True) -> None:
        self._meta(1, path)
        mode = 0o444 if readonly else 0o644
        os.chmod(path, mode)
