"""repro.core — the paper's contribution as a library.

Data version management (git/git-annex model), machine-actionable
reproducibility records (datalad run/rerun model), and the Slurm scheduling
protocol (datalad slurm-schedule/finish/reschedule) that makes both
HPC-compatible. See DESIGN.md for the mapping.
"""
from .annex import AnnexStore, make_pointer, parse_pointer
from .conflicts import (
    OutputConflict,
    ProtectedOutputs,
    WildcardOutputError,
    normalize,
    proper_prefixes,
)
from .dag import Pipeline, PipelineError, PipelineWarning
from .faults import (
    CrashInjected,
    FaultPlan,
    FaultRule,
    InjectedIOError,
    InjectedNetworkError,
    InjectedSlurmError,
    RemoteUnavailable,
    is_crash,
)
from .fsio import FS, GPFS, LOCAL_XFS, NULL_FS, FSProfile, SimClock
from .hashing import annex_key_for_bytes, annex_key_for_file, verify_annex_key
from .jobdb import JobDB, job_spec
from .records import RunFailed, RunRecord, rerun, run, run_spec, spec_of
from .remote import (
    LAN,
    WAN,
    NetFaultRule,
    NetProfile,
    NetworkFaultModel,
    RemoteStore,
)
from .recovery import FileLock, JournalHandle, LockHeld
from .repo import ConflictError, Repository
from .scheduler import FinishResult, ScheduleError, SlurmScheduler
from .session import Session, open
from .slurm import LocalSlurmCluster, SlurmCluster, SubprocessSlurmCluster
from .spec import RunSpec, SpecError

__all__ = [
    "AnnexStore", "make_pointer", "parse_pointer",
    "Pipeline", "PipelineError", "PipelineWarning",
    "OutputConflict", "ProtectedOutputs", "WildcardOutputError",
    "normalize", "proper_prefixes",
    "CrashInjected", "FaultPlan", "FaultRule",
    "InjectedIOError", "InjectedNetworkError", "InjectedSlurmError",
    "RemoteUnavailable", "is_crash",
    "FS", "GPFS", "LOCAL_XFS", "NULL_FS", "FSProfile", "SimClock",
    "annex_key_for_bytes", "annex_key_for_file", "verify_annex_key",
    "JobDB", "job_spec",
    "RunFailed", "RunRecord", "rerun", "run", "run_spec", "spec_of",
    "LAN", "WAN", "NetFaultRule", "NetProfile", "NetworkFaultModel",
    "RemoteStore",
    "FileLock", "JournalHandle", "LockHeld",
    "ConflictError", "Repository",
    "FinishResult", "ScheduleError", "SlurmScheduler",
    # "open" stays importable explicitly but is NOT star-exported: a
    # wildcard import must not shadow the builtin. Prefer repro.open(...).
    "Session",
    "LocalSlurmCluster", "SlurmCluster", "SubprocessSlurmCluster",
    "RunSpec", "SpecError",
]
