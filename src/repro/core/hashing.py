"""Content hashing and annex key handling.

Annex keys follow the git-annex SHA256E-style convention used by the paper:
``SHA256-s<size>--<hex>``. The key alone is sufficient to verify content,
which is what makes ``rerun``'s bitwise verification possible without the
original outputs (paper §3 step 8).
"""
from __future__ import annotations

import hashlib
import re

_CHUNK = 1 << 20

ANNEX_KEY_RE = re.compile(r"^SHA256-s(?P<size>\d+)--(?P<hex>[0-9a-f]{64})$")

# Chunk tier (DESIGN.md §12): sub-file pieces of a chunked object use their
# own key namespace — SHA256C — so store sweeps / gc can tell data chunks
# from whole-content objects without reading them. Verification is
# identical: the key alone binds size + content.
CHUNK_KEY_RE = re.compile(r"^SHA256C-s(?P<size>\d+)--(?P<hex>[0-9a-f]{64})$")
_ANY_KEY_RE = re.compile(r"^SHA256C?-s(?P<size>\d+)--(?P<hex>[0-9a-f]{64})$")


def sha256_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def sha256_file(path: str, fs=None) -> tuple[str, int]:
    """Return (hex digest, size) streaming the file in 1 MiB chunks.

    When ``fs`` (a :class:`~repro.core.fsio.FS`) is given, the read pass is
    routed through it so hashed bytes are charged to the cost model like any
    other data-plane read — hashing a file is not free on a parallel FS.
    The raw-path variant (``fs=None``) exists only for callers with no FS
    context (e.g. hashing files outside any repository)."""
    if fs is not None:
        return fs.hash_file(path, _CHUNK)
    h = hashlib.sha256()
    size = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(_CHUNK)
            if not chunk:
                break
            size += len(chunk)
            h.update(chunk)
    return h.hexdigest(), size


def annex_key_for_bytes(data: bytes) -> str:
    return f"SHA256-s{len(data)}--{sha256_bytes(data)}"


def make_annex_key(hx: str, size: int) -> str:
    return f"SHA256-s{size}--{hx}"


def annex_key_for_file(path: str, fs=None) -> str:
    hx, size = sha256_file(path, fs=fs)
    return make_annex_key(hx, size)


def make_chunk_key(hx: str, size: int) -> str:
    return f"SHA256C-s{size}--{hx}"


def chunk_key_for_bytes(data: bytes) -> str:
    return make_chunk_key(sha256_bytes(data), len(data))


def is_chunk_key(key: str) -> bool:
    return key.startswith("SHA256C-")


def parse_annex_key(key: str) -> tuple[int, str]:
    """Return (size, hex) or raise ValueError. Accepts both whole-content
    (``SHA256-``) and chunk-tier (``SHA256C-``) keys — they share storage
    layout and verification."""
    m = _ANY_KEY_RE.match(key)
    if not m:
        raise ValueError(f"not a valid annex key: {key!r}")
    return int(m.group("size")), m.group("hex")


def verify_annex_key(key: str, data: bytes) -> bool:
    size, hx = parse_annex_key(key)
    return size == len(data) and sha256_bytes(data) == hx
