"""Content hashing and annex key handling.

Annex keys follow the git-annex SHA256E-style convention used by the paper:
``SHA256-s<size>--<hex>``. The key alone is sufficient to verify content,
which is what makes ``rerun``'s bitwise verification possible without the
original outputs (paper §3 step 8).
"""
from __future__ import annotations

import hashlib
import re

_CHUNK = 1 << 20

ANNEX_KEY_RE = re.compile(r"^SHA256-s(?P<size>\d+)--(?P<hex>[0-9a-f]{64})$")


def sha256_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def sha256_file(path: str) -> tuple[str, int]:
    """Return (hex digest, size) streaming the file in 1 MiB chunks."""
    h = hashlib.sha256()
    size = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(_CHUNK)
            if not chunk:
                break
            size += len(chunk)
            h.update(chunk)
    return h.hexdigest(), size


def annex_key_for_bytes(data: bytes) -> str:
    return f"SHA256-s{len(data)}--{sha256_bytes(data)}"


def annex_key_for_file(path: str) -> str:
    hx, size = sha256_file(path)
    return f"SHA256-s{size}--{hx}"


def parse_annex_key(key: str) -> tuple[int, str]:
    """Return (size, hex) or raise ValueError."""
    m = ANNEX_KEY_RE.match(key)
    if not m:
        raise ValueError(f"not a valid annex key: {key!r}")
    return int(m.group("size")), m.group("hex")


def verify_annex_key(key: str, data: bytes) -> bool:
    size, hx = parse_annex_key(key)
    return size == len(data) and sha256_bytes(data) == hx
