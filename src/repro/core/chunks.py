"""Content-defined chunking for the annex data plane (DESIGN.md §12).

Large annex objects are cut into *chunks* at boundaries chosen by the
content itself, so an insertion or a localized overwrite only changes the
chunks it touches — every other chunk keeps its byte string, hence its
content address, hence its storage. This is what makes step N+1 of a
checkpoint campaign ingest only its delta.

Boundary rule
-------------
The cutter slides an 8-byte window over the stream. At position ``i`` the
window value is the little-endian integer of ``bytes[i-7..i]`` (zero-padded
at stream start), mixed by a 64-bit multiplicative hash:

    H_i = sum_{k=0..7} b[i-k] << 8k          (== (H_{i-1} << 8 | b[i]) mod 2^64)
    G_i = (H_i * 0x9E3779B97F4A7C15) mod 2^64

Position ``i`` is a *candidate* boundary iff the top ``avg_bits`` bits of
``G_i`` are all ones — probability 2^-avg_bits per position, so candidate
gaps are geometric with mean 2^avg_bits. Requiring the all-ones residue
(not zero) means runs of constant bytes — zero pages in checkpoints —
produce *no* candidates and fall through to the fixed-size ``max_size``
fallback, instead of degenerating into a boundary at every offset.

Cut selection is greedy: the first candidate at least ``min_size`` bytes
after the previous cut wins; if none appears within ``max_size`` bytes the
cutter forces a fixed-size cut there (the fallback also bounds manifest
size and reassembly memory). Boundaries are a pure function of stream
content — independent of how the stream is split into ``feed()`` blocks —
which the tests assert by re-feeding the same bytes in random block sizes.

The hot path is vectorized with numpy (8 shift-adds + 1 multiply + 1
compare per byte, no gathers); a bit-identical pure-Python fallback keeps
the module importable without numpy.
"""
from __future__ import annotations

from dataclasses import dataclass

try:  # vectorized candidate scan; fallback is bit-identical
    import numpy as _np
except Exception:  # pragma: no cover - numpy is present in the toolchain
    _np = None

_WINDOW = 8
_MIX = 0x9E3779B97F4A7C15
_M64 = (1 << 64) - 1

DEFAULT_MIN_SIZE = 1 << 16   # 64 KiB
DEFAULT_AVG_BITS = 17        # mean candidate gap 128 KiB
DEFAULT_MAX_SIZE = 1 << 20   # 1 MiB fixed-size fallback


@dataclass(frozen=True)
class ChunkParams:
    """Cutter parameters. Part of a store's configuration — two stores
    exchanging *manifests* need not agree on them (chunk keys are content
    addresses regardless of who cut them), but deterministic dedup across
    sessions of one repository requires the repo-wide values persisted in
    ``config.json``."""

    min_size: int = DEFAULT_MIN_SIZE
    avg_bits: int = DEFAULT_AVG_BITS
    max_size: int = DEFAULT_MAX_SIZE

    def __post_init__(self):
        if not (0 < self.min_size <= self.max_size):
            raise ValueError(
                f"need 0 < min_size <= max_size, got {self.min_size}/{self.max_size}"
            )
        if not (1 <= self.avg_bits <= 48):
            raise ValueError(f"avg_bits out of range: {self.avg_bits}")

    def to_json(self) -> dict:
        return {
            "min_size": self.min_size,
            "avg_bits": self.avg_bits,
            "max_size": self.max_size,
        }

    @classmethod
    def from_json(cls, d: dict) -> "ChunkParams":
        return cls(
            min_size=int(d["min_size"]),
            avg_bits=int(d["avg_bits"]),
            max_size=int(d["max_size"]),
        )


def _candidates_numpy(data: bytes, bits: int) -> list[int]:
    s = _np.frombuffer(data, dtype=_np.uint8).astype(_np.uint64)
    h = s.copy()
    for k in range(1, _WINDOW):
        h[k:] += s[:-k] << _np.uint64(8 * k)
    g = h * _np.uint64(_MIX)
    mask = _np.uint64(((1 << bits) - 1) << (64 - bits))
    return _np.nonzero((g & mask) == mask)[0].tolist()


def _candidates_python(data: bytes, bits: int, h: int = 0) -> list[int]:
    out = []
    target = (1 << bits) - 1
    shift = 64 - bits
    for i, b in enumerate(data):
        h = ((h << 8) | b) & _M64
        if ((h * _MIX) & _M64) >> shift == target:
            out.append(i)
    return out


class Cutter:
    """Streaming re-segmenter: ``feed()`` arbitrary byte blocks, receive
    content-defined chunks; ``finish()`` flushes the tail (possibly shorter
    than ``min_size``). Memory is bounded by ``max_size`` plus one block."""

    def __init__(self, params: ChunkParams | None = None):
        self.params = params or ChunkParams()
        self._pending = bytearray()   # stream bytes not yet emitted
        self._emitted = 0             # absolute offset of _pending[0]
        self._fed = 0                 # absolute offset of next byte to feed
        self._carry = b""             # last _WINDOW-1 stream bytes (window context)
        self._cands: list[int] = []   # absolute cut offsets (prefix lengths), ascending
        self._ci = 0                  # consumed prefix of _cands

    def _scan(self, block: bytes) -> None:
        """Append candidate cut offsets found in ``block`` (with window
        context carried across blocks so segmentation never shifts them)."""
        bits = self.params.avg_bits
        if self._ci > 1024:  # shed the consumed prefix on long streams
            del self._cands[: self._ci]
            self._ci = 0
        carry = self._carry
        buf = carry + block
        if _np is not None and len(block) >= 1024:
            idx = _candidates_numpy(buf, bits)
            # positions inside the carry were scanned by the previous call
            base = self._fed - len(carry)
            self._cands.extend(base + i + 1 for i in idx if i >= len(carry))
        else:
            h = 0
            for b in carry:  # rebuild window state, emit nothing
                h = ((h << 8) | b) & _M64
            idx = _candidates_python(block, bits, h)
            self._cands.extend(self._fed + i + 1 for i in idx)
        self._fed += len(block)
        self._carry = bytes(buf[-(_WINDOW - 1):])

    def _emit(self, final: bool = False) -> list[bytes]:
        p = self.params
        out: list[bytes] = []
        while True:
            start = self._emitted
            avail = len(self._pending)
            while self._ci < len(self._cands) and self._cands[self._ci] - start < p.min_size:
                self._ci += 1
            if self._ci == len(self._cands):  # keep the list bounded
                self._cands = []
                self._ci = 0
            cut = None
            if self._ci < len(self._cands) and self._cands[self._ci] - start <= p.max_size:
                cut = self._cands[self._ci]
            elif avail >= p.max_size:
                cut = start + p.max_size  # fixed-size fallback
            if cut is None or cut - start > avail:
                if final and avail:
                    out.append(bytes(self._pending))
                    self._pending.clear()
                    self._emitted = start + avail
                return out
            n = cut - start
            out.append(bytes(self._pending[:n]))
            del self._pending[:n]
            self._emitted = cut

    def feed(self, block: bytes) -> list[bytes]:
        if not isinstance(block, bytes):
            block = bytes(block)  # accept memoryview/bytearray blocks
        if not block:
            return []
        self._scan(block)
        self._pending.extend(block)
        return self._emit()

    def finish(self) -> list[bytes]:
        """Flush the tail chunk (if any). The cutter is exhausted after."""
        return self._emit(final=True)


def cut_bytes(data: bytes, params: ChunkParams | None = None) -> list[bytes]:
    """Convenience one-shot cut: all chunks of ``data`` in order."""
    c = Cutter(params)
    out = c.feed(bytes(data))
    out.extend(c.finish())
    return out
