"""Pipeline DAG model: chained RunSpecs with inferred stage edges.

A :class:`Pipeline` is an ordered set of named stages, each a
:class:`~repro.core.spec.RunSpec`.  Edges are *inferred*, never declared:
stage B depends on stage A iff one of B's declared inputs overlaps one of
A's declared outputs.  Overlap follows the same path semantics as the §5.5
output-conflict checks (`conflicts.normalize`): equal paths, an input
nested under an output directory, an output nested under an input
directory, or a wildcard input whose pattern can match the output (or
files inside an output directory).

Construction validates the whole DAG eagerly:

* duplicate stage names and non-dict/list shapes are rejected;
* two stages claiming the same or nested outputs is an *ambiguous
  producer* (the same condition jobdb's §5.5 check would reject at
  submission — we fail fast here, before anything is queued);
* a stage consuming its own output is a self-cycle;
* any directed cycle among stages raises, naming the stages involved.

Per-stage resource overrides (``resources={"train": {"time_limit_s":
3600, "array_n": 4}}``) are applied via ``RunSpec.replace`` at
construction so the scheduler sees ordinary specs; only scheduling
fields may be overridden, not the input/output contract.

The scheduler (`SlurmScheduler.submit_pipeline`) consumes
:meth:`Pipeline.levels` — topological batches, one ``submit_many`` call
per level — and :attr:`Pipeline.parents` to wire ``afterok`` edges.
See DESIGN.md §14.
"""
from __future__ import annotations

import fnmatch
import hashlib
import json
import warnings
from typing import Iterable, Mapping

from .conflicts import ProtectedOutputs, OutputConflict, has_wildcard, normalize
from .spec import RunSpec, SpecError

__all__ = ["Pipeline", "PipelineError", "PipelineWarning"]

# RunSpec fields a per-stage resource override may touch.  The data
# contract (inputs/outputs/script) is identity — overriding it would
# silently change edge inference and the spec_id provenance trail.
_OVERRIDABLE = frozenset({"time_limit_s", "array_n", "env", "alt_dir", "message"})


class PipelineError(SpecError):
    """Invalid pipeline: bad shape, ambiguous producers, or cycles."""


class PipelineWarning(UserWarning):
    """Suspicious but not fatal pipeline shape (e.g. a root-level wildcard
    input that no declared stage output anchors)."""


def _static_dir(pattern: str) -> str:
    """Directory prefix of a wildcard pattern before its first wildcard.

    ``data/prep/*.npy`` -> ``data/prep``; ``*.bin`` -> ``""``.
    """
    idx = min(i for i, ch in enumerate(pattern) if ch in "*?[]{}")
    return pattern[:idx].rpartition("/")[0]


def _overlaps(inp: str, out: str) -> bool:
    """Does input path/pattern `inp` overlap declared output `out`?

    `out` is already normalized (RunSpec guarantees it); `inp` may be a
    wildcard pattern or a literal path.
    """
    if has_wildcard(inp):
        # fnmatch's `*` crosses `/`, so `data/*` matches `data/a/b.npy`;
        # additionally a pattern rooted inside an output *directory*
        # (`prep/out/*.npy` vs. output `prep/out`) overlaps it.
        if fnmatch.fnmatch(out, inp):
            return True
        static = _static_dir(inp)
        return bool(static) and (static == out or static.startswith(out + "/"))
    n = normalize(inp)
    return n == out or n.startswith(out + "/") or out.startswith(n + "/")


class Pipeline:
    """A DAG of named RunSpec stages with inferred dependency edges."""

    def __init__(
        self,
        stages: Mapping[str, RunSpec] | Iterable[RunSpec | tuple[str, RunSpec]],
        resources: Mapping[str, Mapping] | None = None,
    ) -> None:
        self.stages: dict[str, RunSpec] = self._name_stages(stages)
        self._apply_resources(resources or {})
        self.produced_by = self._check_producers()
        self.parents: dict[str, set[str]] = {n: set() for n in self.stages}
        self.children: dict[str, set[str]] = {n: set() for n in self.stages}
        self._infer_edges()
        self._levels = self._toposort()

    # -- construction ------------------------------------------------------

    @staticmethod
    def _name_stages(stages) -> dict[str, RunSpec]:
        named: dict[str, RunSpec] = {}
        if isinstance(stages, Mapping):
            items = list(stages.items())
        else:
            items = []
            for i, entry in enumerate(stages):
                if isinstance(entry, RunSpec):
                    items.append((f"stage{i}", entry))
                else:
                    items.append(tuple(entry))
        if not items:
            raise PipelineError("pipeline has no stages")
        for name, spec in items:
            if not isinstance(name, str) or not name:
                raise PipelineError(f"invalid stage name: {name!r}")
            if not isinstance(spec, RunSpec):
                raise PipelineError(f"stage {name!r} is not a RunSpec")
            if name in named:
                raise PipelineError(f"duplicate stage name: {name!r}")
            if not spec.script:
                raise PipelineError(
                    f"stage {name!r}: pipeline stages must be script specs"
                )
            named[name] = spec
        return named

    def _apply_resources(self, resources: Mapping[str, Mapping]) -> None:
        for name, overrides in resources.items():
            if name not in self.stages:
                raise PipelineError(f"resource override for unknown stage {name!r}")
            bad = set(overrides) - _OVERRIDABLE
            if bad:
                raise PipelineError(
                    f"stage {name!r}: non-resource override(s) {sorted(bad)}; "
                    f"allowed: {sorted(_OVERRIDABLE)}"
                )
            self.stages[name] = self.stages[name].replace(**dict(overrides))

    def _check_producers(self) -> dict[str, str]:
        """Map normalized output -> producing stage; reject ambiguity.

        Two stages with equal or nested outputs would race on the same
        paths (and be rejected by the jobdb §5.5 check at submission);
        inside one pipeline that is an ambiguous producer — edge
        inference could not say which stage an input chains from.
        """
        guard = ProtectedOutputs()
        produced: dict[str, str] = {}
        for idx, (name, spec) in enumerate(self.stages.items()):
            try:
                guard.check_and_add_all(list(spec.outputs), idx)
            except OutputConflict as e:
                raise PipelineError(
                    f"ambiguous producer: stage {name!r} outputs collide with "
                    f"an earlier stage ({e})"
                ) from e
            for out in spec.outputs:
                produced[out] = name
        return produced

    def _infer_edges(self) -> None:
        for name, spec in self.stages.items():
            for inp in spec.inputs:
                matched = False
                for out, producer in self.produced_by.items():
                    if not _overlaps(inp, out):
                        continue
                    if producer == name:
                        raise PipelineError(
                            f"stage {name!r} consumes its own output {out!r}"
                        )
                    matched = True
                    self.parents[name].add(producer)
                    self.children[producer].add(name)
                # a root-level wildcard (`*.npy`) has no static directory to
                # anchor against a producer's *directory* output (`prep`),
                # so edge inference cannot see through it: since wildcard
                # inputs are never reported missing either, the stage would
                # silently submit with no afterok edge and could run before
                # its intended producer. We cannot soundly infer the edge
                # (any output *might* be a directory — chaining on that
                # guess would fabricate cycles), so surface the hazard.
                if (
                    not matched and has_wildcard(inp) and not _static_dir(inp)
                    and any(p != name for p in self.produced_by.values())
                ):
                    warnings.warn(
                        f"stage {name!r}: root-level wildcard input {inp!r} "
                        "matches no declared stage output, so no dependency "
                        "edge was inferred; if it names files another stage "
                        "writes inside an output directory, anchor it under "
                        f"that directory (e.g. '<dir>/{inp}') or the stage "
                        "may run before its producer",
                        PipelineWarning,
                        stacklevel=3,
                    )

    def _toposort(self) -> list[list[str]]:
        """Kahn level batching; leftover nodes mean a cycle."""
        indeg = {n: len(ps) for n, ps in self.parents.items()}
        frontier = [n for n in self.stages if indeg[n] == 0]
        levels: list[list[str]] = []
        seen = 0
        while frontier:
            levels.append(frontier)
            seen += len(frontier)
            nxt: list[str] = []
            for n in frontier:
                for c in sorted(self.children[n]):
                    indeg[c] -= 1
                    if indeg[c] == 0:
                        nxt.append(c)
            frontier = nxt
        if seen != len(self.stages):
            cyclic = sorted(n for n in self.stages if indeg[n] > 0)
            raise PipelineError(f"cycle among stages: {cyclic}")
        return levels

    # -- queries -----------------------------------------------------------

    def levels(self) -> list[list[str]]:
        """Topological batches: every stage's parents are in earlier levels."""
        return [list(level) for level in self._levels]

    def edges(self) -> list[tuple[str, str]]:
        """Sorted (parent, child) pairs."""
        return sorted(
            (p, c) for c, ps in self.parents.items() for p in ps
        )

    def roots(self) -> list[str]:
        return [n for n, ps in self.parents.items() if not ps]

    def upstream_outputs(self, name: str) -> set[str]:
        """All declared outputs of `name`'s ancestors (transitive).

        These are the paths ``RunSpec.missing_inputs`` must treat as
        satisfied at submission time: they do not exist on disk yet but
        will by the time the stage's `afterok` dependency releases it.
        """
        outs: set[str] = set()
        frontier = list(self.parents[name])
        seen: set[str] = set()
        while frontier:
            p = frontier.pop()
            if p in seen:
                continue
            seen.add(p)
            outs.update(self.stages[p].outputs)
            frontier.extend(self.parents[p])
        return outs

    def downstream_cone(self, name: str) -> list[str]:
        """`name` plus every transitive descendant, in level order."""
        cone = {name}
        for level in self._levels:
            for n in level:
                if n in cone:
                    continue
                if self.parents[n] & cone:
                    cone.add(n)
        return [n for level in self._levels for n in level if n in cone]

    @property
    def pipeline_id(self) -> str:
        """Content address of the DAG: stage spec_ids plus edges."""
        payload = {
            "stages": {n: s.spec_id for n, s in self.stages.items()},
            "edges": self.edges(),
        }
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def __len__(self) -> int:
        return len(self.stages)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Pipeline({len(self.stages)} stages, "
            f"{len(self.edges())} edges, {len(self._levels)} levels)"
        )
