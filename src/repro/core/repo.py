"""Repository: working tree + object store + annex + branches.

This is git/git-annex/DataLad rebuilt as an in-process library (see DESIGN.md
§2 for why): ``save`` = stage+commit, ``checkout`` materializes a commit,
``merge_octopus`` is the N-parent merge of paper §5.8, annex get/drop/whereis
follow §2.3/§2.6. Every filesystem touch goes through :class:`FS` so the
parallel-FS cost model applies to the entire stack.

Committing is *incremental* (DESIGN.md §4): ``save`` diffs the staged paths
against the base commit and rebuilds only the O(changed x depth) dirty spine
of the tree, reusing unchanged subtree oids verbatim — no re-read, no
re-hash, no ``exists`` probe for untouched subtrees. ``save(engine="full")``
keeps the seed-era full rebuild for equivalence testing and benchmarking;
both engines produce byte-identical tree oids for the same content.
"""
from __future__ import annotations

import errno
import fnmatch
import json
import os
import threading
import time
import uuid

from .annex import (
    _POINTER_MAX,
    AnnexStore,
    make_pointer,
    parse_pointer,
    parse_pointer_full,
)
from .chunks import ChunkParams
from .conflicts import proper_prefixes
from .fsio import FS, NULL_FS, FSProfile, SimClock
from .hashing import annex_key_for_bytes, make_annex_key
from .objects import ObjectStore, canonical_json
from .recovery import LOCKS_DIR, FileLock
from .remote import NetworkFaultModel, RemoteStore, coerce_net, net_retry

REPRO_DIR = ".repro"
DEFAULT_ANNEX_THRESHOLD = 64 * 1024  # bytes; files >= this are annexed


class ConflictError(Exception):
    pass


class Repository:
    def __init__(self, root: str, fs: FS | None = None,
                 net_faults: NetworkFaultModel | None = None):
        self.root = os.path.abspath(root)
        self.repro_dir = os.path.join(self.root, REPRO_DIR)
        cfg_path = os.path.join(self.repro_dir, "config.json")
        if not os.path.exists(cfg_path):
            raise FileNotFoundError(f"not a repro repository: {root}")
        self.fs = fs or FS(NULL_FS)
        self.net_faults = net_faults
        # serializes ref read-modify-publish sequences across threads
        # sharing this Repository (concurrent finish batches, §9); an RLock
        # because merge_octopus publishes from inside a holder's section.
        # In-process only — cross-process ref races are out of scope, like
        # the jobdb's (sqlite handles those).
        self.ref_lock = threading.RLock()
        self.config = json.loads(self.fs.read_bytes(cfg_path))
        self.objects = ObjectStore(os.path.join(self.repro_dir, "objects"), self.fs)
        # chunk-tier config (DESIGN §12) is repo-wide and persisted, so every
        # session — and every store of this repo, remotes included — agrees
        # on cutter parameters and on whether manifests may exist at all
        cp = self.config.get("chunk_params")
        self._chunk_params = ChunkParams.from_json(cp) if cp else None
        self._chunk_threshold = self.config.get("chunk_threshold")
        store_kw = dict(
            chunk_params=self._chunk_params, chunk_threshold=self._chunk_threshold
        )
        self.annex = AnnexStore(
            os.path.join(self.repro_dir, "annex", "objects"), self.fs, **store_kw
        )
        self._remotes: list[AnnexStore] = [
            AnnexStore(p, self.fs, name=f"remote{i}", **store_kw)
            for i, p in enumerate(self.config.get("annex_remotes", []))
        ]
        # network remote tier (DESIGN §13): simulated sites on their own
        # charged link, sharing this repo's clock and fault plan (a client
        # crash kills its connections too). Opening each store sweeps the
        # owner-stamped transfer tmps a crashed push left behind.
        self._remotes.extend(
            RemoteStore(
                r["root"], clock=self.fs.clock, name=r["name"],
                net=r.get("net"), fault_model=net_faults,
                faults=self.fs.faults, **store_kw,
            )
            for r in self.config.get("remotes", [])
        )

    # ------------------------------------------------------------------
    @classmethod
    def init(
        cls,
        root: str,
        profile: FSProfile = NULL_FS,
        clock: SimClock | None = None,
        annex_threshold: int = DEFAULT_ANNEX_THRESHOLD,
        annex_patterns: tuple[str, ...] = (),
        dsid: str | None = None,
        faults=None,
        chunk_threshold: int | None = None,
        chunk_params: "ChunkParams | dict | None" = None,
        numcopies: int = 1,
        net_faults: NetworkFaultModel | None = None,
    ) -> "Repository":
        fs = FS(profile, clock, faults=faults)
        root = os.path.abspath(root)
        repro_dir = os.path.join(root, REPRO_DIR)
        os.makedirs(os.path.join(repro_dir, "objects"), exist_ok=True)
        os.makedirs(os.path.join(repro_dir, "refs", "heads"), exist_ok=True)
        os.makedirs(os.path.join(repro_dir, "annex", "objects"), exist_ok=True)
        if isinstance(chunk_params, dict):
            chunk_params = ChunkParams.from_json(chunk_params)
        if chunk_threshold is not None and chunk_params is None:
            chunk_params = ChunkParams()  # chunking on with default cutter
        cfg = {
            "dsid": dsid or str(uuid.uuid4()),
            "annex_threshold": annex_threshold,
            "annex_patterns": list(annex_patterns),
            "annex_remotes": [],
            "remotes": [],
            "numcopies": numcopies,
            "chunk_threshold": chunk_threshold,
            "chunk_params": chunk_params.to_json() if chunk_params else None,
        }
        fs.write_bytes(os.path.join(repro_dir, "config.json"), json.dumps(cfg).encode())
        fs.write_bytes(os.path.join(repro_dir, "HEAD"), b"main")
        return cls(root, fs, net_faults=net_faults)

    @classmethod
    def clone(cls, src: "Repository", dst_root: str, fs: FS | None = None) -> "Repository":
        """Clone metadata + objects; annexed content stays behind (paper §2.3:
        'after cloning ... the annexed files are known but their content is
        not present'). The source's annex store is registered as a remote so
        ``annex_get`` can fetch on demand."""
        dst_root = os.path.abspath(dst_root)
        repo = cls.init(
            dst_root,
            annex_threshold=src.config["annex_threshold"],
            annex_patterns=tuple(src.config.get("annex_patterns", ())),
            dsid=src.config["dsid"],
            chunk_threshold=src.config.get("chunk_threshold"),
            chunk_params=src.config.get("chunk_params"),
        )
        if fs is not None:
            repo.fs = fs
            repo.objects.fs = fs
            repo.annex.fs = fs
        # copy objects + refs
        for dirpath, _, files in os.walk(src.objects.root):
            rel = os.path.relpath(dirpath, src.objects.root)
            for f in files:
                repo.fs.copy_file(
                    os.path.join(dirpath, f), os.path.join(repo.objects.root, rel, f)
                )
        refs_src = os.path.join(src.repro_dir, "refs", "heads")
        for dirpath, _, files in os.walk(refs_src):
            for f in files:
                s = os.path.join(dirpath, f)
                rel = os.path.relpath(s, refs_src)
                repo.fs.copy_file(
                    s, os.path.join(repo.repro_dir, "refs", "heads", rel)
                )
        repo.fs.copy_file(
            os.path.join(src.repro_dir, "HEAD"), os.path.join(repo.repro_dir, "HEAD")
        )
        repo.add_annex_remote(src.annex.root)
        # a clone knows the campaign's sites: carry the remote tier and the
        # retention policy over, rebuilt against the clone's own clock/fs
        repo.config["numcopies"] = src.config.get("numcopies", 1)
        repo._save_config()
        for r in src.config.get("remotes", []):
            repo.add_remote(r["root"], name=r["name"], net=r.get("net"))
        head = repo.head_commit()
        if head:
            repo.checkout(head)
        return repo

    # ------------------------------------------------------------------
    @property
    def dsid(self) -> str:
        return self.config["dsid"]

    def _save_config(self) -> None:
        self.fs.write_bytes(
            os.path.join(self.repro_dir, "config.json"), json.dumps(self.config).encode()
        )

    def add_annex_remote(self, store_root: str) -> None:
        store_root = os.path.abspath(store_root)
        if store_root not in self.config["annex_remotes"]:
            self.config["annex_remotes"].append(store_root)
            self._save_config()
            self._remotes.append(
                AnnexStore(
                    store_root,
                    self.fs,
                    name=f"remote{len(self._remotes)}",
                    chunk_params=self._chunk_params,
                    chunk_threshold=self._chunk_threshold,
                )
            )

    def add_remote(self, store_root: str, name: str | None = None,
                   net=None) -> RemoteStore:
        """Register a network remote (DESIGN §13): an annex store reached
        over a :class:`~repro.core.remote.NetProfile` link ('lan', 'wan', a
        profile dict, or a NetProfile). Persisted in the config so every
        later session — and every clone — rebuilds the same site list."""
        store_root = os.path.abspath(store_root)
        net = coerce_net(net)
        existing = {r["name"] for r in self.config.setdefault("remotes", [])}
        if name is None:
            i = len(existing)
            while f"site{i}" in existing:
                i += 1
            name = f"site{i}"
        elif name in existing:
            raise ValueError(f"remote {name!r} already configured")
        self.config["remotes"].append(
            {"name": name, "root": store_root, "net": net.to_json()}
        )
        self._save_config()
        store = RemoteStore(
            store_root, clock=self.fs.clock, name=name, net=net,
            fault_model=self.net_faults, faults=self.fs.faults,
            chunk_params=self._chunk_params,
            chunk_threshold=self._chunk_threshold,
        )
        self._remotes.append(store)
        return store

    def remote_by_name(self, name: str) -> AnnexStore:
        for s in self._remotes:
            if s.name == name:
                return s
        raise KeyError(f"no remote named {name!r}")

    @property
    def remote_stores(self) -> list[RemoteStore]:
        """The network remotes only (the legacy co-located stores are
        plain AnnexStores and never fault)."""
        return [s for s in self._remotes if isinstance(s, RemoteStore)]

    @property
    def numcopies(self) -> int:
        """Retention policy: how many *verified* replicas must exist
        elsewhere before the local copy may be dropped."""
        return int(self.config.get("numcopies", 1))

    def file_lock(self, name: str, ttl_s: float = 600.0) -> FileLock:
        """Cross-process advisory lock under ``.repro/locks/`` (DESIGN §10).
        Stale (dead-owner) locks are broken automatically on acquire, so a
        crashed holder can never wedge the repository."""
        return FileLock(
            self.fs,
            os.path.join(self.repro_dir, LOCKS_DIR, f"{name}.lock"),
            ttl_s=ttl_s,
        )

    # -- refs ----------------------------------------------------------
    def _ref_path(self, branch: str) -> str:
        return os.path.join(self.repro_dir, "refs", "heads", branch)

    def current_branch(self) -> str:
        return self.fs.read_bytes(os.path.join(self.repro_dir, "HEAD")).decode().strip()

    def branches(self) -> list[str]:
        d = os.path.join(self.repro_dir, "refs", "heads")
        out = []
        for dirpath, _, files in os.walk(d):
            for f in files:
                out.append(os.path.relpath(os.path.join(dirpath, f), d))
        return sorted(out)

    def branch_head(self, branch: str) -> str | None:
        p = self._ref_path(branch)
        if not self.fs.exists(p):
            return None
        return self.fs.read_bytes(p).decode().strip()

    def head_commit(self) -> str | None:
        return self.branch_head(self.current_branch())

    def set_branch(self, branch: str, oid: str) -> None:
        self.fs.write_bytes(self._ref_path(branch), oid.encode())

    def create_branch(self, branch: str, at: str | None = None) -> None:
        at = at or self.head_commit()
        if at is None:
            raise ValueError("cannot branch from an empty repository")
        if self.fs.exists(self._ref_path(branch)):
            raise ValueError(f"branch exists: {branch}")
        self.set_branch(branch, at)

    def switch(self, branch: str, checkout: bool = True) -> None:
        if not self.fs.exists(self._ref_path(branch)):
            raise ValueError(f"no such branch: {branch}")
        self.fs.write_bytes(os.path.join(self.repro_dir, "HEAD"), branch.encode())
        if checkout:
            head = self.head_commit()
            if head:
                self.checkout(head)

    def resolve(self, commitish: str) -> str:
        """Branch name, full oid, or unique oid prefix -> full oid."""
        if self.fs.exists(self._ref_path(commitish)):
            return self.branch_head(commitish)  # type: ignore[return-value]
        if self.objects.has(commitish):
            return commitish
        # prefix search over BOTH tiers: the pack index (in-memory) and the
        # loose shard (one charged listdir) — see ObjectStore.find_prefix
        matches = []
        if len(commitish) >= 4:
            matches = self.objects.find_prefix(commitish)
        if len(matches) == 1:
            return matches[0]
        raise ValueError(f"cannot resolve {commitish!r} ({len(matches)} matches)")

    # -- trees -----------------------------------------------------------
    def tree_of(self, commit_oid: str) -> dict[str, dict]:
        """Flat {relpath: entry} map for a commit (entries: blob|annex)."""
        commit = self.objects.get_commit(commit_oid)
        flat: dict[str, dict] = {}

        def walk(tree_oid: str, prefix: str) -> None:
            for name, entry in self.objects.get_tree(tree_oid).items():
                p = f"{prefix}{name}"
                if entry["t"] == "tree":
                    walk(entry["oid"], p + "/")
                else:
                    flat[p] = entry

        if commit["tree"]:
            walk(commit["tree"], "")
        return flat

    def _write_nested(self, flat: dict[str, dict]) -> str | None:
        """Build hierarchical tree objects from a flat path map."""
        if not flat:
            return None
        root: dict = {}
        for path, entry in flat.items():
            parts = path.split("/")
            node = root
            for part in parts[:-1]:
                node = node.setdefault(part, {})
                if not isinstance(node, dict) or "t" in node:
                    raise ConflictError(f"file/directory conflict at {part} in {path}")
            node[parts[-1]] = {"_entry": entry}

        def emit(node: dict) -> str:
            entries = {}
            for name, child in sorted(node.items()):
                if "_entry" in child:
                    entries[name] = child["_entry"]
                else:
                    entries[name] = {"t": "tree", "oid": emit(child)}
            return self.objects.put_tree(entries)

        return emit(root)

    def _tree_oid_of(self, commit_oid: str | None) -> str | None:
        if commit_oid is None:
            return None
        return self.objects.get_commit(commit_oid)["tree"] or None

    def _update_tree(
        self, base_tree_oid: str | None, changes: dict[str, dict | None]
    ) -> str | None:
        """Incrementally rebuild a tree: apply ``changes`` ({relpath: entry},
        None = delete) on top of ``base_tree_oid``, re-emitting only the dirty
        spine. Untouched sibling subtrees keep their oid verbatim — they are
        never read, re-hashed, or existence-probed. Returns the new tree oid
        (None for an empty tree). O(changed paths x depth)."""
        if not changes:
            return base_tree_oid
        entries = self.objects.get_tree(base_tree_oid) if base_tree_oid else {}
        direct: dict[str, dict | None] = {}
        groups: dict[str, dict[str, dict | None]] = {}
        for path, entry in changes.items():
            name, sep, rest = path.partition("/")
            if sep:
                groups.setdefault(name, {})[rest] = entry
            else:
                direct[name] = entry
        for name, sub in groups.items():
            if direct.get(name) is not None:
                if any(e is not None for e in sub.values()):
                    raise ConflictError(f"file/directory conflict at {name!r}")
                continue  # the direct file replaces the subtree; the group's
                # deletions of its former contents are implied
            existing = entries.get(name)
            sub_base = (
                existing["oid"] if existing and existing["t"] == "tree" else None
            )
            sub_oid = self._update_tree(sub_base, sub)
            if sub_oid is None:
                entries.pop(name, None)
            else:
                entries[name] = {"t": "tree", "oid": sub_oid}
        for name, entry in direct.items():
            if entry is None:
                if name not in groups:  # a group rebuilding here supersedes it
                    entries.pop(name, None)
            else:
                entries[name] = entry
        if not entries:
            return None
        return self.objects.put_tree(entries)

    def _diff_trees(
        self, a_oid: str | None, b_oid: str | None, prefix: str = ""
    ) -> dict[str, dict | None]:
        """Flat changes turning tree ``a`` into tree ``b``: {path: entry} for
        adds/modifications, {path: None} for deletions. Subtrees with equal
        oids are skipped without reading them — O(changed), not O(tree)."""
        if a_oid == b_oid:
            return {}
        a = self.objects.get_tree(a_oid) if a_oid else {}
        b = self.objects.get_tree(b_oid) if b_oid else {}
        out: dict[str, dict | None] = {}
        for name, be in b.items():
            ae = a.get(name)
            if ae == be:
                continue
            p = prefix + name
            a_sub = ae["oid"] if ae is not None and ae["t"] == "tree" else None
            if be["t"] == "tree":
                out.update(self._diff_trees(a_sub, be["oid"], p + "/"))
            else:
                out[p] = be
        for name, ae in a.items():
            if name in b:
                continue
            p = prefix + name
            if ae["t"] == "tree":
                out.update(self._diff_trees(ae["oid"], None, p + "/"))
            else:
                out[p] = None
        return out

    # -- staging/saving ----------------------------------------------------
    def _is_ignored(self, relpath: str) -> bool:
        return relpath == REPRO_DIR or relpath.startswith(REPRO_DIR + "/")

    def _should_annex(self, relpath: str, size: int) -> bool:
        if size >= self.config["annex_threshold"]:
            return True
        return any(
            fnmatch.fnmatch(relpath, pat) for pat in self.config.get("annex_patterns", ())
        )

    def _should_chunk(self, size: int) -> bool:
        """Chunk-tier routing (DESIGN §12): content at/above the configured
        ``chunk_threshold`` is stored as a chunk manifest. Off (None) unless
        a repo opted in at init — entries, pointers, and dedup accounting of
        non-chunked repositories are byte-identical to the legacy path."""
        return (
            self._chunk_threshold is not None
            and self._chunk_params is not None
            and size >= self._chunk_threshold
        )

    @staticmethod
    def _annex_entry(key: str, chunked: bool) -> dict:
        e = {"t": "annex", "key": key}
        if chunked:
            e["chunked"] = True
        return e

    def _entry_for_data(self, relpath: str, data: bytes) -> dict:
        """Tree entry for small in-memory content (pointer passthrough,
        annex-by-pattern, or blob)."""
        parsed = parse_pointer_full(data)
        if parsed is not None:  # pointer file: content not present, key known
            return self._annex_entry(*parsed)
        if self._should_annex(relpath, len(data)):
            key = annex_key_for_bytes(data)
            self.annex.put_bytes(key, data)  # chunk-routes above the threshold
            return self._annex_entry(key, self._should_chunk(len(data)))
        return {"t": "blob", "oid": self.objects.put_blob(data)}

    def _hash_working_file(self, relpath: str, single_pass: bool = True) -> dict:
        """Stage one worktree file into a tree entry.

        Default (``single_pass``): one stat decides the route — files at or
        below the pointer size are read whole (pointer detection needs the
        content), annex-eligible files go through the streamed
        ``AnnexStore.ingest_file`` (hash-while-write, memory bounded at one
        chunk, known-key dedup), the rest are read whole and stored as
        blobs. ``single_pass=False`` keeps the seed-era protocol — read the
        entire file into memory, then write — for the legacy data-plane
        benchmarks (see ``SlurmScheduler.finish(data_plane=...)``)."""
        abspath = os.path.join(self.root, relpath)
        if not single_pass:
            return self._entry_for_data(relpath, self.fs.read_bytes(abspath))
        size = self.fs.stat_size(abspath)
        if size > _POINTER_MAX and self._should_annex(relpath, size):
            chunked = self._should_chunk(size)
            return self._annex_entry(
                self.annex.ingest_file(abspath, chunked=chunked), chunked
            )
        return self._entry_for_data(relpath, self.fs.read_bytes(abspath))

    def hash_path_entry(self, relpath: str) -> dict:
        """The tree entry staging ``relpath`` would produce, computed
        READ-ONLY — no blob written, no annex object, no tmp churn. This is
        rerun's bitwise-verification path (paper §3 step 8): comparing N
        unchanged outputs must charge N read passes and nothing else."""
        abspath = os.path.join(self.root, relpath)
        size = self.fs.stat_size(abspath)
        if size > _POINTER_MAX and self._should_annex(relpath, size):
            hx, sz = self.fs.hash_file(abspath)
            # the chunked flag mirrors what staging would produce, so
            # rerun's entry comparison never sees a spurious difference
            return self._annex_entry(make_annex_key(hx, sz), self._should_chunk(sz))
        data = self.fs.read_bytes(abspath)
        parsed = parse_pointer_full(data)
        if parsed is not None:
            return self._annex_entry(*parsed)
        if self._should_annex(relpath, len(data)):
            return self._annex_entry(
                annex_key_for_bytes(data), self._should_chunk(len(data))
            )
        return {"t": "blob", "oid": self.objects.oid_for("blob", data)}

    def ingest_external_file(self, src: str, relpath: str) -> dict:
        """Fused copy-back + stage (DESIGN.md §9): absorb a file the caller
        *owns* (an --alt-dir staged output) into the repository at
        ``relpath``, moving its bytes exactly once. Annex-eligible content
        is hash-while-write ingested straight from ``src`` into the annex
        (one read + one write) and the source file itself becomes the
        worktree copy via a rename — the in-repo fast path — instead of a
        second byte copy. Small content is read once and renamed likewise.
        Falls back to copy + unlink when ``src`` sits on another device.
        Returns the tree entry."""
        dst = os.path.join(self.root, relpath)
        size = self.fs.stat_size(src)
        entry = None
        if size > _POINTER_MAX and self._should_annex(relpath, size):
            chunked = self._should_chunk(size)
            entry = self._annex_entry(
                self.annex.ingest_file(src, chunked=chunked), chunked
            )
        else:
            entry = self._entry_for_data(relpath, self.fs.read_bytes(src))
        try:
            self.fs.rename(src, dst)
        except OSError as e:
            if e.errno != errno.EXDEV:  # only cross-device falls back
                raise
            self.fs.copy_file(src, dst)
            self.fs.unlink(src)
        return entry

    def _expand_paths(self, paths) -> list[str]:
        out: list[str] = []
        for p in paths:
            rel = os.path.relpath(os.path.join(self.root, p), self.root)
            if rel.startswith(".."):
                raise ValueError(f"path escapes repository: {p}")
            abspath = os.path.join(self.root, rel)
            if os.path.isdir(abspath):
                for dirpath, dirnames, files in os.walk(abspath):
                    dirnames[:] = [d for d in dirnames if d != REPRO_DIR]
                    for f in sorted(files):
                        r = os.path.relpath(os.path.join(dirpath, f), self.root)
                        if not self._is_ignored(r):
                            out.append(r)
            elif os.path.exists(abspath):
                if not self._is_ignored(rel):
                    out.append(rel)
            else:
                raise FileNotFoundError(f"no such path: {p}")
        return out

    def stage_paths(self, paths, single_pass: bool = True) -> dict[str, dict]:
        """Hash ``paths`` (files or directories) into tree entries, writing
        blob/annex content as needed. Returns {relpath: entry}.
        ``single_pass=False`` restores the seed-era read-whole-then-write
        staging (legacy data-plane benchmarks)."""
        return {
            rel: self._hash_working_file(rel, single_pass=single_pass)
            for rel in dict.fromkeys(self._expand_paths(paths))
        }

    def commit_changes(
        self,
        changes: dict[str, dict | None],
        message: str = "",
        parents: list[str] | None = None,
        author: str = "repro",
        allow_empty: bool = False,
        base_commit: str | None = None,
        base_tree: str | None = None,
        spec: dict | None = None,
        defer: list | None = None,
    ) -> tuple[str, str | None]:
        """Low-level incremental commit: apply ``changes`` on top of
        ``base_tree`` and write a commit object. Does NOT move any ref —
        callers (``save``, the scheduler's batched finish) do that. Returns
        ``(commit_oid, tree_oid)``; if nothing changed and ``allow_empty`` is
        false, returns the base commit unchanged. ``spec`` (a RunSpec JSON
        dict) is embedded as a first-class field of the commit object, so
        provenance replay needs no message parsing.

        ``defer``: append the commit object to the given list instead of
        writing it (the oid is still returned). The caller MUST make the
        batch durable via ``objects.put_commits_packed(defer)`` before
        publishing any ref that references these oids — the §11 memoized
        publish path, where N loose commit writes collapse into one pack."""
        tree_oid = self._update_tree(base_tree, changes)
        if tree_oid == base_tree and base_commit is not None and not allow_empty:
            return base_commit, base_tree  # nothing changed (paper §3 step 8)
        commit = {
            "tree": tree_oid or "",
            "parents": parents
            if parents is not None
            else ([base_commit] if base_commit else []),
            "author": author,
            "timestamp": time.time(),
            "message": message,
        }
        if spec is not None:
            commit["spec"] = spec
        if defer is not None:
            defer.append(commit)
            payload = canonical_json(commit)
            return (
                self.objects.oid_for("commit", payload), tree_oid
            )
        return self.objects.put_commit(commit), tree_oid

    def save(
        self,
        paths=None,
        message: str = "",
        parents: list[str] | None = None,
        author: str = "repro",
        allow_empty: bool = False,
        branch: str | None = None,
        engine: str = "incremental",
        spec: dict | None = None,
    ) -> str:
        """Stage ``paths`` (files or directories; None = whole worktree) on top
        of the current tree and commit. Returns the commit oid.

        ``engine="incremental"`` (default) rebuilds only the dirty spine of
        the tree — O(changed paths x depth). ``engine="full"`` re-reads and
        re-emits the entire tree (the seed-era behavior, kept for equivalence
        testing and benchmarks); both emit identical oids for the same
        content. ``spec`` embeds a RunSpec JSON dict into the commit object
        (see ``commit_changes``)."""
        if engine not in ("incremental", "full"):
            raise ValueError(f"unknown save engine: {engine!r}")
        branch = branch or self.current_branch()
        with self.ref_lock:
            return self._save_locked(
                paths, message, parents, author, allow_empty, branch, engine, spec
            )

    def _save_locked(
        self, paths, message, parents, author, allow_empty, branch, engine, spec
    ) -> str:
        base = self.branch_head(branch)
        if engine == "full":
            return self._save_full(
                paths, message, parents, author, allow_empty, branch, base, spec
            )
        base_tree = self._tree_oid_of(base)
        changes: dict[str, dict | None] = {}
        if paths is None:
            # a worktree-wide save must see the full flat tree to notice
            # tracked files that disappeared; it is inherently O(worktree).
            flat = self.tree_of(base) if base else {}
            top = [p for p in os.listdir(self.root) if not self._is_ignored(p)]
            expanded = set(self._expand_paths(top))
            for known in flat:
                # isfile, not exists: a tracked file whose path is now a
                # directory is gone (its contents show up in ``expanded``)
                if known not in expanded and not os.path.isfile(
                    os.path.join(self.root, known)
                ):
                    changes[known] = None
            for rel in sorted(expanded):
                entry = self._hash_working_file(rel)
                if flat.get(rel) != entry:
                    changes[rel] = entry
        else:
            changes = dict(self.stage_paths(paths))
        oid, _ = self.commit_changes(
            changes,
            message=message,
            parents=parents,
            author=author,
            allow_empty=allow_empty,
            base_commit=base,
            base_tree=base_tree,
            spec=spec,
        )
        if oid != base:
            self.set_branch(branch, oid)
        return oid

    def _save_full(
        self, paths, message, parents, author, allow_empty, branch, base,
        spec: dict | None = None,
    ) -> str:
        """Seed-era full rebuild: read the whole base tree, re-serialize and
        re-put every tree object. O(repo files) — kept as the reference
        implementation the incremental engine is tested against."""
        flat = self.tree_of(base) if base else {}
        before = dict(flat)
        if paths is None:
            paths = [p for p in os.listdir(self.root) if not self._is_ignored(p)]
            # full save: drop tracked files that disappeared from the worktree
            # (isfile: a path that is now a directory no longer holds the file)
            expanded = set(self._expand_paths(paths))
            for known in list(flat):
                if known not in expanded and not os.path.isfile(
                    os.path.join(self.root, known)
                ):
                    del flat[known]
            for rel in sorted(expanded):
                flat[rel] = self._hash_working_file(rel)
        else:
            for rel in self._expand_paths(paths):
                # a staged path shadows stale base entries: an ancestor that
                # was a file (now a directory on disk) and any descendants of
                # a path that is a file now — mirrors the incremental engine
                for pre in proper_prefixes(rel):
                    flat.pop(pre, None)
                prefix = rel + "/"
                for stale in [k for k in flat if k.startswith(prefix)]:
                    del flat[stale]
                flat[rel] = self._hash_working_file(rel)
        if flat == before and base is not None and not allow_empty:
            return base  # nothing changed -> no commit (paper §3 step 8)
        tree_oid = self._write_nested(flat)
        commit = {
            "tree": tree_oid or "",
            "parents": [base] if base else [],
            "author": author,
            "timestamp": time.time(),
            "message": message,
        }
        if parents is not None:
            commit["parents"] = parents
        if spec is not None:
            commit["spec"] = spec
        oid = self.objects.put_commit(commit)
        self.set_branch(branch, oid)
        return oid

    # -- checkout ----------------------------------------------------------
    def _collect_tree_paths(
        self, tree_oid: str, prefix: str, targets: list[str], out: dict[str, dict]
    ) -> None:
        """Pruned tree walk: collect {relpath: entry} for every non-tree entry
        equal to or below one of ``targets``, descending only into directories
        on a target's spine. Targets are grouped by leading path component at
        each level (like ``_update_tree``), so the walk is O(entries visited +
        targets), not O(entries x targets)."""
        whole: set[str] = set()  # names whose entire subtree is targeted
        groups: dict[str, list[str]] = {}  # name -> deeper targets within it
        for t in targets:
            name, sep, rest = t.partition("/")
            if sep:
                groups.setdefault(name, []).append(rest)
            else:
                whole.add(name)
        collect_all = "" in whole  # sentinel: this whole subtree is targeted
        for name, entry in self.objects.get_tree(tree_oid).items():
            p = prefix + name
            if entry["t"] == "tree":
                if collect_all or name in whole:
                    self._collect_tree_paths(entry["oid"], p + "/", [""], out)
                elif name in groups:
                    self._collect_tree_paths(entry["oid"], p + "/", groups[name], out)
            elif collect_all or name in whole:
                out[p] = entry

    def checkout(self, commitish: str, paths: list[str] | None = None) -> None:
        """Materialize files from a commit into the worktree. Annexed files are
        written as content when present in any store, else as pointer files."""
        oid = self.resolve(commitish)
        if paths is None:
            targets = self.tree_of(oid)
        else:
            targets = {}
            tree_oid = self._tree_oid_of(oid)
            if tree_oid:
                self._collect_tree_paths(
                    tree_oid, "", [t.rstrip("/") for t in paths], targets
                )
        for relpath, entry in targets.items():
            abspath = os.path.join(self.root, relpath)
            if entry["t"] == "blob":
                self.fs.write_bytes(abspath, self.objects.get_blob(entry["oid"]))
            else:
                # git-annex semantics: only *local* content is materialized;
                # remote content needs an explicit annex_get.
                key = entry["key"]
                if self.annex.has(key):
                    self.annex.copy_to(key, abspath)  # reassembles if chunked
                else:
                    self.fs.write_bytes(
                        abspath, make_pointer(key, chunked=entry.get("chunked", False))
                    )

    # -- history ------------------------------------------------------------
    def log(self, start: str | None = None):
        """Yield (oid, commit) from ``start`` (default HEAD) over all parents,
        newest-first by timestamp."""
        start = start or self.head_commit()
        if start is None:
            return
        seen: set[str] = set()
        frontier = [self.resolve(start)]
        commits = []
        while frontier:
            oid = frontier.pop()
            if oid in seen:
                continue
            seen.add(oid)
            c = self.objects.get_commit(oid)
            commits.append((oid, c))
            frontier.extend(c["parents"])
        commits.sort(key=lambda oc: -oc[1]["timestamp"])
        yield from commits

    # -- merge ---------------------------------------------------------------
    def merge_octopus(
        self, branches: list[str], message: str = "", author: str = "repro"
    ) -> str:
        """N-parent merge (paper §5.8 / Fig. 6). Union of trees; a path changed
        to different contents by different parents is a conflict — concurrent
        jobs with overlapping outputs were already rejected at schedule time,
        so this only fires on misuse.

        Incremental: each branch is diffed against the base tree with subtree
        oids compared first, so unchanged subtrees are never read, and the
        merged tree rebuilds only the union of the branches' dirty spines —
        O(total changes), not O(branches x repo files)."""
        with self.ref_lock:
            return self._merge_octopus_locked(branches, message, author)

    def _merge_octopus_locked(
        self, branches: list[str], message: str, author: str
    ) -> str:
        branch = self.current_branch()
        base_oid = self.head_commit()
        base_tree = self._tree_oid_of(base_oid)
        merged: dict[str, dict] = {}
        provenance: dict[str, str] = {}
        parent_oids = [base_oid] if base_oid else []
        for b in branches:
            b_oid = self.resolve(b)
            parent_oids.append(b_oid)
            b_tree = self._tree_oid_of(b_oid)
            for path, entry in self._diff_trees(base_tree, b_tree).items():
                if entry is None:
                    continue  # union semantics: a branch's deletions don't merge
                if path in provenance and merged.get(path) != entry:
                    raise ConflictError(
                        f"octopus conflict on {path!r} between {provenance[path]} and {b}"
                    )
                merged[path] = entry
                provenance[path] = b
        tree_oid = self._update_tree(base_tree, merged)
        commit = {
            "tree": tree_oid or "",
            "parents": parent_oids,
            "author": author,
            "timestamp": time.time(),
            "message": message or f"octopus merge of {len(branches)} branches",
        }
        oid = self.objects.put_commit(commit)
        self.set_branch(branch, oid)
        if merged:
            self.checkout(oid, paths=sorted(merged))
        return oid

    # -- annex ops -------------------------------------------------------------
    def _find_store(self, key: str) -> AnnexStore | None:
        for store in [self.annex, *self._remotes]:
            if store.has(key):
                return store
        return None

    def whereis(self, key: str) -> list[str]:
        return [
            s.name for s in [self.annex, *self._remotes]
            if getattr(s, "available", True) and s.has(key)
        ]

    def whereis_many(self, keys: list[str]) -> dict[str, list[str]]:
        """Batched ``whereis``: one ``has_many`` per store (per-key probes
        behind each store's known-key set), never a ``keys()`` sweep — so
        locating a handful of keys doesn't charge a listdir of every shard
        in every store. A remote marked unavailable is skipped: an
        unreachable replica can neither confirm nor deny a copy."""
        stores = [
            s for s in [self.annex, *self._remotes]
            if getattr(s, "available", True)
        ]
        present = {s.name: s.has_many(keys) for s in stores}
        return {
            key: [s.name for s in stores if key in present[s.name]]
            for key in keys
        }

    def entry_at(self, commit_oid: str, path: str) -> dict | None:
        """Point lookup of one path's tree entry — O(depth), not O(repo)."""
        tree_oid = self._tree_oid_of(commit_oid)
        parts = path.split("/")
        for part in parts[:-1]:
            if tree_oid is None:
                return None
            e = self.objects.get_tree(tree_oid).get(part)
            if e is None or e["t"] != "tree":
                return None
            tree_oid = e["oid"]
        if tree_oid is None:
            return None
        return self.objects.get_tree(tree_oid).get(parts[-1])

    def annex_key_at(self, path: str, commitish: str | None = None) -> str:
        oid = self.resolve(commitish) if commitish else self.head_commit()
        if oid is None:
            raise KeyError("empty repository")
        entry = self.entry_at(oid, path)
        if entry is None or entry["t"] != "annex":
            raise KeyError(f"{path} is not an annexed file")
        return entry["key"]

    def annex_fetch_key(self, key: str, chunked: bool = False) -> AnnexStore:
        """Ensure the *local* store holds ``key``, fetching from any remote
        that has it. Chunked objects fetch as a delta: a ``has_many``
        pre-pass finds which chunks are already local (shared with earlier
        checkpoints), only the misses move — streamed, verified per chunk —
        and a manifest referencing them is published locally. Returns the
        local store."""
        if self.annex.has(key):
            return self.annex
        store = self._find_store(key)
        if store is None:
            raise FileNotFoundError(f"no store has {key}")
        chunks = store.manifest_of(key) if (chunked or store.chunk_aware) else None
        if chunks is None:
            # whole object: streamed verified copy, never a memory buffer —
            # routed through the store so a network remote charges the
            # download on its link, not on the local profile
            store.fetch_into(key, self.annex)
            return self.annex
        local = self.annex.has_many(chunks)
        for ck in chunks:
            if ck not in local:
                store.fetch_into(ck, self.annex)
                local.add(ck)  # duplicate chunk keys in one manifest
        self.annex.put_manifest(key, chunks)
        return self.annex

    def annex_get(self, path: str) -> bool:
        """Ensure the worktree file at ``path`` has real content (datalad get).
        Returns True if a fetch occurred."""
        abspath = os.path.join(self.root, path)
        data = self.fs.read_bytes(abspath)
        parsed = parse_pointer_full(data)
        if parsed is None:
            return False  # already content
        key, chunked = parsed
        if chunked or self.annex.chunk_aware:
            # chunk-tier route: delta-fetch into the local store, then a
            # streamed reassembly into the worktree — whole-object bytes
            # never transit memory
            self.annex_fetch_key(key, chunked=chunked)
            self.annex.copy_to(key, abspath)
            return True
        store = self._find_store(key)
        if store is None:
            raise FileNotFoundError(f"no store has {key} for {path}")
        content = store.read(key)
        self.annex.put_bytes(key, content)  # cache locally
        self.fs.write_bytes(abspath, content)
        return True

    def verified_copies(self, key: str) -> list[str]:
        """Names of the remotes holding ``key`` by *fresh* presence probe —
        the only evidence a drop may rely on. Every check routes through
        ``has_many(fresh=True)`` (one batched round trip per remote, never
        the known-key set: a cached positive can be stale the moment a
        foreign process drops its copy). A remote that is unavailable or
        errors through its retry budget confirms nothing — an unreachable
        replica cannot vouch for a copy."""
        from .faults import InjectedNetworkError, RemoteUnavailable

        confirmed = []
        for s in self._remotes:
            if isinstance(s, RemoteStore) and not s.available:
                continue
            try:
                if key in net_retry(
                    s, lambda s=s: s.has_many([key], fresh=True),
                    f"numcopies probe on {s.name}",
                ):
                    confirmed.append(s.name)
            except (InjectedNetworkError, RemoteUnavailable):
                continue
        return confirmed

    def annex_drop(self, path: str, force: bool = False) -> None:
        """Replace worktree content with a pointer and drop the local copy.
        Refuses unless ``numcopies`` verified replicas exist elsewhere
        (paper §2.6) — verified means a fresh probe *now*, per
        :meth:`verified_copies`; nothing cached can authorize a drop."""
        abspath = os.path.join(self.root, path)
        data = self.fs.read_bytes(abspath)
        key = parse_pointer(data)
        if key is None:
            key = annex_key_for_bytes(data)
        need = self.numcopies
        others = self.verified_copies(key)
        if len(others) < need and not force:
            raise RuntimeError(
                f"refusing to drop {path} ({key}): {len(others)} verified "
                f"cop{'y' if len(others) == 1 else 'ies'} elsewhere "
                f"({', '.join(others) or 'none'}), numcopies={need}; "
                "use force=True"
            )
        chunked = False
        if self.annex.chunk_aware and self.annex.has(key):
            chunked = self.annex.manifest_of(key) is not None
        self.fs.write_bytes(abspath, make_pointer(key, chunked=chunked))
        if self.annex.has(key):
            # a chunked drop removes the manifest; shared chunks stay for
            # other manifests and are reclaimed by gc's orphan sweep
            self.annex.drop(key)

    def annex_push(self, store: AnnexStore, keys: list[str] | None = None) -> int:
        """Push local annex content to another store (datalad push). Returns
        number of keys transferred. An explicit key list is served by
        per-key presence probes on both sides (``has_many``); only the
        push-everything form pays the full ``keys()`` enumeration. Content
        moves as a streamed file copy, never a whole-object read into
        memory."""
        if keys is None:
            keys = self.annex.keys()
        local = self.annex.has_many(keys)
        remote = store.has_many(keys)
        n = 0
        for key in keys:
            if key not in local or key in remote:
                continue
            chunks = self.annex.manifest_of(key) if self.annex.chunk_aware else None
            if chunks is not None:
                # chunked object: move only the chunks the remote lacks,
                # then bind them there with a freshly encoded manifest
                # (manifest bytes don't hash to the key, so put_file
                # can't carry them)
                remote_chunks = store.has_many(chunks)
                for ck in chunks:
                    if ck not in remote_chunks:
                        store.receive_file(ck, self.annex.fs, self.annex._path(ck))
                        remote_chunks.add(ck)
                store.put_manifest(key, chunks)
            else:
                store.receive_file(key, self.annex.fs, self.annex._path(key))
            n += 1
        return n

    # -- lock/unlock -------------------------------------------------------------
    def unlock(self, path: str) -> None:
        abspath = os.path.join(self.root, path)
        if os.path.exists(abspath):
            self.fs.chmod_readonly(abspath, readonly=False)

    def lock(self, path: str) -> None:
        abspath = os.path.join(self.root, path)
        if os.path.exists(abspath):
            self.fs.chmod_readonly(abspath, readonly=True)
