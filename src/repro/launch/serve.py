"""Serving launcher: batched prefill+decode for any architecture, optionally
restoring weights from a version-store checkpoint commit.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_1_6b \\
        --batch 8 --prompt-len 64 --gen 32 [--repo PATH [--commit OID]]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from .. import configs
from ..core.repo import Repository
from ..models import transformer as T
from ..models.params import init_params
from ..train.checkpoint import CheckpointManager
from ..train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repo", default="", help="restore weights from this repo")
    ap.add_argument("--commit", default=None)
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.get_smoke(args.arch)
    if args.repo:
        state, manifest = CheckpointManager(Repository(args.repo)).restore(args.commit)
        params = state["params"]
        print(f"restored checkpoint step {manifest['step']} from {args.repo}")
    else:
        params = init_params(T.param_defs(cfg), seed=0)

    cache_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, None, cache_len=cache_len))
    step = jax.jit(make_decode_step(cfg, None), donate_argnums=(1,))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}

    t0 = time.perf_counter()
    caches, logits = jax.block_until_ready(prefill(params, batch))
    print(f"prefill: {(time.perf_counter()-t0)*1e3:.1f} ms (incl. compile)")
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    lat = []
    for i in range(args.gen - 1):
        t0 = time.perf_counter()
        logits, caches = step(params, caches, tok,
                              jnp.asarray(args.prompt_len + i, jnp.int32))
        jax.block_until_ready(logits)
        lat.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    ms = np.array(lat[1:]) * 1e3
    print(f"decode: p50={np.percentile(ms,50):.2f} ms  p95={np.percentile(ms,95):.2f} ms  "
          f"throughput={args.batch*1e3/ms.mean():.0f} tok/s")


if __name__ == "__main__":
    main()
