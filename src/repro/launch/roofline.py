"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch x shape x mesh) cell, using TPU v5e constants and the per-device
numbers from launch/dryrun.py (XLA reports the per-replica SPMD module, so
per-device time IS step time — all devices run the same program):

    compute_s    = flops_per_device / 197e12
    memory_s     = bytes_per_device / 819e9
    collective_s = collective_bytes_per_device / 50e9

The dominant term is the bottleneck; roofline fraction = dominant /
(compute+memory+collective) measures how balanced the cell is, and
MODEL_FLOPS / HLO_FLOPS (6·N·D train, 2·N·D inference, N_active for MoE)
measures how much compiled compute is "useful" (catches remat/dispatch
overhead — and, for small-d_model archs, genuine attention-matmul work the
parameter-count metric ignores).

Usage: python -m repro.launch.roofline [--tag TAG] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results_dryrun")


def model_flops(cell: dict) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params, D = global
    tokens processed by the step."""
    n_active = cell["params_active"]
    if cell["kind"] == "train":
        tokens = cell["global_batch"] * cell["seq_len"]
        return 6.0 * n_active * tokens
    if cell["kind"] == "prefill":
        tokens = cell["global_batch"] * cell["seq_len"]
        return 2.0 * n_active * tokens
    tokens = cell["global_batch"]  # decode: one token per sequence
    return 2.0 * n_active * tokens


def analyze(cell: dict) -> dict:
    chips = cell["chips"]
    compute_s = cell["flops_per_device"] / PEAK_FLOPS_BF16
    memory_s = cell["bytes_per_device"] / HBM_BW
    coll_s = cell["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    total_hlo_flops = cell["flops_per_device"] * chips
    mf = model_flops(cell)
    hbm_gib = (cell["memory"]["argument_bytes"] + cell["memory"]["temp_bytes"]
               + cell["memory"]["output_bytes"]) / 2**30
    return {
        **{k: cell.get(k) for k in ("arch", "shape", "mesh", "kind", "chips")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_step_s": terms[dominant],
        "roofline_fraction": terms[dominant] / (compute_s + memory_s + coll_s),
        "model_flops": mf,
        "useful_compute_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "hbm_gib_per_device": hbm_gib,
        "fits_v5e_16g": hbm_gib < 16.0,
        "collective_by_type": cell.get("collective_by_type", {}),
    }


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split(".")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | dominant | "
           "MODEL/HLO | HBM GiB/dev | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_compute_ratio']:.2f} | {r['hbm_gib_per_device']:.2f} | "
            f"{'yes' if r['fits_v5e_16g'] else 'NO'} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()
    cells = load_cells(args.tag)
    rows, skips, fails = [], [], []
    for c in cells:
        if c["status"] == "ok":
            rows.append(analyze(c))
        elif c["status"] == "skipped":
            skips.append(c)
        else:
            fails.append(c)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.markdown:
        print(markdown_table(rows))
        if skips:
            print("\nSkipped cells (assignment rule):")
            for s in skips:
                print(f"- {s['arch']} x {s['shape']}: {s['skip_reason']}")
        if fails:
            print("\nFAILED cells:")
            for s in fails:
                print(f"- {s['arch']} x {s['shape']} x {s['mesh']}: {s.get('error')}")
    else:
        for r in rows:
            print(json.dumps(r))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
