"""Training launcher: run a (smoke-sized) architecture as a reproducible
training job inside a version-store repository.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \\
        --steps 40 --repo /tmp/myrun [--full]

``--full`` selects the full assignment config (needs real accelerators);
the default smoke config runs on CPU in minutes. Either way the run is
checkpointed into the repository with machine-actionable records and is
resumable by re-invoking the same command (kill-anywhere semantics).
"""
from __future__ import annotations

import argparse
import os

from .. import configs
from ..core.repo import Repository
from ..data.tokens import SyntheticTokens
from ..optim.adamw import AdamW, cosine_schedule
from ..train.loop import train_segment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--repo", default="")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (accelerators required)")
    ap.add_argument("--async-ckpt", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.get_smoke(args.arch)
    root = args.repo or os.path.abspath(f"train_{args.arch}")
    if os.path.exists(os.path.join(root, ".repro")):
        repo = Repository(root)
        print(f"resuming in existing repository {root}")
    else:
        repo = Repository.init(root)
        print(f"new repository {root}")

    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                         global_batch=args.batch, seed=0)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=10, total=args.steps),
                moment_dtype=cfg.opt_moment_dtype)
    res = train_segment(repo, cfg, ds, n_steps=args.steps,
                        ckpt_every=args.ckpt_every, optimizer=opt,
                        async_ckpt=args.async_ckpt)
    print(f"steps {res.start_step} -> {res.end_step}  loss {res.final_loss:.4f}")
    print(f"checkpoint commit: {res.checkpoint_commit}")


if __name__ == "__main__":
    main()
